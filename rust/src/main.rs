//! `ebc-summarizer` — the L3 coordinator launcher.
//!
//! Every subcommand parses its flags into one
//! [`ebc::api::SummarizeRequest`] and executes it through one
//! [`ebc::api::Service`] — the typed façade is the only way work enters
//! the system (no per-subcommand backend wiring).
//!
//! Subcommands:
//! * `info`         — runtime + artifact inventory
//! * `summarize`    — summarize a synthetic dataset (quick demo)
//! * `casestudy`    — the paper's §6 injection-molding study (Table 2 / Fig. 4)
//! * `serve`        — run the production daemon over a simulated fleet
//! * `serve-replica` — run one TCP worker replica (the `tcp` transport's far end)
//! * `shard-bench`  — sharded two-stage scaling sweep (shards × wall-clock)
//! * `kernel-bench` — CPU kernel backend sweep (scalar vs blocked vs simd × threads)
//! * `devices`      — analytical device-model predictions (Table 1 shape)
//! * `obs-dump`     — run a traced synthetic request, dump metrics + span tree

use anyhow::Result;
use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
use ebc::bench::report::fmt_secs;
use ebc::bench::{
    kernel_scaling_sweep, prune_scaling_sweep, shard_scaling_sweep, shard_split_sweep,
    KernelSweepConfig, Reporter, ShardSweepConfig,
};
use ebc::cli::{flag, opt, AppSpec, CommandSpec, Matches};
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{Admission, CycleRecord, SimulatedFleet, FLEET_QUERY};
use ebc::daemon::{shutdown, Daemon};
use ebc::engine::{OracleSpec, PlanRequest, Precision};
use ebc::gpumodel::{
    predict_seconds, speedup, EbcWorkload, ModelPrecision, A72, QUADRO_RTX_5000, TX2, XEON_W2155,
};
use ebc::imm::casestudy::{fig4_table, run_table2, table2_text, validate_expectations};
use ebc::imm::{Part, ProcessState};
use ebc::linalg::{CpuKernel, SharedMatrix};
use ebc::obs;
use ebc::shard::{NetOptions, ReplicaServer};
use ebc::optim::Greedy;
use ebc::runtime::Runtime;
use ebc::util::logging;
use std::sync::Arc;

fn app() -> AppSpec {
    AppSpec {
        name: "ebc-summarizer",
        about: "Exemplar-based clustering data summarization for Industry 4.0",
        commands: vec![
            CommandSpec {
                name: "info",
                help: "show runtime platform + artifact inventory",
                flags: vec![],
            },
            CommandSpec {
                name: "summarize",
                help: "summarize a synthetic dataset (quick demo)",
                flags: vec![
                    opt("n", "ground-set size", "1000"),
                    opt("d", "dimensionality", "100"),
                    opt("k", "summary size", "5"),
                    opt("seed", "rng seed", "42"),
                    opt("backend", "cpu | xla", "xla"),
                    opt("precision", "f32 | bf16", "f32"),
                    opt("kernel", "cpu kernel backend: scalar | blocked | simd", "blocked"),
                    opt("oracle-threads", "cpu oracle worker threads (0 = auto)", "0"),
                    opt("algorithm", "any optim registry name (greedy, lazy_greedy, ...)", "greedy"),
                    opt("shards", "run two-stage over P shards (0 = single-node)", "0"),
                    opt("prune", "coordinator-side prune rate in [0, 1)", "0"),
                    opt("fanout", "hierarchical merge fanout (0 = flat merge)", "0"),
                    opt("max-merge-n", "per-merge-node ground cap (0 = off)", "0"),
                    opt("merge-optimizer", "optimizer for coordinator merge nodes", "greedy"),
                    flag("trace", "record this request's span tree and print it"),
                ],
            },
            CommandSpec {
                name: "casestudy",
                help: "injection-molding case study (paper §6)",
                flags: vec![
                    opt("k", "representatives per dataset", "5"),
                    opt("samples", "samples per cycle (paper: 3524)", "3524"),
                    opt("seed", "rng seed", "7"),
                    opt("backend", "cpu | xla", "xla"),
                    opt("kernel", "cpu kernel backend: scalar | blocked | simd", "scalar"),
                    opt("oracle-threads", "cpu oracle worker threads (0 = auto)", "1"),
                    flag("table2", "print Table 2"),
                    flag("fig4", "export Fig. 4 regrind curves (plate)"),
                    flag("validate", "check process-knowledge expectations"),
                ],
            },
            CommandSpec {
                name: "serve",
                help: "run the production daemon over a simulated fleet (ctrl-c drains)",
                flags: vec![
                    opt("config", "service config file (TOML subset; SIGHUP re-reads it)", ""),
                    opt("samples", "samples per cycle", "256"),
                    opt("seed", "rng seed", "1"),
                    opt("backend", "cpu | xla", "cpu"),
                    opt("status-addr", "status/metrics HTTP endpoint (overrides [daemon])", ""),
                    opt("cycles", "stop after N offered cycles (0 = run until SIGINT)", "0"),
                ],
            },
            CommandSpec {
                name: "serve-replica",
                help: "run one TCP worker replica serving shard jobs to a coordinator",
                flags: vec![
                    opt("addr", "listen address (port 0 = ephemeral)", "127.0.0.1:7700"),
                    opt("id", "replica name sent in hello/heartbeat frames", "replica-1"),
                    opt("capacity", "relative share of the shard deal (>= 1)", "1"),
                    opt("workers", "job execution worker threads (>= 1)", "1"),
                    opt("backend", "cpu | xla", "cpu"),
                    opt("precision", "f32 | bf16", "f32"),
                    opt("kernel", "cpu kernel backend: scalar | blocked | simd", "blocked"),
                    opt("max-frame-mb", "largest accepted frame (MiB)", "64"),
                    opt("io-timeout-ms", "per-socket-op read/write deadline", "5000"),
                ],
            },
            CommandSpec {
                name: "shard-bench",
                help: "sharded two-stage summarization scaling sweep on a generated IMM dataset",
                flags: vec![
                    opt("samples", "samples per cycle (dataset dimensionality)", "256"),
                    opt("k", "summary size", "10"),
                    opt("seed", "rng seed", "7"),
                    opt("shards", "comma-separated shard counts", "1,2,4,8"),
                    opt("partitioner", "round_robin | hash | locality", "round_robin"),
                    opt("algorithms", "comma-separated optimizer names", "greedy"),
                    opt("threads", "shard-stage worker threads (0 = auto)", "0"),
                    opt("backend", "cpu | xla", "cpu"),
                    opt("kernel", "cpu kernel backend: scalar | blocked | simd", "scalar"),
                    opt(
                        "oracle-threads",
                        "per-shard oracle threads (0 = auto; 1 = shard workers own it)",
                        "1",
                    ),
                    flag("plan", "pre-plan bucket shape + P x T core split per shard count"),
                    opt("cores", "core budget for --plan (0 = auto)", "0"),
                    opt("transport", "shard-stage transport: inproc | loopback | tcp", "inproc"),
                    opt("replicas", "replica count for --transport loopback", "2"),
                    opt(
                        "replica-addrs",
                        "comma-separated host:port endpoints for --transport tcp",
                        "",
                    ),
                    opt("chaos", "fault-injection seed, 0 = off (see shard::fault)", "0"),
                    opt(
                        "prune",
                        "comma-separated prune rates for the prune sweep (empty = skip)",
                        "",
                    ),
                    opt("fanout", "hierarchical merge fanout for pruned cells (0 = flat)", "0"),
                    opt("max-merge-n", "per-merge-node ground cap (0 = off)", "0"),
                    opt("merge-optimizer", "optimizer for coordinator merge nodes", "greedy"),
                    opt("out", "output JSON path", "BENCH_shard.json"),
                ],
            },
            CommandSpec {
                name: "kernel-bench",
                help: "CPU kernel backend sweep: scalar vs blocked vs simd x threads",
                flags: vec![
                    opt("n", "ground-set size", "20000"),
                    opt("d", "dimensionality", "32"),
                    opt("c", "candidate-batch width", "1024"),
                    opt("threads", "comma-separated thread counts", "1,2,4,8"),
                    opt("shards", "shard counts for the planned-vs-unplanned split", "2,4"),
                    opt("seed", "rng seed", "7"),
                    opt("out", "output JSON path", "BENCH_kernel.json"),
                ],
            },
            CommandSpec {
                name: "obs-dump",
                help: "run a traced synthetic sharded request, dump metrics + span tree",
                flags: vec![
                    opt("n", "ground-set size", "400"),
                    opt("d", "dimensionality", "16"),
                    opt("k", "summary size", "4"),
                    opt("seed", "rng seed", "42"),
                    opt("shards", "shard count for the traced request", "2"),
                    opt("backend", "cpu | xla", "cpu"),
                ],
            },
            CommandSpec {
                name: "devices",
                help: "analytical device model: paper Table 1 predictions",
                flags: vec![
                    opt("n", "ground-set size", "50000"),
                    opt("l", "number of sets", "5000"),
                    opt("k", "set size", "10"),
                    opt("d", "dimensionality", "100"),
                ],
            },
        ],
    }
}

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let spec = app();
    let (cmd, m) = match spec.parse(&args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "info" => cmd_info(),
        "summarize" => cmd_summarize(&m),
        "casestudy" => cmd_casestudy(&m),
        "serve" => cmd_serve(&m),
        "serve-replica" => cmd_serve_replica(&m),
        "shard-bench" => cmd_shard_bench(&m),
        "kernel-bench" => cmd_kernel_bench(&m),
        "obs-dump" => cmd_obs_dump(&m),
        "devices" => cmd_devices(&m),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_precision(s: &str) -> Result<Precision> {
    match s {
        "f32" => Ok(Precision::F32),
        "bf16" | "fp16" => Ok(Precision::Bf16),
        other => anyhow::bail!("unknown precision '{other}'"),
    }
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::discover()?;
    println!(
        "platform: {} ({} device(s))",
        rt.client().platform_name(),
        rt.client().device_count()
    );
    println!("artifacts: {}", rt.manifest().dir.display());
    println!(
        "{:<44} {:>6} {:>6} {:>6} {:>10} {:>9}",
        "name", "n", "d", "c/l*k", "vmem", "programs"
    );
    for e in &rt.manifest().entries {
        let extra = if e.c > 0 {
            e.c.to_string()
        } else {
            format!("{}x{}", e.l, e.k)
        };
        println!(
            "{:<44} {:>6} {:>6} {:>6} {:>8.2}MB {:>9}",
            e.name,
            e.n,
            e.d,
            extra,
            e.vmem_bytes as f64 / 1e6,
            e.grid_programs
        );
    }
    Ok(())
}

fn cmd_summarize(m: &Matches) -> Result<()> {
    let n = m.usize("n")?;
    let d = m.usize("d")?;
    let service = Service::from_backend(m.str("backend")?)?;
    let shards = m.usize("shards")?;
    let mut req = SummarizeRequest::new(
        DatasetRef::synthetic(n, d, m.usize("seed")? as u64),
        m.usize("k")?,
    )
    .optimizer(m.str("algorithm")?)
    .precision(parse_precision(m.str("precision")?)?)
    .cpu_kernel(CpuKernel::parse(m.str("kernel")?)?)
    .threads(m.usize("oracle-threads")?)
    .trace(m.has("trace"));
    if shards > 0 {
        req = req.sharded(
            ShardSpec::new(shards)
                .prune(m.f64("prune")?)
                .fanout(m.usize("fanout")?)
                .max_merge_n(m.usize("max-merge-n")?)
                .merge_optimizer(m.str("merge-optimizer")?),
        );
    }
    let res = service.summarize(&req)?;
    println!(
        "summary of {n}x{d} ({}, backend={}): k={}",
        res.provenance.optimizer,
        res.provenance.backend,
        res.k()
    );
    println!("representatives: {:?}", res.exemplars);
    println!("f(S) = {:.6}", res.f_final);
    println!(
        "wall: {:.3}s, oracle calls: {}, distance work: {:.2e}",
        res.timings.wall_seconds, res.oracle_calls, res.oracle_work as f64
    );
    if shards > 0 {
        println!(
            "shards: {} used, pruned_n = {}, prune {:.3}s, merge depth {} ({})",
            res.provenance.shards_used,
            res.provenance.pruned_n,
            res.provenance.prune_seconds,
            res.provenance.merge_depth,
            res.provenance.merge_optimizer,
        );
    }
    if m.has("trace") {
        match &res.provenance.trace {
            Some(spans) => print!("\ntrace ({} spans):\n{}", spans.len(), obs::expo::render_trace(spans)),
            None => println!("\ntrace: (span recording disabled)"),
        }
    }
    Ok(())
}

fn cmd_casestudy(m: &Matches) -> Result<()> {
    let k = m.usize("k")?;
    let samples = m.usize("samples")?;
    let seed = m.usize("seed")? as u64;
    let service = Service::from_backend(m.str("backend")?)?;
    // the base request the per-campaign oracles are built from (each
    // campaign dataset is generated inside run_table2)
    let base = SummarizeRequest::new(
        DatasetRef::imm(Part::Cover, ProcessState::Stable, samples, seed),
        k,
    )
    .cpu_kernel(CpuKernel::parse(m.str("kernel")?)?)
    .threads(m.usize("oracle-threads")?);
    base.validate()?;
    let optimizer = Greedy::default();

    log::info!("generating 10 campaigns ({} samples/cycle) + summarizing", samples);
    let results = run_table2(&optimizer, &service.case_factory(&base), k, samples, seed);

    if m.has("table2") || (!m.has("fig4") && !m.has("validate")) {
        println!("{}", table2_text(&results, k));
        for r in &results {
            println!(
                "  {:>6}/{:<16} f={:.1} wall={:.2}s",
                r.part.name(),
                r.state.name(),
                r.f_value,
                r.wall_seconds
            );
        }
    }
    if m.has("validate") {
        let mut failures = 0;
        for r in &results {
            match validate_expectations(r) {
                Ok(()) => println!("  OK   {} / {}", r.part.name(), r.state.name()),
                Err(e) => {
                    failures += 1;
                    println!("  FAIL {} / {}: {e}", r.part.name(), r.state.name());
                }
            }
        }
        if failures > 0 {
            anyhow::bail!("{failures} expectation(s) violated");
        }
    }
    if m.has("fig4") {
        let r = results
            .iter()
            .find(|r| r.part == Part::Plate && r.state == ProcessState::Regrind)
            .expect("plate/regrind present");
        let t = fig4_table(r);
        let path = std::path::Path::new("bench_results").join("fig4_regrind_plate.csv");
        t.save(&path)?;
        println!("fig4: wrote {} ({} curves)", path.display(), r.reps.len());
    }
    Ok(())
}

fn cmd_serve(m: &Matches) -> Result<()> {
    let samples = m.usize("samples")?;
    let seed = m.usize("seed")? as u64;
    let cycles = m.usize("cycles")?;
    let config_path = m.str("config")?.to_string();
    let status_override = m.str("status-addr")?.to_string();
    let mut cfg = match config_path.as_str() {
        "" => ServiceConfig::default(),
        path => ServiceConfig::load(path)?,
    };
    if !status_override.is_empty() {
        cfg.daemon.status_addr = status_override.clone();
    }
    let drain_timeout = std::time::Duration::from_millis(cfg.daemon.drain_timeout_ms);
    let service = Service::from_backend(m.str("backend")?)?;
    let daemon = Daemon::start(service.coordinator(cfg))?;
    let coordinator = Arc::clone(daemon.coordinator());
    let dmetrics = daemon.metrics_arc();
    if let Some(addr) = daemon.status_addr() {
        println!("status endpoint: http://{addr} (/healthz /metrics /status)");
    }
    let flags = shutdown::install();
    flags.reset();

    let specs = [
        ("imm-cover-1", Part::Cover, ProcessState::Stable),
        ("imm-cover-2", Part::Cover, ProcessState::StartUp),
        ("imm-plate-1", Part::Plate, ProcessState::Regrind),
        ("imm-plate-2", Part::Plate, ProcessState::Downtimes),
    ];
    let mut fleet = SimulatedFleet::new(&specs, samples, seed);
    // campaign replays restart machine-local seq at 0; rebase so every
    // machine's sequence stays monotone across replays
    let mut seqs: std::collections::BTreeMap<String, u64> = Default::default();
    let mut replay = 0u64;
    let mut offered: usize = 0;
    println!(
        "serving {} machines ({} samples/cycle); {} (SIGHUP reloads config)",
        specs.len(),
        samples,
        if cycles == 0 { "ctrl-c to drain".to_string() } else { format!("{cycles} cycles") }
    );
    let t0 = std::time::Instant::now();
    while !flags.stop_requested() && (cycles == 0 || offered < cycles) {
        if flags.take_reload() {
            if config_path.is_empty() {
                log::warn!("SIGHUP received but no --config file to reload from");
            } else {
                match ServiceConfig::load(&config_path) {
                    Ok(mut new) => {
                        if !status_override.is_empty() {
                            new.daemon.status_addr = status_override.clone();
                        }
                        match daemon.reload(new) {
                            Ok(plan) => log::info!("reloaded {config_path}: {plan:?}"),
                            Err(e) => log::error!("reload rejected: {e}"),
                        }
                    }
                    Err(e) => log::error!("reload: cannot read {config_path}: {e:#}"),
                }
            }
        }
        let rec = match fleet.next_record() {
            Some(r) => r,
            None => {
                // continuous operation: replay a fresh campaign
                replay += 1;
                fleet = SimulatedFleet::new(&specs, samples, seed + replay);
                continue;
            }
        };
        let seq = seqs.entry(rec.machine.clone()).or_insert(0);
        let rec = CycleRecord { seq: *seq, ..rec };
        *seq += 1;
        match daemon.offer(rec) {
            None => break, // draining
            Some(Admission::Accepted) => {}
            // past the watermark (or evicting): yield so workers catch up
            Some(_) => std::thread::sleep(std::time::Duration::from_millis(1)),
        }
        offered += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "offered {offered} cycles in {dt:.2}s ({:.0} cycles/s); draining...",
        offered as f64 / dt
    );
    let report = daemon.drain(drain_timeout);
    if report.drained {
        println!("drained in {:.2}s", report.seconds);
    } else {
        println!(
            "drain timed out after {:.2}s: {} record(s) queued, {} job(s) pending, {} in flight",
            report.seconds, report.queue_len, report.pending_jobs, report.in_flight_jobs
        );
    }
    if let Some(path) = &report.snapshot_path {
        println!("snapshot: {path}");
    }
    for (name, _, _) in &specs {
        println!("--- {name}: {}", coordinator.query(name).describe());
    }
    println!("--- fleet: {}", coordinator.query(FLEET_QUERY).describe());
    print!(
        "\nmetrics:\n{}{}",
        obs::expo::render_text(&coordinator.metrics.registry().snapshot()),
        obs::expo::render_text(&dmetrics.registry().snapshot())
    );
    if !report.drained {
        anyhow::bail!("drain incomplete (work lost)");
    }
    Ok(())
}

fn cmd_serve_replica(m: &Matches) -> Result<()> {
    let addr = m.str("addr")?;
    let id = m.str("id")?;
    let service = Service::from_backend(m.str("backend")?)?;
    let factory = service.oracle_factory(
        parse_precision(m.str("precision")?)?,
        CpuKernel::parse(m.str("kernel")?)?,
        0,
    );
    let f = |mat: SharedMatrix, spec: &OracleSpec| factory(mat, spec);
    let opts = NetOptions {
        io_timeout_ms: m.usize("io-timeout-ms")?.max(1) as u64,
        max_frame_mb: m.usize("max-frame-mb")?.max(1) as u32,
        ..NetOptions::default()
    };
    let server = ReplicaServer::bind(
        addr,
        id,
        m.usize("capacity")?.max(1) as u32,
        m.usize("workers")?,
        &opts,
    )?;
    println!(
        "replica '{id}' listening on {} (backend={}, stop with ctrl-c)",
        server.local_addr()?,
        service.backend_name()
    );
    // SIGINT/SIGTERM set the stop flag: the accept loop finishes the
    // frame in flight and exits instead of dying mid-write
    let flags = shutdown::install();
    flags.reset();
    let served = server.serve(&f, flags.stop)?;
    println!("replica '{id}' served {served} job(s), exiting cleanly");
    Ok(())
}

fn parse_usize_list(raw: &str, flag: &str) -> Result<Vec<usize>> {
    let out: Vec<usize> = raw
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("flag '--{flag}': '{raw}' is not a comma-separated list of integers"))?;
    if out.is_empty() {
        anyhow::bail!("flag '--{flag}': empty list");
    }
    Ok(out)
}

/// Comma-separated floats; an empty string is an empty list (the
/// prune sweep is opt-in, unlike the integer lists above).
fn parse_f64_list(raw: &str, flag: &str) -> Result<Vec<f64>> {
    raw.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| {
            anyhow::anyhow!("flag '--{flag}': '{raw}' is not a comma-separated list of numbers")
        })
}

fn cmd_shard_bench(m: &Matches) -> Result<()> {
    let samples = m.usize("samples")?;
    let k = m.usize("k")?;
    let seed = m.usize("seed")? as u64;
    let algorithms: Vec<String> = m
        .str("algorithms")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if algorithms.is_empty() {
        anyhow::bail!("flag '--algorithms': empty list");
    }
    let service = Service::from_backend(m.str("backend")?)?;
    let cfg = ShardSweepConfig {
        k,
        shard_counts: parse_usize_list(m.str("shards")?, "shards")?,
        algorithms,
        partitioner: m.str("partitioner")?.to_string(),
        threads: m.usize("threads")?,
        seed,
        planned: m.has("plan"),
        cores: m.usize("cores")?,
        transport: m.str("transport")?.to_string(),
        replicas: m.usize("replicas")?.max(1),
        net: NetOptions {
            addrs: m
                .str("replica-addrs")?
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
            chaos: m.usize("chaos")? as u64,
            ..NetOptions::default()
        },
        cpu_kernel: CpuKernel::parse(m.str("kernel")?)?,
        oracle_threads: m.usize("oracle-threads")?,
        prune_rates: parse_f64_list(m.str("prune")?, "prune")?,
        fanout: m.usize("fanout")?,
        max_merge_n: m.usize("max-merge-n")?,
        merge_optimizer: m.str("merge-optimizer")?.to_string(),
    };

    log::info!("generating IMM dataset (cover/stable, d={samples})");
    // materialize once, then share: every sweep cell aliases one matrix
    let data = DatasetRef::imm(Part::Cover, ProcessState::Stable, samples, seed).materialize()?;
    let dataset = DatasetRef::Inline(Arc::clone(&data));
    println!(
        "shard scaling sweep: {}x{} IMM cycles, k={k}, partitioner={}, threads={}, \
         transport={}{}{}",
        data.rows(),
        data.cols(),
        cfg.partitioner,
        if cfg.threads == 0 {
            ebc::util::threadpool::default_threads()
        } else {
            cfg.threads
        },
        cfg.transport,
        match cfg.transport.as_str() {
            "loopback" => format!(" ({} replicas)", cfg.replicas),
            "tcp" => format!(" ({} endpoint(s))", cfg.net.addrs.len()),
            _ => String::new(),
        },
        if cfg.planned { " (planned)" } else { "" }
    );

    if cfg.planned {
        // report the planned bucket shape + core split per shard count
        let plan_source = service.plan_source(Precision::F32, cfg.cpu_kernel);
        for &p in &cfg.shard_counts {
            let mut req = PlanRequest::new(data.rows(), data.cols(), p, k);
            req.cores = cfg.cores;
            println!("plan P={p}: {}", plan_source(&req).describe());
        }
    }
    let points = shard_scaling_sweep(&service, &dataset, &cfg)?;

    let mut rep = Reporter::new(
        "shard-bench: two-stage wall-clock vs single-node",
        &[
            "algorithm", "P", "plan", "transport", "wire_kB", "retries", "shard_s",
            "merge_s", "total_s", "single_s", "speedup", "f_merged", "f_single", "quality",
        ],
    );
    for p in &points {
        rep.row(&[
            p.algorithm.clone(),
            p.shards.to_string(),
            p.plan.clone(),
            p.transport.clone(),
            format!("{:.1}", p.wire_bytes as f64 / 1e3),
            p.shard_retries.to_string(),
            fmt_secs(p.shard_seconds),
            fmt_secs(p.merge_seconds),
            fmt_secs(p.total_seconds),
            fmt_secs(p.single_seconds),
            format!("{:.2}x", p.speedup),
            format!("{:.4}", p.f_merged),
            format!("{:.4}", p.f_single),
            format!("{:.3}", p.quality_ratio),
        ]);
    }
    rep.print();
    match rep.save_csv("shard_scaling") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => log::warn!("csv export failed: {e}"),
    }

    // opt-in prune sweep: rate x P cells against the exact reference
    let prune_points = if cfg.prune_rates.is_empty() {
        Vec::new()
    } else {
        let pts = prune_scaling_sweep(&service, &dataset, &cfg)?;
        let mut prep = Reporter::new(
            "prune sweep: pruned submodularity graph + hierarchical merge vs exact",
            &[
                "rate", "P", "pruned_n", "prune_s", "depth", "total_s", "f_pruned",
                "f_exact", "quality",
            ],
        );
        for p in &pts {
            prep.row(&[
                format!("{:.2}", p.rate),
                p.shards.to_string(),
                p.pruned_n.to_string(),
                fmt_secs(p.prune_seconds),
                p.merge_depth.to_string(),
                fmt_secs(p.total_seconds),
                format!("{:.4}", p.f_pruned),
                format!("{:.4}", p.f_exact),
                format!("{:.3}", p.quality_ratio),
            ]);
        }
        prep.print();
        pts
    };

    let out = std::path::PathBuf::from(m.str("out")?);
    let path = ebc::bench::save_shard_json(&out, &cfg, &points, &prune_points)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_kernel_bench(m: &Matches) -> Result<()> {
    // the workload travels as an api request like everywhere else; the
    // sweep derives its shape from the validated request
    let base = SummarizeRequest::new(
        DatasetRef::synthetic(m.usize("n")?, m.usize("d")?, m.usize("seed")? as u64),
        1,
    )
    .batch(m.usize("c")?);
    let cfg =
        KernelSweepConfig::from_request(&base, parse_usize_list(m.str("threads")?, "threads")?)?;
    println!(
        "kernel sweep: N={} d={} C={} threads={:?} (scalar baseline vs blocked/simd \
         Gram-matrix; simd level: {})",
        cfg.n,
        cfg.d,
        cfg.c,
        cfg.thread_counts,
        ebc::linalg::simd::detected().name()
    );
    let points = kernel_scaling_sweep(&cfg, &ebc::bench::Settings::default());
    let rep = ebc::bench::kernel_scaling::kernel_report(
        "kernel-bench: CPU oracle hot path by backend",
        &points,
    );
    rep.print();

    // planned-vs-unplanned sharded CPU split (P x T <= cores vs P x cores)
    let shard_counts = parse_usize_list(m.str("shards")?, "shards")?;
    let splits = shard_split_sweep(&cfg, &shard_counts, &ebc::bench::Settings::default());
    ebc::bench::kernel_scaling::split_report(
        "kernel-bench: planned vs unplanned shard split (blocked f32 gains)",
        &splits,
    )
    .print();

    let out = std::path::PathBuf::from(m.str("out")?);
    ebc::bench::kernel_scaling::save_bench_json(&out, &cfg, &points, &splits)?;
    println!("\nwrote {}", out.display());

    // the headline numbers: best f32 gains speedup over scalar ST for
    // each gemm-family backend (simd vs blocked is the explicit-vector
    // margin on this host)
    for kernel in ["blocked", "simd"] {
        if let Some(best) = points
            .iter()
            .filter(|p| p.op == "gains" && p.kernel == kernel && p.precision == "f32")
            .max_by(|a, b| a.speedup_vs_scalar_st.total_cmp(&b.speedup_vs_scalar_st))
        {
            println!(
                "{kernel} f32 gains: {:.2}x vs scalar ST at {} thread(s)",
                best.speedup_vs_scalar_st, best.threads
            );
        }
    }
    Ok(())
}

fn cmd_obs_dump(m: &Matches) -> Result<()> {
    let n = m.usize("n")?;
    let d = m.usize("d")?;
    let service = Service::from_backend(m.str("backend")?)?;
    // a sharded loopback request walks the whole instrumented path:
    // api -> shard stages -> transport jobs -> wire frames -> kernel
    let req = SummarizeRequest::new(
        DatasetRef::synthetic(n, d, m.usize("seed")? as u64),
        m.usize("k")?,
    )
    .sharded(ShardSpec::new(m.usize("shards")?).transport("loopback"))
    .trace(true);
    let res = service.summarize(&req)?;
    println!(
        "obs-dump: traced {n}x{d} k={} sharded summarize, f(S) = {:.6}",
        res.k(),
        res.f_final
    );
    match &res.provenance.trace {
        Some(spans) => print!("\ntrace ({} spans):\n{}", spans.len(), obs::expo::render_trace(spans)),
        None => println!("\ntrace: (span recording disabled)"),
    }
    let snap = obs::global().registry.snapshot();
    print!("\nmetrics (Prometheus text):\n{}", obs::expo::render_text(&snap));
    println!("\nmetrics (JSON):\n{}", obs::expo::render_json(&snap).dump());
    Ok(())
}

fn cmd_devices(m: &Matches) -> Result<()> {
    let w = EbcWorkload {
        n: m.usize("n")?,
        l: m.usize("l")?,
        k: m.usize("k")?,
        d: m.usize("d")?,
    };
    println!("workload: N={} l={} k={} d={} ({:.2} GFLOP)", w.n, w.l, w.k, w.d, w.flops() / 1e9);
    println!("\npredicted runtimes:");
    for (dev, p) in [
        (&QUADRO_RTX_5000, ModelPrecision::Fp32),
        (&QUADRO_RTX_5000, ModelPrecision::Fp16),
        (&TX2, ModelPrecision::Fp32),
        (&TX2, ModelPrecision::Fp16),
        (&XEON_W2155, ModelPrecision::Fp32),
        (&A72, ModelPrecision::Fp32),
    ] {
        println!(
            "  {:<18} {:>5}: {:>10.4}s",
            dev.name,
            if p == ModelPrecision::Fp16 { "fp16" } else { "fp32" },
            predict_seconds(dev, &w, p)
        );
    }
    println!("\npredicted speedups (paper Table 1 shape):");
    println!(
        "  Quadro fp32 vs Xeon ST fp32: {:6.1}x (paper: 34-72x)",
        speedup(&QUADRO_RTX_5000, ModelPrecision::Fp32, &XEON_W2155, ModelPrecision::Fp32, &w)
    );
    println!(
        "  Quadro fp16 vs Xeon ST fp32: {:6.1}x (paper: 8.5-438x)",
        speedup(&QUADRO_RTX_5000, ModelPrecision::Fp16, &XEON_W2155, ModelPrecision::Fp32, &w)
    );
    println!(
        "  TX2    fp32 vs A72 ST fp32:  {:6.1}x (paper: 4.3-6x)",
        speedup(&TX2, ModelPrecision::Fp32, &A72, ModelPrecision::Fp32, &w)
    );
    println!(
        "  TX2    fp16 vs A72 ST fp32:  {:6.1}x (paper: 5.1-35.5x)",
        speedup(&TX2, ModelPrecision::Fp16, &A72, ModelPrecision::Fp32, &w)
    );
    Ok(())
}
