//! Informative Vector Machine (IVM) submodular function — the paper's §1
//! comparator: f(S) = ½ log det(I + σ⁻² K_SS) with an RBF Mercer kernel.
//!
//! The paper's point is that IVM is cheap to evaluate but its summary
//! quality hinges on a *tuned* kernel scale, while EBC is parameter-free;
//! the `ablation_ivm` bench quantifies exactly that sensitivity on the
//! IMM datasets. Implemented with a dense Cholesky (sets are small: k ≲
//! hundreds).

use crate::linalg::{sq_euclidean, Matrix};

/// RBF kernel k(x, y) = exp(−‖x−y‖² / (2 ℓ²)).
#[derive(Clone, Copy, Debug)]
pub struct RbfKernel {
    pub length_scale: f32,
}

impl RbfKernel {
    pub fn eval(&self, x: &[f32], y: &[f32]) -> f32 {
        let d2 = sq_euclidean(x, y);
        (-d2 / (2.0 * self.length_scale * self.length_scale)).exp()
    }
}

/// IVM function over a fixed ground set.
pub struct IvmFunction {
    v: Matrix,
    kernel: RbfKernel,
    sigma2_inv: f32,
}

impl IvmFunction {
    pub fn new(v: Matrix, length_scale: f32, sigma2: f32) -> IvmFunction {
        assert!(length_scale > 0.0 && sigma2 > 0.0);
        IvmFunction {
            v,
            kernel: RbfKernel { length_scale },
            sigma2_inv: 1.0 / sigma2,
        }
    }

    pub fn ground(&self) -> &Matrix {
        &self.v
    }

    /// f(S) = ½ log det(I + σ⁻² K_SS).
    pub fn eval(&self, set: &[usize]) -> f32 {
        let k = set.len();
        if k == 0 {
            return 0.0;
        }
        // Build M = I + σ⁻² K_SS (symmetric positive definite).
        let mut m = vec![0f64; k * k];
        for a in 0..k {
            for b in a..k {
                let kv = self.kernel.eval(self.v.row(set[a]), self.v.row(set[b])) as f64
                    * self.sigma2_inv as f64;
                let val = if a == b { 1.0 + kv } else { kv };
                m[a * k + b] = val;
                m[b * k + a] = val;
            }
        }
        // log det via Cholesky: det = Π L_ii², so log det = 2 Σ log L_ii.
        let l = cholesky(&m, k).expect("I + σ⁻²K is SPD");
        let logdet: f64 = (0..k).map(|i| l[i * k + i].ln()).sum::<f64>() * 2.0;
        (0.5 * logdet) as f32
    }
}

/// Dense Cholesky factorization (lower-triangular), row-major.
/// Returns None if the matrix is not positive definite.
pub fn cholesky(m: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(m.len(), n * n);
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = m[i * n + j];
            for p in 0..j {
                sum -= l[i * n + p] * l[j * n + p];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_reconstructs() {
        // A = L0 L0^T with a fixed L0
        let l0 = [2.0, 0.0, 0.0, 0.5, 1.5, 0.0, -0.3, 0.7, 1.1f64];
        let n = 3;
        let mut a = vec![0f64; 9];
        for i in 0..n {
            for j in 0..n {
                for p in 0..n {
                    a[i * n + j] += l0[i * n + p] * l0[j * n + p];
                }
            }
        }
        let l = cholesky(&a, n).unwrap();
        for i in 0..9 {
            assert!((l[i] - l0[i]).abs() < 1e-10, "{l:?}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn ivm_empty_zero_and_monotone() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(20, 4, &mut rng);
        let f = IvmFunction::new(v, 1.0, 1.0);
        assert_eq!(f.eval(&[]), 0.0);
        let v1 = f.eval(&[3]);
        let v2 = f.eval(&[3, 7]);
        let v3 = f.eval(&[3, 7, 11]);
        assert!(v1 > 0.0);
        assert!(v2 >= v1 - 1e-6);
        assert!(v3 >= v2 - 1e-6);
    }

    #[test]
    fn ivm_submodular_on_samples() {
        // Δ(e|A) >= Δ(e|B) for A ⊆ B, sampled
        let mut rng = Rng::new(2);
        let v = Matrix::random_normal(15, 3, &mut rng);
        let f = IvmFunction::new(v, 1.2, 0.5);
        for _ in 0..20 {
            let a: Vec<usize> = rng.sample_indices(15, 2);
            let mut b = a.clone();
            for extra in rng.sample_indices(15, 4) {
                if !b.contains(&extra) {
                    b.push(extra);
                }
            }
            let e = loop {
                let e = rng.below(15);
                if !b.contains(&e) {
                    break e;
                }
            };
            let da = f.eval(&[a.clone(), vec![e]].concat()) - f.eval(&a);
            let db = f.eval(&[b.clone(), vec![e]].concat()) - f.eval(&b);
            assert!(da >= db - 1e-5, "Δ(e|A)={da} < Δ(e|B)={db}");
        }
    }

    #[test]
    fn kernel_scale_changes_ranking_sensitivity() {
        // the paper's motivation: IVM values depend strongly on scale
        let mut rng = Rng::new(3);
        let v = Matrix::random_normal(10, 3, &mut rng);
        let tight = IvmFunction::new(v.clone(), 0.1, 1.0).eval(&[0, 1, 2]);
        let wide = IvmFunction::new(v, 10.0, 1.0).eval(&[0, 1, 2]);
        assert!((tight - wide).abs() > 0.1, "tight={tight} wide={wide}");
    }
}
