//! Exemplar-based clustering on the CPU — the paper's Algorithm 1
//! (single-threaded) and its set-parallel multi-threaded variant (§4.1),
//! both serving as the baselines of Fig. 2 / Table 1, plus the
//! mindist-incremental [`CpuOracle`] the optimizers use.

use crate::linalg::{sq_euclidean, sq_norms, Matrix};
use crate::submodular::Oracle;
use crate::util::threadpool::scoped_chunks;
use std::sync::atomic::{AtomicU64, Ordering};

/// The EBC function f(S) = L({e0}) − L(S ∪ {e0}) over a fixed ground set
/// (paper Definition 5), with e0 = 0 and d = squared Euclidean.
pub struct EbcFunction {
    v: Matrix,
    vsq: Vec<f32>,
    /// scalar distance-evaluation counter (ablation metric)
    work: AtomicU64,
}

impl EbcFunction {
    pub fn new(v: Matrix) -> EbcFunction {
        let vsq = sq_norms(v.data(), v.cols());
        EbcFunction { v, vsq, work: AtomicU64::new(0) }
    }

    pub fn ground(&self) -> &Matrix {
        &self.v
    }

    pub fn vsq(&self) -> &[f32] {
        &self.vsq
    }

    /// Paper Algorithm 1, verbatim structure: for every v_i take the min
    /// distance over S ∪ {e0}, average, and subtract from L({e0}).
    ///
    /// `set` holds row indices into the ground matrix.
    pub fn eval(&self, set: &[usize]) -> f32 {
        let n = self.v.rows();
        let mut acc = 0f64;
        for i in 0..n {
            let vi = self.v.row(i);
            let mut t = self.vsq[i]; // distance to e0
            for &s in set {
                let d = sq_euclidean(vi, self.v.row(s));
                if d < t {
                    t = d;
                }
            }
            acc += (self.vsq[i] - t) as f64;
        }
        self.work
            .fetch_add((n * set.len()) as u64, Ordering::Relaxed);
        (acc / n as f64) as f32
    }

    /// Evaluate f for sets whose members are *external* vectors (used by
    /// the streaming coordinator where candidates are not ground rows).
    pub fn eval_external(&self, set: &Matrix) -> f32 {
        assert_eq!(set.cols(), self.v.cols());
        let n = self.v.rows();
        let mut acc = 0f64;
        for i in 0..n {
            let vi = self.v.row(i);
            let mut t = self.vsq[i];
            for s in 0..set.rows() {
                let d = sq_euclidean(vi, set.row(s));
                if d < t {
                    t = d;
                }
            }
            acc += (self.vsq[i] - t) as f64;
        }
        (acc / n as f64) as f32
    }

    /// Single-threaded multi-set evaluation: Algorithm 1 looped over
    /// S_multi — the paper's ST baseline for Fig. 2.
    pub fn eval_sets_st(&self, sets: &[&[usize]]) -> Vec<f32> {
        sets.iter().map(|s| self.eval(s)).collect()
    }

    /// Multi-threaded multi-set evaluation: the outer loop over sets is
    /// distributed over a thread pool — the paper's MT baseline (§4.1,
    /// "runs the mentioned algorithm on different sets in parallel").
    pub fn eval_sets_mt(&self, sets: &[&[usize]], threads: usize) -> Vec<f32> {
        let mut out = vec![0f32; sets.len()];
        {
            let slots: Vec<std::sync::Mutex<&mut f32>> =
                out.iter_mut().map(std::sync::Mutex::new).collect();
            scoped_chunks(sets.len(), threads, |_, start, end| {
                for j in start..end {
                    let v = self.eval(sets[j]);
                    **slots[j].lock().unwrap() = v;
                }
            });
        }
        out
    }

    /// d²(v_i, v_j) for all i.
    pub fn dist_col(&self, j: usize) -> Vec<f32> {
        let vj = self.v.row(j);
        self.work
            .fetch_add(self.v.rows() as u64, Ordering::Relaxed);
        (0..self.v.rows())
            .map(|i| sq_euclidean(self.v.row(i), vj))
            .collect()
    }

    /// Batched marginal gains given the incremental state.
    pub fn gains(&self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        let n = self.v.rows() as f32;
        self.work
            .fetch_add((self.v.rows() * cands.len()) as u64, Ordering::Relaxed);
        cands
            .iter()
            .map(|&c| {
                let vc = self.v.row(c);
                let mut acc = 0f64;
                for i in 0..self.v.rows() {
                    let d = sq_euclidean(self.v.row(i), vc);
                    let r = mindist[i] - d;
                    if r > 0.0 {
                        acc += r as f64;
                    }
                }
                (acc / n as f64) as f32
            })
            .collect()
    }

    /// Multi-threaded gains (candidate-parallel).
    pub fn gains_mt(&self, mindist: &[f32], cands: &[usize], threads: usize) -> Vec<f32> {
        let mut out = vec![0f32; cands.len()];
        {
            let slots: Vec<std::sync::Mutex<&mut f32>> =
                out.iter_mut().map(std::sync::Mutex::new).collect();
            scoped_chunks(cands.len(), threads, |_, start, end| {
                let part = self.gains(mindist, &cands[start..end]);
                for (o, v) in (start..end).zip(part) {
                    **slots[o].lock().unwrap() = v;
                }
            });
        }
        out
    }

    pub fn work_counter(&self) -> u64 {
        self.work.load(Ordering::Relaxed)
    }
}

/// CPU-backed [`Oracle`]: single-threaded when `threads == 1`, else the
/// MT baseline.
pub struct CpuOracle {
    f: EbcFunction,
    threads: usize,
}

impl CpuOracle {
    pub fn new(v: Matrix) -> CpuOracle {
        CpuOracle { f: EbcFunction::new(v), threads: 1 }
    }

    pub fn new_mt(v: Matrix, threads: usize) -> CpuOracle {
        CpuOracle { f: EbcFunction::new(v), threads: threads.max(1) }
    }

    pub fn function(&self) -> &EbcFunction {
        &self.f
    }
}

impl Oracle for CpuOracle {
    fn n(&self) -> usize {
        self.f.ground().rows()
    }
    fn dim(&self) -> usize {
        self.f.ground().cols()
    }
    fn vsq(&self) -> &[f32] {
        self.f.vsq()
    }
    fn gains(&mut self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        if self.threads <= 1 {
            self.f.gains(mindist, cands)
        } else {
            self.f.gains_mt(mindist, cands, self.threads)
        }
    }
    fn dist_col(&mut self, j: usize) -> Vec<f32> {
        self.f.dist_col(j)
    }
    fn eval_sets(&mut self, sets: &[&[usize]]) -> Vec<f32> {
        if self.threads <= 1 {
            self.f.eval_sets_st(sets)
        } else {
            self.f.eval_sets_mt(sets, self.threads)
        }
    }
    fn work_counter(&self) -> u64 {
        self.f.work_counter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::{f_from_mindist, fold_mindist, initial_mindist};
    use crate::util::rng::Rng;

    fn toy() -> Matrix {
        // three well-separated clusters in 2D
        Matrix::from_rows(&[
            &[0.0, 0.0],
            &[0.1, 0.0],
            &[5.0, 5.0],
            &[5.1, 5.0],
            &[-4.0, 3.0],
            &[-4.0, 3.1],
        ])
    }

    #[test]
    fn empty_set_value_zero() {
        let f = EbcFunction::new(toy());
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn monotone_on_chain() {
        let f = EbcFunction::new(toy());
        let chain: [&[usize]; 4] = [&[], &[2], &[2, 4], &[2, 4, 0]];
        let vals: Vec<f32> = chain.iter().map(|s| f.eval(s)).collect();
        for w in vals.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "{vals:?}");
        }
    }

    #[test]
    fn duplicate_member_changes_nothing() {
        let f = EbcFunction::new(toy());
        assert!((f.eval(&[2, 4]) - f.eval(&[2, 4, 4])).abs() < 1e-6);
    }

    #[test]
    fn gains_match_direct_differences() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(40, 6, &mut rng);
        let f = EbcFunction::new(v);
        let base: Vec<usize> = vec![3, 17];
        let fs = f.eval(&base);
        // build mindist for the base set
        let mut mind = f.vsq().to_vec();
        for &s in &base {
            fold_mindist(&mut mind, &f.dist_col(s));
        }
        let cands = [0usize, 9, 25, 39];
        let g = f.gains(&mind, &cands);
        for (ci, &c) in cands.iter().enumerate() {
            let mut ext = base.clone();
            ext.push(c);
            let direct = f.eval(&ext) - fs;
            assert!(
                (g[ci] - direct).abs() < 1e-4,
                "cand {c}: gain {} vs direct {direct}",
                g[ci]
            );
        }
    }

    #[test]
    fn mt_matches_st() {
        let mut rng = Rng::new(2);
        let v = Matrix::random_normal(30, 5, &mut rng);
        let f = EbcFunction::new(v);
        let sets: Vec<Vec<usize>> = vec![vec![0, 5], vec![7], vec![], vec![1, 2, 3]];
        let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
        let st = f.eval_sets_st(&refs);
        let mt = f.eval_sets_mt(&refs, 4);
        for (a, b) in st.iter().zip(&mt) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn f_from_mindist_matches_eval() {
        let mut rng = Rng::new(3);
        let v = Matrix::random_normal(25, 4, &mut rng);
        let mut o = CpuOracle::new(v);
        let set = [4usize, 11, 20];
        let mut mind = initial_mindist(&o);
        for &s in &set {
            fold_mindist(&mut mind, &o.dist_col(s));
        }
        let via_state = f_from_mindist(o.vsq(), &mind);
        let direct = o.function().eval(&set);
        assert!((via_state - direct).abs() < 1e-5, "{via_state} vs {direct}");
    }

    #[test]
    fn eval_external_matches_internal_rows() {
        let v = toy();
        let f = EbcFunction::new(v.clone());
        let ext = v.gather(&[2, 4]);
        assert!((f.eval_external(&ext) - f.eval(&[2, 4])).abs() < 1e-6);
    }

    #[test]
    fn work_counter_increases() {
        let f = EbcFunction::new(toy());
        let w0 = f.work_counter();
        f.eval(&[1, 2]);
        assert!(f.work_counter() > w0);
    }
}
