//! Exemplar-based clustering on the CPU — the paper's Algorithm 1
//! (single-threaded) and its set-parallel multi-threaded variant (§4.1),
//! both serving as the baselines of Fig. 2 / Table 1, plus the
//! mindist-incremental [`CpuOracle`] the optimizers use.
//!
//! Every hot entry point (`gains`, `dist_col`, `eval*`) dispatches on a
//! [`CpuKernel`]: `Scalar` is the paper-faithful baseline; the
//! gemm family (`Blocked`, and `Simd` with explicit vector
//! micro-kernels — bit-identical, see [`crate::linalg::simd`]) routes
//! through the tiled Gram-matrix backend in [`crate::linalg::gemm`],
//! threading **ground-parallel** (over ground rows, not candidates) so
//! small candidate batches from `lazy_greedy`/the sieves still
//! saturate every core, with an optional bf16 input-demotion path
//! selected via [`Precision`].

use crate::linalg::gemm::{self, CpuKernel};
use crate::linalg::{sq_euclidean, sq_norms, Matrix, SharedMatrix};
use crate::obs;
use crate::runtime::artifact::Precision;
use crate::submodular::Oracle;
use crate::util::threadpool::scoped_chunks_mut;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

fn gains_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::GAINS_SECONDS, "per-call CPU-oracle gains latency (seconds)")
    })
}

/// The EBC function f(S) = L({e0}) − L(S ∪ {e0}) over a fixed ground set
/// (paper Definition 5), with e0 = 0 and d = squared Euclidean.
pub struct EbcFunction {
    v: SharedMatrix,
    vsq: Vec<f32>,
    /// bf16-demoted ground copy + its norms — present only on the
    /// blocked bf16 path (inputs demoted, accumulation stays f32).
    lp: Option<(Matrix, Vec<f32>)>,
    kernel: CpuKernel,
    precision: Precision,
    /// Ground-parallel worker count for the blocked kernel (>= 1).
    threads: usize,
    /// Per-ground-row charge weights + their (f64) sum — the weighted-eval
    /// seam of [`crate::prune`]: a pruned core's survivors stand in for
    /// the rows sieved onto them, so eval/gains average `w_i · (…)` over
    /// `Σw` instead of a unit weight over n. `None` (the default) keeps
    /// every path byte-for-byte on the legacy unweighted code.
    weights: Option<(Vec<f32>, f64)>,
    /// scalar distance-evaluation counter (ablation metric)
    work: AtomicU64,
}

impl EbcFunction {
    /// Scalar f32 single-threaded function — the paper's Algorithm 1.
    pub fn new(v: Matrix) -> EbcFunction {
        Self::with_kernel(v, CpuKernel::Scalar, Precision::F32, 1)
    }

    /// Backend-selectable constructor: `kernel` picks the scalar baseline
    /// or the blocked Gram-matrix path, `precision` the f32/bf16 axis
    /// (demotion applies to the blocked kernel only — the scalar path is
    /// the exact baseline), `threads` the ground-parallel width of the
    /// blocked kernels (0 = `default_threads()`).
    pub fn with_kernel(
        v: Matrix,
        kernel: CpuKernel,
        precision: Precision,
        threads: usize,
    ) -> EbcFunction {
        Self::with_kernel_shared(Arc::new(v), kernel, precision, threads)
    }

    /// Like [`Self::with_kernel`] but over a shared ground handle: the
    /// matrix is never copied, so the merge oracle, the baseline run and
    /// the engine's cached CPU fallback can all alias one dataset.
    pub fn with_kernel_shared(
        v: SharedMatrix,
        kernel: CpuKernel,
        precision: Precision,
        threads: usize,
    ) -> EbcFunction {
        let vsq = sq_norms(v.data(), v.cols());
        let lp = (kernel.uses_gemm() && precision == Precision::Bf16).then(|| {
            let m = Matrix::from_vec(v.rows(), v.cols(), gemm::demote_bf16_with(kernel, v.data()));
            let s = sq_norms(m.data(), m.cols());
            (m, s)
        });
        EbcFunction {
            v,
            vsq,
            lp,
            kernel,
            precision,
            threads: resolve_threads(threads),
            weights: None,
            work: AtomicU64::new(0),
        }
    }

    /// Attach per-row charge weights (see [`crate::prune::PrunedGround`]):
    /// every eval/gains entry point becomes the weighted objective
    /// `f_w(S) = Σ w_i (‖v_i‖² − mindist_i) / Σw`. All-ones weights are
    /// bit-identical to the unweighted function (an f32 multiply by 1.0
    /// is exact and the accumulation order is unchanged).
    ///
    /// # Panics
    /// If `w.len()` differs from the ground-set size.
    pub fn with_weights(mut self, w: Vec<f32>) -> EbcFunction {
        assert_eq!(w.len(), self.v.rows(), "one weight per ground row");
        let wsum: f64 = w.iter().map(|&x| x as f64).sum();
        self.weights = Some((w, wsum));
        self
    }

    /// The attached charge weights, if any.
    pub fn weights(&self) -> Option<&[f32]> {
        self.weights.as_ref().map(|(w, _)| w.as_slice())
    }

    /// f(S) from the incremental state — the weighted counterpart of
    /// [`crate::submodular::f_from_mindist`], identical to it when no
    /// weights are attached.
    pub fn f_of_state(&self, mindist: &[f32]) -> f32 {
        match &self.weights {
            None => crate::submodular::f_from_mindist(&self.vsq, mindist),
            Some((w, wsum)) => {
                debug_assert_eq!(mindist.len(), self.vsq.len());
                let mut acc = 0f64;
                for i in 0..self.vsq.len() {
                    acc += (w[i] * (self.vsq[i] - mindist[i])) as f64;
                }
                (acc / wsum) as f32
            }
        }
    }

    pub fn ground(&self) -> &Matrix {
        &self.v
    }

    pub fn vsq(&self) -> &[f32] {
        &self.vsq
    }

    pub fn kernel(&self) -> CpuKernel {
        self.kernel
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Effective (ground matrix, norms) the blocked kernels compute
    /// distances from: the bf16-demoted copy when present, else exact.
    fn eff(&self) -> (&Matrix, &[f32]) {
        match &self.lp {
            Some((m, s)) => (m, s),
            None => (&self.v, &self.vsq),
        }
    }

    /// Paper Algorithm 1, verbatim structure: for every v_i take the min
    /// distance over S ∪ {e0}, average, and subtract from L({e0}).
    ///
    /// `set` holds row indices into the ground matrix.
    pub fn eval(&self, set: &[usize]) -> f32 {
        match self.kernel {
            CpuKernel::Scalar => {
                let rows: Vec<&[f32]> = set.iter().map(|&s| self.v.row(s)).collect();
                self.eval_scalar(&rows)
            }
            CpuKernel::Blocked | CpuKernel::Simd => {
                let (vm, vs) = self.eff();
                let y = vm.gather(set);
                let vsq_y: Vec<f32> = set.iter().map(|&s| vs[s]).collect();
                self.eval_blocked(&y, &vsq_y)
            }
        }
    }

    /// Evaluate f for sets whose members are *external* vectors (used by
    /// the streaming coordinator where candidates are not ground rows).
    pub fn eval_external(&self, set: &Matrix) -> f32 {
        assert_eq!(set.cols(), self.v.cols());
        match self.kernel {
            CpuKernel::Scalar => {
                let rows: Vec<&[f32]> = (0..set.rows()).map(|s| set.row(s)).collect();
                self.eval_scalar(&rows)
            }
            CpuKernel::Blocked | CpuKernel::Simd if self.lp.is_some() => {
                let m = Matrix::from_vec(
                    set.rows(),
                    set.cols(),
                    gemm::demote_bf16_with(self.kernel, set.data()),
                );
                let vsq_y = sq_norms(m.data(), m.cols());
                self.eval_blocked(&m, &vsq_y)
            }
            CpuKernel::Blocked | CpuKernel::Simd => {
                self.eval_blocked(set, &sq_norms(set.data(), set.cols()))
            }
        }
    }

    /// The one scalar Algorithm-1 inner loop behind both [`Self::eval`]
    /// (members are ground rows) and [`Self::eval_external`] (members
    /// are arbitrary vectors): `rows` holds one slice per set member.
    /// Both entry points therefore count distance work identically.
    fn eval_scalar(&self, rows: &[&[f32]]) -> f32 {
        let n = self.v.rows();
        self.work.fetch_add((n * rows.len()) as u64, Ordering::Relaxed);
        match &self.weights {
            None => {
                let mut acc = 0f64;
                for i in 0..n {
                    let vi = self.v.row(i);
                    let mut t = self.vsq[i]; // distance to e0
                    for vs in rows {
                        let d = sq_euclidean(vi, vs);
                        if d < t {
                            t = d;
                        }
                    }
                    acc += (self.vsq[i] - t) as f64;
                }
                (acc / n as f64) as f32
            }
            Some((w, wsum)) => {
                let mut acc = 0f64;
                for i in 0..n {
                    let vi = self.v.row(i);
                    let mut t = self.vsq[i];
                    for vs in rows {
                        let d = sq_euclidean(vi, vs);
                        if d < t {
                            t = d;
                        }
                    }
                    acc += (w[i] * (self.vsq[i] - t)) as f64;
                }
                (acc / wsum) as f32
            }
        }
    }

    /// Blocked evaluation: per ground tile compute the distance block
    /// against the packed member matrix and min-reduce, ground-parallel
    /// over disjoint row ranges.
    fn eval_blocked(&self, y: &Matrix, vsq_y: &[f32]) -> f32 {
        let n = self.v.rows();
        let m = y.rows();
        self.work.fetch_add((n * m) as u64, Ordering::Relaxed);
        let (vm, vs) = self.eff();
        let sums = ground_partials(n, 1, self.threads, |r0, r1, part| {
            let mut acc = 0f64;
            for_ground_tiles(self.kernel, vm, vs, y.data(), vsq_y, r0, r1, |i, drow| {
                let mut t = self.vsq[i];
                for &dv in drow {
                    if dv < t {
                        t = dv;
                    }
                }
                match &self.weights {
                    None => acc += (self.vsq[i] - t) as f64,
                    Some((w, _)) => acc += (w[i] * (self.vsq[i] - t)) as f64,
                }
            });
            part[0] += acc;
        });
        let denom = match &self.weights {
            None => n as f64,
            Some((_, wsum)) => *wsum,
        };
        (sums[0] / denom) as f32
    }

    /// Single-threaded multi-set evaluation: Algorithm 1 looped over
    /// S_multi — with the scalar kernel this is the paper's ST baseline
    /// for Fig. 2; with the blocked kernel each set goes through the
    /// Gram-matrix path.
    pub fn eval_sets_st(&self, sets: &[&[usize]]) -> Vec<f32> {
        sets.iter().map(|s| self.eval(s)).collect()
    }

    /// Multi-threaded multi-set evaluation: with the scalar kernel the
    /// outer loop over sets is distributed over scoped threads writing
    /// disjoint output chunks — the paper's MT baseline (§4.1, "runs
    /// the mentioned algorithm on different sets in parallel"). The
    /// blocked kernel is already ground-parallel per set, so it runs
    /// the sets sequentially instead of nesting thread scopes.
    pub fn eval_sets_mt(&self, sets: &[&[usize]], threads: usize) -> Vec<f32> {
        if self.kernel.uses_gemm() {
            return self.eval_sets_st(sets);
        }
        let mut out = vec![0f32; sets.len()];
        scoped_chunks_mut(&mut out, threads, |_, start, slice| {
            for (off, slot) in slice.iter_mut().enumerate() {
                *slot = self.eval(sets[start + off]);
            }
        });
        out
    }

    /// d²(v_i, v_j) for all i.
    pub fn dist_col(&self, j: usize) -> Vec<f32> {
        let n = self.v.rows();
        self.work.fetch_add(n as u64, Ordering::Relaxed);
        match self.kernel {
            CpuKernel::Scalar => {
                let vj = self.v.row(j);
                (0..n).map(|i| sq_euclidean(self.v.row(i), vj)).collect()
            }
            CpuKernel::Blocked | CpuKernel::Simd => {
                let (vm, vs) = self.eff();
                let vj = vm.row(j).to_vec();
                let vsj = vs[j];
                self.dist_col_blocked(&vj, vsj)
            }
        }
    }

    /// The blocked distance-column loop over an already-demoted probe
    /// vector — shared by [`Self::dist_col`] and
    /// [`Self::dist_col_external`].
    fn dist_col_blocked(&self, vj: &[f32], vsj: f32) -> Vec<f32> {
        let n = self.v.rows();
        let (vm, vs) = self.eff();
        let d = vm.cols();
        let vsj = [vsj];
        let mut out = vec![0f32; n];
        scoped_chunks_mut(&mut out, self.threads, |_, start, slice| {
            gemm::sq_dist_block_with(
                self.kernel,
                &vm.data()[start * d..(start + slice.len()) * d],
                &vs[start..start + slice.len()],
                vj,
                &vsj,
                d,
                slice.len(),
                1,
                slice,
            );
        });
        out
    }

    /// Batched marginal gains given the incremental state.
    pub fn gains(&self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        match self.kernel {
            CpuKernel::Scalar => self.gains_scalar(mindist, cands),
            CpuKernel::Blocked | CpuKernel::Simd => self.gains_blocked(mindist, cands),
        }
    }

    fn gains_scalar(&self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        let n = self.v.rows() as f32;
        self.work
            .fetch_add((self.v.rows() * cands.len()) as u64, Ordering::Relaxed);
        match &self.weights {
            None => cands
                .iter()
                .map(|&c| {
                    let vc = self.v.row(c);
                    let mut acc = 0f64;
                    for i in 0..self.v.rows() {
                        let d = sq_euclidean(self.v.row(i), vc);
                        let r = mindist[i] - d;
                        if r > 0.0 {
                            acc += r as f64;
                        }
                    }
                    (acc / n as f64) as f32
                })
                .collect(),
            Some((w, wsum)) => cands
                .iter()
                .map(|&c| {
                    let vc = self.v.row(c);
                    let mut acc = 0f64;
                    for i in 0..self.v.rows() {
                        let d = sq_euclidean(self.v.row(i), vc);
                        let r = mindist[i] - d;
                        if r > 0.0 {
                            acc += (w[i] * r) as f64;
                        }
                    }
                    (acc / wsum) as f32
                })
                .collect(),
        }
    }

    /// Blocked gains: one Gram-matrix distance block per ground tile,
    /// the clamped `mindist − D` reduction accumulated into per-thread
    /// f64 partials over disjoint ground-row ranges (ground-parallel —
    /// a C=1 candidate batch still uses every worker).
    fn gains_blocked(&self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        let c = cands.len();
        self.work.fetch_add((self.v.rows() * c) as u64, Ordering::Relaxed);
        if c == 0 {
            return vec![];
        }
        let (vm, vs) = self.eff();
        let y = vm.gather(cands);
        let vsq_y: Vec<f32> = cands.iter().map(|&j| vs[j]).collect();
        self.gains_blocked_rows(mindist, y.data(), &vsq_y)
    }

    /// The blocked-gains reduction over an already-packed candidate
    /// matrix `y` — shared by the index path ([`Self::gains_blocked`])
    /// and the external-vector path ([`Self::gains_external`]).
    fn gains_blocked_rows(&self, mindist: &[f32], y: &[f32], vsq_y: &[f32]) -> Vec<f32> {
        let n = self.v.rows();
        let (vm, vs) = self.eff();
        let sums = ground_partials(n, vsq_y.len(), self.threads, |r0, r1, part| {
            for_ground_tiles(self.kernel, vm, vs, y, vsq_y, r0, r1, |i, drow| {
                let md = mindist[i];
                match &self.weights {
                    None => {
                        for (p, &dv) in part.iter_mut().zip(drow) {
                            let r = md - dv;
                            if r > 0.0 {
                                *p += r as f64;
                            }
                        }
                    }
                    Some((w, _)) => {
                        let wi = w[i];
                        for (p, &dv) in part.iter_mut().zip(drow) {
                            let r = md - dv;
                            if r > 0.0 {
                                *p += (wi * r) as f64;
                            }
                        }
                    }
                }
            });
        });
        let nf = match &self.weights {
            None => n as f64,
            Some((_, wsum)) => *wsum,
        };
        sums.iter().map(|&s| (s / nf) as f32).collect()
    }

    /// Batched marginal gains for **external** candidate vectors (rows of
    /// `cands` need not be ground rows) — the CPU mirror of the engine's
    /// `gains` graph, used by its fallback path. Matches [`Self::gains`]
    /// exactly when the rows are gathered ground rows.
    pub fn gains_external(&self, mindist: &[f32], cands: &Matrix) -> Vec<f32> {
        assert_eq!(cands.cols(), self.v.cols());
        let n = self.v.rows();
        let c = cands.rows();
        self.work.fetch_add((n * c) as u64, Ordering::Relaxed);
        if c == 0 {
            return vec![];
        }
        match self.kernel {
            CpuKernel::Scalar => {
                let nf = match &self.weights {
                    None => n as f64,
                    Some((_, wsum)) => *wsum,
                };
                (0..c)
                    .map(|j| {
                        let vc = cands.row(j);
                        let mut acc = 0f64;
                        for i in 0..n {
                            let r = mindist[i] - sq_euclidean(self.v.row(i), vc);
                            if r > 0.0 {
                                match &self.weights {
                                    None => acc += r as f64,
                                    Some((w, _)) => acc += (w[i] * r) as f64,
                                }
                            }
                        }
                        (acc / nf) as f32
                    })
                    .collect()
            }
            CpuKernel::Blocked | CpuKernel::Simd if self.lp.is_some() => {
                let y = gemm::demote_bf16_with(self.kernel, cands.data());
                let vsq_y = sq_norms(&y, cands.cols());
                self.gains_blocked_rows(mindist, &y, &vsq_y)
            }
            CpuKernel::Blocked | CpuKernel::Simd => {
                let vsq_y = sq_norms(cands.data(), cands.cols());
                self.gains_blocked_rows(mindist, cands.data(), &vsq_y)
            }
        }
    }

    /// d²(v_i, s) for an **external** vector `s` — the CPU mirror of the
    /// engine's dist-column/update graph, used by its fallback path.
    pub fn dist_col_external(&self, s: &[f32]) -> Vec<f32> {
        assert_eq!(s.len(), self.v.cols());
        let n = self.v.rows();
        self.work.fetch_add(n as u64, Ordering::Relaxed);
        match self.kernel {
            CpuKernel::Scalar => (0..n).map(|i| sq_euclidean(self.v.row(i), s)).collect(),
            CpuKernel::Blocked | CpuKernel::Simd => {
                let sv: Vec<f32> = if self.lp.is_some() {
                    gemm::demote_bf16_with(self.kernel, s)
                } else {
                    s.to_vec()
                };
                let ssq = sq_norms(&sv, sv.len());
                self.dist_col_blocked(&sv, ssq[0])
            }
        }
    }

    /// Multi-threaded **candidate-parallel** gains over the scalar
    /// kernel — the paper's MT baseline. On a blocked-kernel function
    /// this delegates to the ground-parallel blocked path (which uses
    /// the constructor's thread width), so every entry point on one
    /// object computes with the same kernel and precision.
    pub fn gains_mt(&self, mindist: &[f32], cands: &[usize], threads: usize) -> Vec<f32> {
        if self.kernel.uses_gemm() {
            return self.gains_blocked(mindist, cands);
        }
        let mut out = vec![0f32; cands.len()];
        scoped_chunks_mut(&mut out, threads, |_, start, slice| {
            let part = self.gains_scalar(mindist, &cands[start..start + slice.len()]);
            slice.copy_from_slice(&part);
        });
        out
    }

    pub fn work_counter(&self) -> u64 {
        self.work.load(Ordering::Relaxed)
    }
}

/// 0 = auto (`default_threads()`), else at least 1 — the one resolution
/// every kernel-seam constructor shares.
fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        crate::util::threadpool::default_threads()
    } else {
        threads
    }
}

/// The one blocked tile loop behind both the blocked eval (min-reduce)
/// and gains (sum-reduce): over ground rows [r0, r1), compute the
/// clamped squared-distance block of each [`gemm::tile_rows`]-high tile
/// against the packed member matrix `y` — through the caller's
/// gemm-family `kernel` — and hand each row to
/// `row_fn(global_row_index, distance_row)`.
#[allow(clippy::too_many_arguments)]
fn for_ground_tiles(
    kernel: CpuKernel,
    vm: &Matrix,
    vs: &[f32],
    y: &[f32],
    vsq_y: &[f32],
    r0: usize,
    r1: usize,
    mut row_fn: impl FnMut(usize, &[f32]),
) {
    let d = vm.cols();
    let c = vsq_y.len();
    let tile = gemm::tile_rows(c);
    let mut dbuf = vec![0f32; tile * c];
    let mut i0 = r0;
    while i0 < r1 {
        let i1 = (i0 + tile).min(r1);
        let rows = i1 - i0;
        gemm::sq_dist_block_with(
            kernel,
            &vm.data()[i0 * d..i1 * d],
            &vs[i0..i1],
            y,
            vsq_y,
            d,
            rows,
            c,
            &mut dbuf[..rows * c],
        );
        for ii in 0..rows {
            row_fn(i0 + ii, &dbuf[ii * c..(ii + 1) * c]);
        }
        i0 = i1;
    }
}

/// Run `f(start, end, partial)` over disjoint ground-row ranges on
/// scoped threads, one zeroed f64 partial buffer (`plen` wide) per
/// thread — no shared slots, no locks — then sum the partials in thread
/// order (deterministic for a fixed thread count).
fn ground_partials(
    n: usize,
    plen: usize,
    threads: usize,
    f: impl Fn(usize, usize, &mut [f64]) + Sync,
) -> Vec<f64> {
    if plen == 0 {
        return vec![];
    }
    let t = threads.max(1).min(n.max(1));
    if t == 1 {
        let mut part = vec![0f64; plen];
        if n > 0 {
            f(0, n, &mut part);
        }
        return part;
    }
    let rows = n.div_ceil(t);
    let mut partials = vec![0f64; t * plen];
    std::thread::scope(|scope| {
        for (ti, part) in partials.chunks_mut(plen).enumerate() {
            let start = ti * rows;
            let end = ((ti + 1) * rows).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end, part));
        }
    });
    let mut out = vec![0f64; plen];
    for chunk in partials.chunks(plen) {
        for (o, p) in out.iter_mut().zip(chunk) {
            *o += p;
        }
    }
    out
}

/// CPU-backed [`Oracle`]. With the scalar kernel: single-threaded when
/// `threads == 1`, else the candidate-/set-parallel MT baseline. With
/// the blocked kernel: the Gram-matrix backend, ground-parallel over
/// `threads` workers regardless of batch size.
pub struct CpuOracle {
    f: EbcFunction,
    threads: usize,
}

impl CpuOracle {
    pub fn new(v: Matrix) -> CpuOracle {
        CpuOracle { f: EbcFunction::new(v), threads: 1 }
    }

    /// Scalar single-threaded oracle over a shared ground handle (no
    /// matrix copy).
    pub fn new_shared(v: SharedMatrix) -> CpuOracle {
        Self::with_kernel_shared(v, CpuKernel::Scalar, Precision::F32, 1)
    }

    pub fn new_mt(v: Matrix, threads: usize) -> CpuOracle {
        CpuOracle { f: EbcFunction::new(v), threads: threads.max(1) }
    }

    /// The `CpuKernel` backend seam: one constructor the config layer,
    /// the CLI, the shard workers and the coordinator all build through.
    /// `threads == 0` resolves to `default_threads()`.
    pub fn with_kernel(
        v: Matrix,
        kernel: CpuKernel,
        precision: Precision,
        threads: usize,
    ) -> CpuOracle {
        Self::with_kernel_shared(Arc::new(v), kernel, precision, threads)
    }

    /// [`Self::with_kernel`] over a shared ground handle.
    pub fn with_kernel_shared(
        v: SharedMatrix,
        kernel: CpuKernel,
        precision: Precision,
        threads: usize,
    ) -> CpuOracle {
        let threads = resolve_threads(threads);
        CpuOracle { f: EbcFunction::with_kernel_shared(v, kernel, precision, threads), threads }
    }

    pub fn function(&self) -> &EbcFunction {
        &self.f
    }

    /// Attach [`crate::prune`] charge weights — see
    /// [`EbcFunction::with_weights`]. All-ones weights keep the oracle
    /// bit-identical to the unweighted one.
    pub fn with_weights(mut self, w: Vec<f32>) -> CpuOracle {
        self.f = self.f.with_weights(w);
        self
    }
}

impl Oracle for CpuOracle {
    fn n(&self) -> usize {
        self.f.ground().rows()
    }
    fn dim(&self) -> usize {
        self.f.ground().cols()
    }
    fn vsq(&self) -> &[f32] {
        self.f.vsq()
    }
    fn gains(&mut self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        let _span = obs::span("kernel.gains");
        gains_hist().time(|| match self.f.kernel() {
            CpuKernel::Scalar if self.threads > 1 => self.f.gains_mt(mindist, cands, self.threads),
            _ => self.f.gains(mindist, cands),
        })
    }
    fn dist_col(&mut self, j: usize) -> Vec<f32> {
        self.f.dist_col(j)
    }
    fn eval_sets(&mut self, sets: &[&[usize]]) -> Vec<f32> {
        match self.f.kernel() {
            CpuKernel::Scalar if self.threads > 1 => self.f.eval_sets_mt(sets, self.threads),
            _ => self.f.eval_sets_st(sets),
        }
    }
    fn work_counter(&self) -> u64 {
        self.f.work_counter()
    }
    fn f_of_state(&self, mindist: &[f32]) -> f32 {
        self.f.f_of_state(mindist)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::{f_from_mindist, fold_mindist, initial_mindist};
    use crate::util::rng::Rng;

    fn toy() -> Matrix {
        // three well-separated clusters in 2D
        Matrix::from_rows(&[
            &[0.0, 0.0],
            &[0.1, 0.0],
            &[5.0, 5.0],
            &[5.1, 5.0],
            &[-4.0, 3.0],
            &[-4.0, 3.1],
        ])
    }

    fn blocked(v: Matrix, threads: usize) -> EbcFunction {
        EbcFunction::with_kernel(v, CpuKernel::Blocked, Precision::F32, threads)
    }

    #[test]
    fn empty_set_value_zero() {
        let f = EbcFunction::new(toy());
        assert_eq!(f.eval(&[]), 0.0);
        let b = blocked(toy(), 2);
        assert_eq!(b.eval(&[]), 0.0);
    }

    #[test]
    fn monotone_on_chain() {
        let f = EbcFunction::new(toy());
        let chain: [&[usize]; 4] = [&[], &[2], &[2, 4], &[2, 4, 0]];
        let vals: Vec<f32> = chain.iter().map(|s| f.eval(s)).collect();
        for w in vals.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "{vals:?}");
        }
    }

    #[test]
    fn duplicate_member_changes_nothing() {
        let f = EbcFunction::new(toy());
        assert!((f.eval(&[2, 4]) - f.eval(&[2, 4, 4])).abs() < 1e-6);
    }

    #[test]
    fn gains_match_direct_differences() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(40, 6, &mut rng);
        let f = EbcFunction::new(v);
        let base: Vec<usize> = vec![3, 17];
        let fs = f.eval(&base);
        // build mindist for the base set
        let mut mind = f.vsq().to_vec();
        for &s in &base {
            fold_mindist(&mut mind, &f.dist_col(s));
        }
        let cands = [0usize, 9, 25, 39];
        let g = f.gains(&mind, &cands);
        for (ci, &c) in cands.iter().enumerate() {
            let mut ext = base.clone();
            ext.push(c);
            let direct = f.eval(&ext) - fs;
            assert!(
                (g[ci] - direct).abs() < 1e-4,
                "cand {c}: gain {} vs direct {direct}",
                g[ci]
            );
        }
    }

    #[test]
    fn mt_matches_st() {
        let mut rng = Rng::new(2);
        let v = Matrix::random_normal(30, 5, &mut rng);
        let f = EbcFunction::new(v);
        let sets: Vec<Vec<usize>> = vec![vec![0, 5], vec![7], vec![], vec![1, 2, 3]];
        let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
        let st = f.eval_sets_st(&refs);
        let mt = f.eval_sets_mt(&refs, 4);
        for (a, b) in st.iter().zip(&mt) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn f_from_mindist_matches_eval() {
        let mut rng = Rng::new(3);
        let v = Matrix::random_normal(25, 4, &mut rng);
        let mut o = CpuOracle::new(v);
        let set = [4usize, 11, 20];
        let mut mind = initial_mindist(&o);
        for &s in &set {
            fold_mindist(&mut mind, &o.dist_col(s));
        }
        let via_state = f_from_mindist(o.vsq(), &mind);
        let direct = o.function().eval(&set);
        assert!((via_state - direct).abs() < 1e-5, "{via_state} vs {direct}");
    }

    #[test]
    fn eval_external_matches_internal_rows() {
        let v = toy();
        let f = EbcFunction::new(v.clone());
        let ext = v.gather(&[2, 4]);
        assert!((f.eval_external(&ext) - f.eval(&[2, 4])).abs() < 1e-6);
        let b = blocked(v.clone(), 2);
        assert!((b.eval_external(&ext) - b.eval(&[2, 4])).abs() < 1e-6);
    }

    #[test]
    fn eval_external_counts_work() {
        let f = EbcFunction::new(toy());
        let w0 = f.work_counter();
        f.eval_external(&toy().gather(&[1, 3]));
        assert_eq!(f.work_counter() - w0, 2 * 6);
    }

    #[test]
    fn work_counter_increases() {
        let f = EbcFunction::new(toy());
        let w0 = f.work_counter();
        f.eval(&[1, 2]);
        assert!(f.work_counter() > w0);
    }

    #[test]
    fn blocked_matches_scalar_all_entry_points() {
        let mut rng = Rng::new(7);
        let v = Matrix::random_normal(45, 11, &mut rng); // d not divisible by 8
        let scalar = EbcFunction::new(v.clone());
        for threads in [1usize, 3] {
            let b = blocked(v.clone(), threads);
            // eval
            let sets: [&[usize]; 3] = [&[], &[0], &[4, 19, 33]];
            for set in sets {
                let (s, g) = (scalar.eval(set), b.eval(set));
                assert!((s - g).abs() <= 1e-4 * (1.0 + s.abs()), "eval {set:?}: {s} vs {g}");
            }
            // dist_col
            let (ds, db) = (scalar.dist_col(9), b.dist_col(9));
            for (i, (a, bb)) in ds.iter().zip(&db).enumerate() {
                assert!((a - bb).abs() <= 1e-3 * (1.0 + a), "dist_col[{i}]: {a} vs {bb}");
            }
            // gains on a non-trivial mindist state
            let mut mind = scalar.vsq().to_vec();
            fold_mindist(&mut mind, &scalar.dist_col(7));
            let cands: Vec<usize> = vec![0, 3, 12, 30, 44];
            let (gs, gb) = (scalar.gains(&mind, &cands), b.gains(&mind, &cands));
            for (i, (a, bb)) in gs.iter().zip(&gb).enumerate() {
                assert!((a - bb).abs() <= 1e-4 * (1.0 + a.abs()), "gains[{i}]: {a} vs {bb}");
            }
        }
    }

    #[test]
    fn blocked_single_row_ground() {
        let v = Matrix::from_rows(&[&[1.0, 2.0, 3.0]]);
        let b = blocked(v.clone(), 4);
        let s = EbcFunction::new(v);
        assert!(b.gains(s.vsq(), &[]).is_empty());
        assert!((b.eval(&[0]) - s.eval(&[0])).abs() < 1e-5);
        assert!(b.dist_col(0)[0] < 1e-5);
    }

    #[test]
    fn bf16_demotes_inputs_but_stays_close() {
        let mut rng = Rng::new(9);
        let v = Matrix::random_normal(30, 7, &mut rng);
        let exact = EbcFunction::new(v.clone());
        let lp = EbcFunction::with_kernel(v, CpuKernel::Blocked, Precision::Bf16, 2);
        assert_eq!(lp.precision(), Precision::Bf16);
        let set = [2usize, 11, 25];
        let (a, b) = (exact.eval(&set), lp.eval(&set));
        // documented looser bound: bf16 keeps 8 significand bits, so
        // distance terms carry ~2^-8 relative input error
        let vmax = exact.vsq().iter().cloned().fold(0f32, f32::max);
        assert!((a - b).abs() <= 0.05 * (1.0 + a.abs()) + 0.02 * vmax, "{a} vs {b}");
    }

    #[test]
    fn external_gains_and_dist_col_match_index_paths() {
        let mut rng = Rng::new(21);
        let v = Matrix::random_normal(35, 9, &mut rng);
        let cands = [0usize, 4, 17, 34];
        let gathered = v.gather(&cands);
        let probe = 11usize;
        for (kernel, precision, threads) in [
            (CpuKernel::Scalar, Precision::F32, 1usize),
            (CpuKernel::Blocked, Precision::F32, 3),
            (CpuKernel::Blocked, Precision::Bf16, 2),
            (CpuKernel::Simd, Precision::F32, 3),
            (CpuKernel::Simd, Precision::Bf16, 2),
        ] {
            let f = EbcFunction::with_kernel(v.clone(), kernel, precision, threads);
            let mut mind = f.vsq().to_vec();
            fold_mindist(&mut mind, &f.dist_col(2));
            let by_index = f.gains(&mind, &cands);
            let by_rows = f.gains_external(&mind, &gathered);
            for (i, (a, b)) in by_index.iter().zip(&by_rows).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5 * (1.0 + a.abs()),
                    "{kernel:?}/{precision:?} gains[{i}]: {a} vs {b}"
                );
            }
            let dc = f.dist_col(probe);
            let de = f.dist_col_external(v.row(probe));
            for (i, (a, b)) in dc.iter().zip(&de).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5 * (1.0 + a.abs()),
                    "{kernel:?}/{precision:?} dist_col[{i}]: {a} vs {b}"
                );
            }
            assert!(f.gains_external(&mind, &Matrix::zeros(0, 9)).is_empty());
        }
    }

    #[test]
    fn simd_matches_blocked_bitwise_all_entry_points() {
        let mut rng = Rng::new(31);
        // n=1-adjacent small dims plus d not a multiple of the 8-lane
        // width: the simd kernel must agree with blocked to the bit on
        // both precisions (shared accumulation order, no FMA)
        for (n, d) in [(1usize, 3usize), (45, 11), (33, 16)] {
            let v = Matrix::random_normal(n, d, &mut rng);
            for precision in [Precision::F32, Precision::Bf16] {
                for threads in [1usize, 3] {
                    let b = EbcFunction::with_kernel(v.clone(), CpuKernel::Blocked, precision, threads);
                    let s = EbcFunction::with_kernel(v.clone(), CpuKernel::Simd, precision, threads);
                    let set: Vec<usize> = (0..n).step_by(7).collect();
                    assert_eq!(b.eval(&set).to_bits(), s.eval(&set).to_bits());
                    let probe = n / 2;
                    for (a, bb) in b.dist_col(probe).iter().zip(&s.dist_col(probe)) {
                        assert_eq!(a.to_bits(), bb.to_bits());
                    }
                    let mut mind = b.vsq().to_vec();
                    fold_mindist(&mut mind, &b.dist_col(probe));
                    let cands: Vec<usize> = (0..n).step_by(3).collect();
                    for (a, bb) in
                        b.gains(&mind, &cands).iter().zip(&s.gains(&mind, &cands))
                    {
                        assert_eq!(a.to_bits(), bb.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn shared_handle_aliases_one_ground_matrix() {
        let v = Arc::new(toy());
        let a = EbcFunction::with_kernel_shared(
            Arc::clone(&v),
            CpuKernel::Scalar,
            Precision::F32,
            1,
        );
        let b = CpuOracle::new_shared(Arc::clone(&v));
        assert!(std::ptr::eq(a.ground(), v.as_ref()));
        assert!(std::ptr::eq(b.function().ground(), v.as_ref()));
        assert_eq!(a.eval(&[2]), b.function().eval(&[2]));
    }

    #[test]
    fn all_ones_weights_bit_identical_every_entry_point() {
        let mut rng = Rng::new(41);
        let v = Matrix::random_normal(37, 6, &mut rng);
        for (kernel, threads) in
            [(CpuKernel::Scalar, 1usize), (CpuKernel::Blocked, 1), (CpuKernel::Blocked, 3)]
        {
            let plain = EbcFunction::with_kernel(v.clone(), kernel, Precision::F32, threads);
            let ones = EbcFunction::with_kernel(v.clone(), kernel, Precision::F32, threads)
                .with_weights(vec![1.0; 37]);
            let set = [3usize, 12, 30];
            assert_eq!(plain.eval(&set).to_bits(), ones.eval(&set).to_bits(), "{kernel:?}");
            let mut mind = plain.vsq().to_vec();
            fold_mindist(&mut mind, &plain.dist_col(5));
            let cands = [0usize, 7, 19, 36];
            for (a, b) in plain.gains(&mind, &cands).iter().zip(&ones.gains(&mind, &cands)) {
                assert_eq!(a.to_bits(), b.to_bits(), "{kernel:?}");
            }
            let ext = v.gather(&cands);
            for (a, b) in
                plain.gains_external(&mind, &ext).iter().zip(&ones.gains_external(&mind, &ext))
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{kernel:?}");
            }
            assert_eq!(
                plain.f_of_state(&mind).to_bits(),
                ones.f_of_state(&mind).to_bits(),
                "{kernel:?}"
            );
        }
    }

    #[test]
    fn weighted_eval_matches_row_duplication() {
        // weight w on a row ≡ that row appearing w times in the ground
        let base = Matrix::from_rows(&[&[0.0f32, 0.0], &[4.0, 0.0], &[0.0, 4.0]]);
        let dup = Matrix::from_rows(&[
            &[0.0f32, 0.0],
            &[4.0, 0.0],
            &[4.0, 0.0],
            &[4.0, 0.0],
            &[0.0, 4.0],
        ]);
        let w = EbcFunction::new(base).with_weights(vec![1.0, 3.0, 1.0]);
        let d = EbcFunction::new(dup);
        assert!((w.eval(&[1]) - d.eval(&[1])).abs() < 1e-6);
        let mut mw = w.vsq().to_vec();
        fold_mindist(&mut mw, &w.dist_col(1));
        let mut md = d.vsq().to_vec();
        fold_mindist(&mut md, &d.dist_col(1));
        let gw = w.gains(&mw, &[0, 2]);
        let gd = d.gains(&md, &[0, 4]);
        for (a, b) in gw.iter().zip(&gd) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((w.f_of_state(&mw) - d.f_of_state(&md)).abs() < 1e-6);
    }

    #[test]
    fn oracle_with_kernel_runs_greedy_path() {
        let mut rng = Rng::new(12);
        let v = Matrix::random_normal(25, 4, &mut rng);
        let mut o = CpuOracle::with_kernel(v, CpuKernel::Blocked, Precision::F32, 2);
        let mut mind = initial_mindist(&o);
        let g = o.gains(&mind, &[0, 5, 9]);
        assert_eq!(g.len(), 3);
        fold_mindist(&mut mind, &o.dist_col(5));
        let vals = o.eval_sets(&[&[5], &[]]);
        assert!(vals[0] >= vals[1]);
    }
}
