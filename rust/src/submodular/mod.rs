//! Submodular functions (paper §3–4): the Exemplar-based-clustering
//! function with its CPU evaluators (Algorithm 1, single- and
//! multi-threaded — the paper's baselines, plus the blocked Gram-matrix
//! backend selected via [`crate::linalg::CpuKernel`]), the IVM
//! comparator, and the [`Oracle`] abstraction every optimizer in
//! [`crate::optim`] runs against. The accelerated implementation of the
//! same trait lives in [`crate::engine`].

pub mod ebc;
pub mod ivm;

pub use crate::linalg::gemm::CpuKernel;
pub use ebc::{CpuOracle, EbcFunction};

/// Evaluation interface between datasets and optimizers.
///
/// A summary is a set of *indices into the ground set*. Optimizer state
/// is carried by `mindist` (min squared distance of every ground vector
/// to the current summary ∪ {e0}; initialized to [`Oracle::vsq`]), which
/// makes the greedy/streaming marginal-gain pattern O(N·C) per step
/// instead of O(N·k·C) — on both CPU and the accelerator.
pub trait Oracle {
    /// Ground-set size.
    fn n(&self) -> usize;
    /// Feature dimensionality.
    fn dim(&self) -> usize;
    /// ‖v_i‖² per ground vector == d²(v_i, e0) (EBC's auxiliary exemplar).
    fn vsq(&self) -> &[f32];

    /// Marginal gains Δf(c | S) for candidate indices, given the state.
    fn gains(&mut self, mindist: &[f32], cands: &[usize]) -> Vec<f32>;

    /// d²(v_i, v_j) for every i — used to fold a selection into `mindist`.
    fn dist_col(&mut self, j: usize) -> Vec<f32>;

    /// Work-matrix evaluation of arbitrary sets (paper Algorithm 2):
    /// EBC value f(S_j) for each set of ground indices.
    fn eval_sets(&mut self, sets: &[&[usize]]) -> Vec<f32>;

    /// Number of scalar distance evaluations performed so far (for the
    /// call-count ablations); implementations may approximate.
    fn work_counter(&self) -> u64 {
        0
    }

    /// f(S) from the incremental state. The default is exactly
    /// [`f_from_mindist`]; weighted oracles (a [`crate::prune`] core's
    /// charge weights) override it so trajectories stay unbiased
    /// estimates of the full-ground objective.
    fn f_of_state(&self, mindist: &[f32]) -> f32 {
        f_from_mindist(self.vsq(), mindist)
    }
}

/// Fresh mindist state (distance to e0 only — the empty summary).
pub fn initial_mindist(oracle: &dyn Oracle) -> Vec<f32> {
    oracle.vsq().to_vec()
}

/// f(S) given the current state: mean(vsq) − mean(mindist).
pub fn f_from_mindist(vsq: &[f32], mindist: &[f32]) -> f32 {
    debug_assert_eq!(vsq.len(), mindist.len());
    let n = vsq.len() as f32;
    let mut acc = 0f64;
    for i in 0..vsq.len() {
        acc += (vsq[i] - mindist[i]) as f64;
    }
    (acc / n as f64) as f32
}

/// Fold a selected column into the state: mindist ← min(mindist, dcol).
pub fn fold_mindist(mindist: &mut [f32], dcol: &[f32]) {
    debug_assert_eq!(mindist.len(), dcol.len());
    for i in 0..mindist.len() {
        if dcol[i] < mindist[i] {
            mindist[i] = dcol[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f_from_mindist_zero_for_empty() {
        let vsq = vec![1.0, 2.0, 3.0];
        assert_eq!(f_from_mindist(&vsq, &vsq), 0.0);
    }

    #[test]
    fn fold_takes_elementwise_min() {
        let mut m = vec![3.0, 1.0, 2.0];
        fold_mindist(&mut m, &[2.0, 5.0, 2.0]);
        assert_eq!(m, vec![2.0, 1.0, 2.0]);
    }
}
