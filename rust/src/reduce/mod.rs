//! Dimensionality reduction for industrial process data — the paper's
//! §7 future work: *"it may be interesting to see, which dimensionality
//! reduction techniques are appropriate for industrial process control,
//! to reduce optimization times and to provide summaries even faster."*
//!
//! Two reducers, both preserving the squared-Euclidean geometry EBC
//! consumes:
//!
//! * [`RandomProjection`] — sparse Achlioptas projection with the
//!   Johnson–Lindenstrauss guarantee: pairwise distances preserved to
//!   (1 ± ε) w.h.p. at m = O(log n / ε²) dims, fit-free and streamable
//!   (the right default for the coordinator's ingest path);
//! * [`Pca`] — top-r principal components via orthogonal iteration on
//!   the centered data (no d×d covariance materialized — X is 1000×3524
//!   in the case study), capturing the melt-pressure curves' dominant
//!   modes.
//!
//! The `ablations` bench (`reduce`) measures what both do to summary
//! fidelity and optimization time on the case-study data.

pub mod pca;
pub mod random_projection;

pub use pca::Pca;
pub use random_projection::RandomProjection;

use crate::linalg::Matrix;

/// A fitted feature-space reducer.
pub trait Reducer {
    /// Output dimensionality.
    fn out_dim(&self) -> usize;
    /// Project one row.
    fn transform_row(&self, row: &[f32]) -> Vec<f32>;
    /// Project a whole matrix.
    fn transform(&self, m: &Matrix) -> Matrix {
        let mut data = Vec::with_capacity(m.rows() * self.out_dim());
        for i in 0..m.rows() {
            data.extend(self.transform_row(m.row(i)));
        }
        Matrix::from_vec(m.rows(), self.out_dim(), data)
    }
}

/// Fraction of pairwise squared distances preserved within (1 ± eps),
/// sampled — the JL quality metric used by tests and the ablation.
pub fn distance_distortion_ok_fraction(
    original: &Matrix,
    reduced: &Matrix,
    eps: f32,
    pairs: usize,
    seed: u64,
) -> f32 {
    use crate::linalg::sq_euclidean;
    use crate::util::rng::Rng;
    assert_eq!(original.rows(), reduced.rows());
    let n = original.rows();
    if n < 2 {
        return 1.0;
    }
    let mut rng = Rng::new(seed);
    let mut ok = 0usize;
    for _ in 0..pairs {
        let i = rng.below(n);
        let j = (i + 1 + rng.below(n - 1)) % n;
        let d0 = sq_euclidean(original.row(i), original.row(j));
        let d1 = sq_euclidean(reduced.row(i), reduced.row(j));
        if d0 == 0.0 {
            ok += (d1 < 1e-6) as usize;
        } else {
            let ratio = d1 / d0;
            ok += (ratio >= 1.0 - eps && ratio <= 1.0 + eps) as usize;
        }
    }
    ok as f32 / pairs as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn distortion_metric_perfect_on_identity() {
        let mut rng = Rng::new(1);
        let m = Matrix::random_normal(30, 8, &mut rng);
        let frac = distance_distortion_ok_fraction(&m, &m, 0.01, 100, 2);
        assert_eq!(frac, 1.0);
    }

    #[test]
    fn distortion_metric_detects_scaling() {
        let mut rng = Rng::new(3);
        let m = Matrix::random_normal(20, 6, &mut rng);
        // double every coordinate: squared distances x4 -> all out of band
        let scaled = Matrix::from_vec(
            20,
            6,
            m.data().iter().map(|x| 2.0 * x).collect(),
        );
        let frac = distance_distortion_ok_fraction(&m, &scaled, 0.5, 100, 4);
        assert_eq!(frac, 0.0);
    }
}
