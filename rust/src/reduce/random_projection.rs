//! Sparse random projection (Achlioptas 2003): entries of the m×d
//! projection matrix are √(3/m)·{+1, 0, −1} with probabilities
//! {1/6, 2/3, 1/6} — the database-friendly JL transform. Fit-free: the
//! matrix is a pure function of (seed, d, m), so coordinator replicas
//! project identically without coordination.

use crate::reduce::Reducer;
use crate::util::rng::Rng;

pub struct RandomProjection {
    in_dim: usize,
    out_dim: usize,
    /// row-major (out_dim x in_dim), entries already scaled by sqrt(3/m)
    proj: Vec<f32>,
}

impl RandomProjection {
    pub fn new(in_dim: usize, out_dim: usize, seed: u64) -> RandomProjection {
        assert!(out_dim > 0 && in_dim > 0);
        let scale = (3.0f32 / out_dim as f32).sqrt();
        let mut rng = Rng::new(seed ^ 0xA11C_E017);
        let mut proj = Vec::with_capacity(in_dim * out_dim);
        for _ in 0..in_dim * out_dim {
            let u = rng.f32();
            proj.push(if u < 1.0 / 6.0 {
                scale
            } else if u < 2.0 / 6.0 {
                -scale
            } else {
                0.0
            });
        }
        RandomProjection { in_dim, out_dim, proj }
    }

    /// JL dimension for n points at distortion eps (standard bound,
    /// constant 4: m >= 4 ln n / (eps²/2 - eps³/3)).
    pub fn jl_dim(n: usize, eps: f32) -> usize {
        let e = eps as f64;
        let denom = e * e / 2.0 - e * e * e / 3.0;
        ((4.0 * (n.max(2) as f64).ln()) / denom).ceil() as usize
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }
}

impl Reducer for RandomProjection {
    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn transform_row(&self, row: &[f32]) -> Vec<f32> {
        assert_eq!(row.len(), self.in_dim);
        let mut out = vec![0f32; self.out_dim];
        // out[o] = sum_i P[o, i] * row[i]; P is row-major (out x in)
        for (o, out_v) in out.iter_mut().enumerate() {
            let prow = &self.proj[o * self.in_dim..(o + 1) * self.in_dim];
            let mut acc = 0f32;
            for i in 0..self.in_dim {
                // sparse entries: 2/3 are zero; branch-free multiply is
                // still fastest with autovectorization
                acc += prow[i] * row[i];
            }
            *out_v = acc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::reduce::distance_distortion_ok_fraction;
    use crate::util::rng::Rng;

    #[test]
    fn preserves_distances_at_jl_dim() {
        let n = 60;
        let d = 500;
        let eps = 0.4;
        let m = RandomProjection::jl_dim(n, eps);
        let mut rng = Rng::new(7);
        let data = Matrix::random_normal(n, d, &mut rng);
        let rp = RandomProjection::new(d, m, 42);
        let red = rp.transform(&data);
        assert_eq!(red.cols(), m);
        let frac = distance_distortion_ok_fraction(&data, &red, eps, 300, 9);
        // JL holds w.h.p.; demand the overwhelming majority in-band
        assert!(frac > 0.9, "only {frac} of pairs within (1±{eps})");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = RandomProjection::new(64, 16, 5);
        let b = RandomProjection::new(64, 16, 5);
        let row: Vec<f32> = (0..64).map(|i| i as f32).collect();
        assert_eq!(a.transform_row(&row), b.transform_row(&row));
        let c = RandomProjection::new(64, 16, 6);
        assert_ne!(a.transform_row(&row), c.transform_row(&row));
    }

    #[test]
    fn jl_dim_monotone() {
        assert!(RandomProjection::jl_dim(1000, 0.2) > RandomProjection::jl_dim(1000, 0.4));
        assert!(RandomProjection::jl_dim(10000, 0.3) > RandomProjection::jl_dim(100, 0.3));
    }

    #[test]
    fn entries_distribution_roughly_achlioptas() {
        let rp = RandomProjection::new(200, 50, 11);
        let zeros = rp.proj.iter().filter(|&&x| x == 0.0).count() as f64
            / rp.proj.len() as f64;
        assert!((zeros - 2.0 / 3.0).abs() < 0.03, "zero fraction {zeros}");
        let pos = rp.proj.iter().filter(|&&x| x > 0.0).count();
        let neg = rp.proj.iter().filter(|&&x| x < 0.0).count();
        let ratio = pos as f64 / neg as f64;
        assert!((0.8..1.25).contains(&ratio), "sign balance {ratio}");
    }
}
