//! PCA via orthogonal (block power) iteration — top-r principal
//! components of the centered data without materializing the d×d
//! covariance: each iteration computes Xᵀ(X Q) in O(n·d·r).
//!
//! Melt-pressure cycles are dominated by a handful of physical modes
//! (peak height, holding level, plasticization length), so small r
//! captures most variance — the tailored reducer for the case study.

use crate::linalg::Matrix;
use crate::reduce::Reducer;
use crate::util::rng::Rng;

pub struct Pca {
    mean: Vec<f32>,
    /// row-major (r x d) orthonormal component matrix
    components: Vec<f32>,
    in_dim: usize,
    r: usize,
    /// variance explained per component (descending)
    pub explained: Vec<f32>,
}

impl Pca {
    /// Fit top-`r` components with `iters` orthogonal iterations.
    pub fn fit(data: &Matrix, r: usize, iters: usize, seed: u64) -> Pca {
        let (n, d) = (data.rows(), data.cols());
        assert!(r >= 1 && r <= d.min(n), "r={r} out of range");
        // mean
        let mut mean = vec![0f64; d];
        for i in 0..n {
            for (j, m) in mean.iter_mut().enumerate() {
                *m += data.row(i)[j] as f64;
            }
        }
        let mean: Vec<f32> = mean.into_iter().map(|x| (x / n as f64) as f32).collect();

        // Q: (r x d) random init, orthonormalized
        let mut rng = Rng::new(seed ^ 0x9CA0_0A9C);
        let mut q: Vec<f32> = (0..r * d).map(|_| rng.normal()).collect();
        gram_schmidt(&mut q, r, d);

        let mut scratch = vec![0f32; n * r];
        for _ in 0..iters.max(1) {
            // Y = Xc Qᵀ   (n x r)
            for i in 0..n {
                let row = data.row(i);
                for c in 0..r {
                    let comp = &q[c * d..(c + 1) * d];
                    let mut acc = 0f32;
                    for j in 0..d {
                        acc += (row[j] - mean[j]) * comp[j];
                    }
                    scratch[i * r + c] = acc;
                }
            }
            // Qnew = Yᵀ Xc   (r x d)
            let mut qn = vec![0f32; r * d];
            for i in 0..n {
                let row = data.row(i);
                for c in 0..r {
                    let w = scratch[i * r + c];
                    if w == 0.0 {
                        continue;
                    }
                    let dst = &mut qn[c * d..(c + 1) * d];
                    for j in 0..d {
                        dst[j] += w * (row[j] - mean[j]);
                    }
                }
            }
            gram_schmidt(&mut qn, r, d);
            q = qn;
        }

        // explained variance per component = var of projections
        let mut explained = vec![0f32; r];
        for i in 0..n {
            let row = data.row(i);
            for c in 0..r {
                let comp = &q[c * d..(c + 1) * d];
                let mut acc = 0f32;
                for j in 0..d {
                    acc += (row[j] - mean[j]) * comp[j];
                }
                explained[c] += acc * acc;
            }
        }
        for e in explained.iter_mut() {
            *e /= n as f32;
        }
        Pca { mean, components: q, in_dim: d, r, explained }
    }
}

/// In-place modified Gram–Schmidt over `r` row vectors of length `d`.
fn gram_schmidt(q: &mut [f32], r: usize, d: usize) {
    for c in 0..r {
        // subtract projections onto previous rows
        for p in 0..c {
            let (head, tail) = q.split_at_mut(c * d);
            let prev = &head[p * d..(p + 1) * d];
            let cur = &mut tail[..d];
            let dot: f32 = prev.iter().zip(cur.iter()).map(|(a, b)| a * b).sum();
            for j in 0..d {
                cur[j] -= dot * prev[j];
            }
        }
        let cur = &mut q[c * d..(c + 1) * d];
        let norm: f32 = cur.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for x in cur.iter_mut() {
                *x /= norm;
            }
        } else {
            // degenerate direction: re-seed with a unit basis vector
            cur.fill(0.0);
            cur[c % d] = 1.0;
        }
    }
}

impl Reducer for Pca {
    fn out_dim(&self) -> usize {
        self.r
    }

    fn transform_row(&self, row: &[f32]) -> Vec<f32> {
        assert_eq!(row.len(), self.in_dim);
        (0..self.r)
            .map(|c| {
                let comp = &self.components[c * self.in_dim..(c + 1) * self.in_dim];
                let mut acc = 0f32;
                for j in 0..self.in_dim {
                    acc += (row[j] - self.mean[j]) * comp[j];
                }
                acc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// planted 2-mode data in d=40: x = a*u + b*v + small noise
    fn planted(n: usize, rng: &mut Rng) -> Matrix {
        let d = 40;
        let u: Vec<f32> = (0..d).map(|j| ((j as f32) * 0.3).sin()).collect();
        let v: Vec<f32> = (0..d).map(|j| ((j as f32) * 0.11).cos()).collect();
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            let a = rng.normal() * 10.0;
            let b = rng.normal() * 3.0;
            for j in 0..d {
                data.push(a * u[j] + b * v[j] + 0.01 * rng.normal());
            }
        }
        Matrix::from_vec(n, d, data)
    }

    #[test]
    fn recovers_planted_low_rank() {
        let mut rng = Rng::new(1);
        let data = planted(200, &mut rng);
        let pca = Pca::fit(&data, 3, 15, 2);
        // first two components carry essentially all the variance
        let total: f32 = pca.explained.iter().sum();
        let top2: f32 = pca.explained[0] + pca.explained[1];
        assert!(top2 / total > 0.99, "{:?}", pca.explained);
        // explained variances descending
        assert!(pca.explained[0] >= pca.explained[1]);
        assert!(pca.explained[1] >= pca.explained[2]);
    }

    #[test]
    fn components_orthonormal() {
        let mut rng = Rng::new(3);
        let data = Matrix::random_normal(80, 20, &mut rng);
        let pca = Pca::fit(&data, 4, 10, 4);
        for a in 0..4 {
            for b in 0..4 {
                let ca = &pca.components[a * 20..(a + 1) * 20];
                let cb = &pca.components[b * 20..(b + 1) * 20];
                let dot: f32 = ca.iter().zip(cb).map(|(x, y)| x * y).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}): {dot}");
            }
        }
    }

    #[test]
    fn transform_shape_and_centering() {
        let mut rng = Rng::new(5);
        let data = planted(50, &mut rng);
        let pca = Pca::fit(&data, 2, 10, 6);
        let red = pca.transform(&data);
        assert_eq!((red.rows(), red.cols()), (50, 2));
        // projected data is centered
        for c in 0..2 {
            let mean: f32 = (0..50).map(|i| red.row(i)[c]).sum::<f32>() / 50.0;
            assert!(mean.abs() < 0.5, "component {c} mean {mean}");
        }
    }

    #[test]
    fn preserves_low_rank_distances_well() {
        use crate::reduce::distance_distortion_ok_fraction;
        let mut rng = Rng::new(7);
        let data = planted(100, &mut rng);
        let pca = Pca::fit(&data, 2, 15, 8);
        let red = pca.transform(&data);
        // rank-2 data: distances essentially exact in 2 components
        let frac = distance_distortion_ok_fraction(&data, &red, 0.05, 200, 9);
        assert!(frac > 0.95, "{frac}");
    }
}
