//! Pruned submodularity graphs + hierarchical shards-of-shards merge —
//! the sublinear ground-set scaling layer.
//!
//! Two papers drive this module:
//!
//! * **Zhou et al., "Scaling Submodular Maximization via Pruned
//!   Submodularity Graphs" (arXiv:1606.00399)** — a sparse directed
//!   graph over the ground set lets provably-dominated elements be
//!   removed *before* any optimizer runs. [`graph`] builds probe-based
//!   neighbor lists with the existing blocked/simd
//!   [`gemm::sq_dist_block_with`](crate::linalg::gemm::sq_dist_block_with)
//!   kernels (never the O(n²) dense matrix) and sieves the ground set
//!   down to an O(n/p) core; every dropped element *charges* its
//!   dominating neighbor, so the surviving core carries per-element
//!   weights whose total equals the original ground size.
//! * **Mitrovic et al., "Data Summarization at Scale: A Two-Stage
//!   Submodular Approach" (arXiv:1806.02815)** — a shards-of-shards
//!   reduction keeps the stage-2 merge off any single node.
//!   [`hierarchy`] arranges the per-shard results into a merge tree of
//!   configurable fanout whose nodes score candidates against weighted
//!   pruned cores, capped at `max_merge_n` rows per node.
//!
//! [`core`] holds [`PrunedGround`] — surviving global ids + charge
//! weights — and builds weighted [`CpuOracle`](crate::submodular::CpuOracle)s
//! through the weighted-eval seam on
//! [`EbcFunction`](crate::submodular::EbcFunction), so **any registry
//! optimizer runs on a pruned core unchanged** and merge scoring stays
//! an unbiased estimate of the full-ground objective. Weights default
//! to 1.0 everywhere else: the unpruned path is untouched (and proven
//! bit-identical by proptests).
//!
//! Everything here is coordinator-local. The prune knobs never cross
//! the frozen v2 wire — `from_wire` forces them off — so replicas need
//! no protocol change: a pruned stage-1 job is just a smaller job.

pub mod core;
pub mod graph;
pub mod hierarchy;

pub use self::core::{cap_ground, prune_rows, PrunedGround};
pub use graph::{dominated, nearest_probes, sieve, PruneConfig, PruneStats};
pub use hierarchy::{merge_tree, HierarchyConfig, MergeLeaf, MergeNodeReport, MergeOutcome};

use crate::linalg::gemm::CpuKernel;
use crate::runtime::artifact::Precision;

/// Prune + hierarchy knobs as they ride on
/// [`ShardedSummarizer`](crate::shard::ShardedSummarizer) — the
/// summarizer-level mirror of the `[shard] prune/fanout/max_merge_n`
/// config keys and the `--prune/--fanout/--max-merge-n` CLI flags.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PruneOptions {
    /// Fraction of each shard's ground rows to sieve away before the
    /// stage-1 optimizer runs (0.0 = pruning off, the legacy path).
    pub rate: f64,
    /// Merge-tree fanout: children per merge node. 0 = flat (a single
    /// root merge, the legacy shape); values ≥ 2 build intermediate
    /// levels whenever more than `fanout` shards report.
    pub fanout: usize,
    /// Hard cap on ground rows any single merge node may score.
    /// 0 = unlimited. When a node's (pruned) ground exceeds the cap it
    /// is sieved further — candidates are protected and charges carry
    /// over, so the weighted objective estimate stays unbiased.
    pub max_merge_n: usize,
    /// Seed for the deterministic sieve (mixed per shard / per node).
    pub seed: u64,
    /// CPU kernel the sieve distance passes and the weighted merge
    /// oracles run on (pruned merge scoring is CPU-side — weights do
    /// not exist on the engine backend).
    pub kernel: CpuKernel,
    /// Precision axis for the same oracles.
    pub precision: Precision,
}

impl Default for PruneOptions {
    fn default() -> Self {
        PruneOptions {
            rate: 0.0,
            fanout: 0,
            max_merge_n: 0,
            seed: 0,
            kernel: CpuKernel::Blocked,
            precision: Precision::F32,
        }
    }
}

impl PruneOptions {
    /// Whether stage-1 pruning is on.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Whether any knob forces the merge through the hierarchy path
    /// (`shards` = non-empty shards that reported). Everything default
    /// ⇒ the summarizer keeps the legacy flat merge verbatim.
    pub fn hierarchical(&self, shards: usize) -> bool {
        self.enabled() || self.max_merge_n > 0 || (self.fanout >= 2 && self.fanout < shards)
    }
}
