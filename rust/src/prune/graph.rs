//! The sparse directed submodularity graph and the seeded sieve prune
//! (Zhou et al., arXiv:1606.00399, adapted to the EBC objective).
//!
//! The full submodularity graph has an edge u → v weighted by how much
//! of v's marginal value survives once u is selected; for EBC
//! (facility-location with the auxiliary exemplar e0) that weight is
//! governed by d²(u, v): if u is close to v, then any coverage v
//! provides, u provides up to d²(u, v) of slack. Materializing all n²
//! edges would defeat the purpose, so — exactly as Zhou et al.'s
//! random-probe sieve — each round draws a seeded probe set U, builds
//! the **sparse neighbor list** {v → (argmin_{u∈U} d²(v,u), d²)} with
//! the blocked/simd distance kernels, and drops the most-dominated
//! elements, charging each dropped v's weight to its dominating probe.
//! Charge is conserved: the surviving core's weights always sum to the
//! original ground size, which is what keeps weighted evaluation over
//! the core an unbiased estimate of the full-ground objective.
//!
//! **Loss bound.** A dropped element v satisfies
//! `d²(v, u) ≤ slack · ‖v‖²` for its kept dominator u, and v's
//! per-point contribution to f is at most ‖v‖² (= d²(v, e0)). Charging
//! v to u therefore misestimates its coverage by at most d²(v, u), so
//! the total objective error is bounded by
//! `slack · Σ_dropped ‖v‖² / n` — the ε of the (1 − ε) guarantee the
//! proptests check empirically.

use crate::linalg::gemm::{self, CpuKernel};
use crate::linalg::Matrix;
use crate::obs;
use crate::util::rng::Rng;
use crate::util::threadpool::scoped_chunks_mut;

/// Sieve parameters. `rate`/`seed` come from the user; the rest have
/// solid defaults via [`PruneConfig::new`].
#[derive(Clone, Copy, Debug)]
pub struct PruneConfig {
    /// Fraction of rows to drop, in [0, 1).
    pub rate: f64,
    /// Seed of the deterministic probe sampler.
    pub seed: u64,
    /// Probe-set size per round; 0 = auto (≈ √|alive|, clamped to
    /// [8, 128]).
    pub probes: usize,
    /// Dominance slack: v may be dropped only when its nearest probe
    /// satisfies `d²(v, u) ≤ slack · ‖v‖²`. `f32::INFINITY` disables
    /// the guard (used by the hard `max_merge_n` cap, which must reach
    /// its target).
    pub slack: f32,
}

impl PruneConfig {
    pub fn new(rate: f64, seed: u64) -> PruneConfig {
        PruneConfig { rate, seed, probes: 0, slack: 1.0 }
    }
}

/// What one sieve did — surfaced through `Provenance`/metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Probe rounds run.
    pub rounds: usize,
    /// Elements dropped (and charged to a dominator).
    pub dropped: usize,
}

/// The dominance test of the pruned submodularity graph: may `v`
/// (with squared norm `vsq_v`) be charged to a neighbor at squared
/// distance `d_uv`? See the module docs for the induced loss bound.
#[inline]
pub fn dominated(d_uv: f32, vsq_v: f32, slack: f32) -> bool {
    d_uv <= slack * vsq_v + 1e-12
}

/// Sparse neighbor list: for every row id in `query` (indices into
/// `sub`), the position of its nearest row in `probes` plus the squared
/// distance — computed tile-by-tile through the blocked/simd
/// [`gemm::sq_dist_block_with`] kernel (|query| × |probes| work, never
/// O(n²)), parallel over disjoint query chunks. Ties go to the lowest
/// probe position, so the result is deterministic for any thread count.
pub fn nearest_probes(
    kernel: CpuKernel,
    threads: usize,
    sub: &Matrix,
    subsq: &[f32],
    query: &[usize],
    probes: &[usize],
) -> Vec<(u32, f32)> {
    let s = probes.len();
    let d = sub.cols();
    assert!(s > 0, "nearest_probes needs a non-empty probe set");
    let pm = sub.gather(probes);
    let psq: Vec<f32> = probes.iter().map(|&p| subsq[p]).collect();
    let mut out = vec![(0u32, 0f32); query.len()];
    let tile = gemm::tile_rows(s);
    scoped_chunks_mut(&mut out, threads.max(1), |_, start, slice| {
        let mut dbuf = vec![0f32; tile * s];
        let mut i0 = 0usize;
        while i0 < slice.len() {
            let i1 = (i0 + tile).min(slice.len());
            let rows = i1 - i0;
            let q = &query[start + i0..start + i1];
            let qm = sub.gather(q);
            let qsq: Vec<f32> = q.iter().map(|&r| subsq[r]).collect();
            gemm::sq_dist_block_with(
                kernel,
                qm.data(),
                &qsq,
                pm.data(),
                &psq,
                d,
                rows,
                s,
                &mut dbuf[..rows * s],
            );
            for ii in 0..rows {
                let drow = &dbuf[ii * s..(ii + 1) * s];
                let mut bi = 0u32;
                let mut bd = f32::INFINITY;
                for (j, &dv) in drow.iter().enumerate() {
                    if dv < bd {
                        bd = dv;
                        bi = j as u32;
                    }
                }
                slice[i0 + ii] = (bi, bd);
            }
            i0 = i1;
        }
    });
    out
}

/// The seeded sieve: repeatedly draw probes, build the neighbor list,
/// and drop the most-dominated elements until at most `target` of
/// `rows` survive (or no droppable element remains). `weights` carries
/// each row's incoming charge (pass all-ones for a fresh prune; pass a
/// prior core's weights to sieve further, e.g. the `max_merge_n` cap);
/// the weight of every dropped row moves to its dominating probe, so
/// the returned weights sum to the input sum exactly. `protect` lists
/// **global** ids that must survive (merge candidates). `rows` must be
/// sorted ascending; the returned ids are too.
///
/// Fully deterministic: seed + inputs ⇒ identical core, independent of
/// thread count.
pub fn sieve(
    kernel: CpuKernel,
    threads: usize,
    data: &Matrix,
    rows: &[usize],
    mut weights: Vec<f32>,
    target: usize,
    protect: &[usize],
    cfg: &PruneConfig,
) -> (Vec<usize>, Vec<f32>, PruneStats) {
    let m = rows.len();
    assert_eq!(weights.len(), m, "one weight per row");
    debug_assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must be sorted + deduplicated");
    let target = target.max(1);
    let mut stats = PruneStats::default();
    if m <= target {
        return (rows.to_vec(), weights, stats);
    }

    let sub = data.gather(rows);
    let subsq = crate::linalg::sq_norms(sub.data(), sub.cols());
    let mut protected = vec![false; m];
    for g in protect {
        if let Ok(l) = rows.binary_search(g) {
            protected[l] = true;
        }
    }
    let mut alive: Vec<usize> = (0..m).collect();
    let mut dead = vec![false; m];
    let mut rng = Rng::new(cfg.seed);
    const MAX_ROUNDS: usize = 64;

    while alive.len() > target && stats.rounds < MAX_ROUNDS {
        let _round = obs::span("prune.drop");
        stats.rounds += 1;
        let s = if cfg.probes > 0 {
            cfg.probes
        } else {
            ((alive.len() as f64).sqrt().ceil() as usize).clamp(8, 128)
        };
        if s >= alive.len() {
            break; // nothing left to compare the probes against
        }
        // seeded partial Fisher–Yates over the (sorted) alive list
        let mut pool = alive.clone();
        for i in 0..s {
            let j = i + rng.below(pool.len() - i);
            pool.swap(i, j);
        }
        let probes: Vec<usize> = pool[..s].to_vec();
        let mut probe_set = probes.clone();
        probe_set.sort_unstable();
        let query: Vec<usize> =
            alive.iter().copied().filter(|l| probe_set.binary_search(l).is_err()).collect();
        let nearest = nearest_probes(kernel, threads, &sub, &subsq, &query, &probes);

        // rank droppable (unprotected, dominated) queries by how
        // redundant they are: smallest probe distance first, ties to
        // the lower row id
        let mut order: Vec<usize> = (0..query.len())
            .filter(|&qi| {
                !protected[query[qi]] && dominated(nearest[qi].1, subsq[query[qi]], cfg.slack)
            })
            .collect();
        if order.is_empty() {
            break; // every remaining element is protected or undominated
        }
        order.sort_unstable_by(|&a, &b| {
            nearest[a].1.total_cmp(&nearest[b].1).then(query[a].cmp(&query[b]))
        });
        // drop at most half the queries per round so later rounds see
        // fresh probes — but never overshoot the target
        let q = (alive.len() - target).min((query.len() / 2).max(1)).min(order.len());
        for &qi in &order[..q] {
            let v = query[qi];
            let u = probes[nearest[qi].0 as usize];
            weights[u] += weights[v];
            weights[v] = 0.0;
            dead[v] = true;
        }
        stats.dropped += q;
        alive.retain(|&l| !dead[l]);
    }

    let ids: Vec<usize> = alive.iter().map(|&l| rows[l]).collect();
    let w: Vec<f32> = alive.iter().map(|&l| weights[l]).collect();
    (ids, w, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn clustered(n: usize, seed: u64) -> Matrix {
        // tight clusters around 4 well-separated centers
        let centers = [[0.0f32, 0.0], [20.0, 0.0], [0.0, 20.0], [20.0, 20.0]];
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let c = centers[i % 4];
                vec![c[0] + 0.1 * rng.normal(), c[1] + 0.1 * rng.normal()]
            })
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        Matrix::from_rows(&refs)
    }

    #[test]
    fn nearest_probe_finds_the_closest_row() {
        let m = clustered(40, 1);
        let sq = crate::linalg::sq_norms(m.data(), m.cols());
        let all: Vec<usize> = (0..40).collect();
        let probes = vec![0usize, 1, 2, 3]; // one per cluster
        let nn = nearest_probes(CpuKernel::Blocked, 2, &m, &sq, &all, &probes);
        for (i, &(p, d)) in nn.iter().enumerate() {
            // every row lands on the probe from its own cluster
            assert_eq!(p as usize, i % 4, "row {i}");
            assert!(d < 1.0, "row {i}: {d}");
        }
    }

    #[test]
    fn sieve_reaches_target_and_conserves_charge() {
        let m = clustered(64, 2);
        let rows: Vec<usize> = (0..64).collect();
        let cfg = PruneConfig::new(0.75, 7);
        let (ids, w, stats) =
            sieve(CpuKernel::Blocked, 2, &m, &rows, vec![1.0; 64], 16, &[], &cfg);
        assert_eq!(ids.len(), 16);
        assert_eq!(stats.dropped, 48);
        assert!(stats.rounds >= 1);
        assert!(ids.windows(2).all(|p| p[0] < p[1]), "core ids must stay sorted");
        let total: f64 = w.iter().map(|&x| x as f64).sum();
        assert!((total - 64.0).abs() < 1e-3, "charge not conserved: {total}");
        assert!(w.iter().all(|&x| x >= 1.0));
    }

    #[test]
    fn sieve_is_deterministic_across_thread_counts() {
        let m = clustered(80, 3);
        let rows: Vec<usize> = (0..80).collect();
        let cfg = PruneConfig::new(0.5, 11);
        let a = sieve(CpuKernel::Blocked, 1, &m, &rows, vec![1.0; 80], 20, &[], &cfg);
        let b = sieve(CpuKernel::Blocked, 4, &m, &rows, vec![1.0; 80], 20, &[], &cfg);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    }

    #[test]
    fn protected_rows_always_survive() {
        let m = clustered(48, 4);
        let rows: Vec<usize> = (0..48).collect();
        let mut cfg = PruneConfig::new(0.9, 5);
        cfg.slack = f32::INFINITY;
        let keep = [5usize, 17, 33];
        let (ids, _, _) =
            sieve(CpuKernel::Blocked, 2, &m, &rows, vec![1.0; 48], 4, &keep, &cfg);
        for g in keep {
            assert!(ids.binary_search(&g).is_ok(), "{g} was dropped");
        }
    }

    #[test]
    fn dominance_guard_blocks_outlier_drops() {
        // slack 0 ⇒ nothing is dominated ⇒ the sieve refuses to drop
        let m = clustered(32, 6);
        let rows: Vec<usize> = (0..32).collect();
        let mut cfg = PruneConfig::new(0.5, 9);
        cfg.slack = 0.0;
        let (ids, _, stats) =
            sieve(CpuKernel::Blocked, 1, &m, &rows, vec![1.0; 32], 8, &[], &cfg);
        // cluster members at distance ~0 from a probe with vsq 0 can
        // still qualify through the epsilon; everything else survives
        assert!(ids.len() >= 8);
        assert!(stats.dropped <= 32 - ids.len() + 1);
    }

    #[test]
    fn subset_rows_map_back_to_global_ids() {
        let m = clustered(60, 8);
        let rows: Vec<usize> = (10..50).collect();
        let cfg = PruneConfig::new(0.5, 13);
        let (ids, w, _) =
            sieve(CpuKernel::Blocked, 2, &m, &rows, vec![1.0; 40], 20, &[], &cfg);
        assert_eq!(ids.len(), 20);
        assert!(ids.iter().all(|&g| (10..50).contains(&g)));
        let total: f64 = w.iter().map(|&x| x as f64).sum();
        assert!((total - 40.0).abs() < 1e-3);
    }
}
