//! The shards-of-shards merge tree (Mitrovic et al., arXiv:1806.02815):
//! per-shard results are merged through intermediate nodes of
//! configurable fanout instead of one flat stage-2 merge, and no node
//! ever scores more than `max_merge_n` ground rows.
//!
//! Each node unions its children's (disjoint, weighted) pruned grounds,
//! unions their selected exemplars as the candidate pool, caps the
//! ground at `max_merge_n` via [`cap_ground`] (candidates protected,
//! charges carried), and re-selects `k` exemplars scored against the
//! weighted core — an unbiased estimate of the node's whole subtree
//! objective. The surviving (capped) ground and the node's picks flow
//! up to the parent; the root's picks are the final summary.
//!
//! With `fanout = 0` (or ≥ the shard count) the tree degenerates to a
//! single root — the flat merge shape — and with pruning off that root
//! scores the identity ground with unit weights, which the proptests
//! prove bit-identical to the legacy flat path.

use crate::linalg::gemm::CpuKernel;
use crate::linalg::Matrix;
use crate::obs;
use crate::optim::greedy::greedy_over_candidates;
use crate::optim::{Optimizer, SummaryResult};
use crate::prune::core::{cap_ground, PrunedGround};
use crate::runtime::artifact::Precision;
use crate::submodular::{CpuOracle, Oracle};
use std::sync::Arc;

/// Merge-tree knobs, resolved by the summarizer from
/// [`crate::prune::PruneOptions`] + the run's oracle settings.
#[derive(Clone, Copy, Debug)]
pub struct HierarchyConfig {
    /// Children per merge node; 0 = unlimited (single root).
    pub fanout: usize,
    /// Ground-row cap per node; 0 = unlimited.
    pub max_merge_n: usize,
    /// Seed for the cap sieves (mixed per node).
    pub seed: u64,
    /// CPU kernel / precision / thread width of the node oracles.
    pub kernel: CpuKernel,
    pub precision: Precision,
    pub threads: usize,
    /// Candidate-batch size of the per-node greedy.
    pub batch: usize,
}

/// One leaf of the tree: a shard's surviving ground core and the
/// exemplars its stage-1 optimizer picked (global ids).
#[derive(Clone, Debug)]
pub struct MergeLeaf {
    pub ground: PrunedGround,
    pub selected: Vec<usize>,
}

/// Accounting for one merge node (asserted on by the `max_merge_n`
/// tests, reported through `Provenance`).
#[derive(Clone, Copy, Debug)]
pub struct MergeNodeReport {
    /// Tree level, 1 = first merge above the shards.
    pub level: usize,
    /// Ground rows this node actually scored (post-cap).
    pub scored_n: usize,
    /// Candidate-pool size.
    pub candidates: usize,
}

/// The merge tree's output.
#[derive(Clone, Debug)]
pub struct MergeOutcome {
    /// Root selection with **global** indices; `f_final` is the
    /// weighted (unbiased) estimate against the root's scored core.
    pub result: SummaryResult,
    /// Merge levels run (1 = flat).
    pub depth: usize,
    /// Every node, level order.
    pub nodes: Vec<MergeNodeReport>,
    /// max over nodes of `scored_n` — provably ≤ `max_merge_n` when
    /// the cap is set.
    pub max_scored_n: usize,
}

/// Run the full merge tree over the per-shard leaves. `merge_opt`
/// switches the per-node selector: `None` = the candidate-pool greedy
/// (the legacy merge, scored on the node's weighted ground); `Some` =
/// any registry optimizer run over the candidate-pool oracle (the
/// classic two-stage shape, where stage 2's ground *is* the union of
/// stage-1 picks), with `f_final` re-measured on the node ground so
/// reported quality stays comparable.
pub fn merge_tree(
    data: &Matrix,
    leaves: Vec<MergeLeaf>,
    k: usize,
    cfg: &HierarchyConfig,
    merge_opt: Option<&dyn Optimizer>,
) -> MergeOutcome {
    let fanout = if cfg.fanout == 0 { usize::MAX } else { cfg.fanout.max(2) };
    if leaves.is_empty() {
        return MergeOutcome {
            result: SummaryResult {
                indices: vec![],
                f_trajectory: vec![],
                f_final: 0.0,
                wall_seconds: 0.0,
                oracle_calls: 0,
                oracle_work: 0,
            },
            depth: 0,
            nodes: vec![],
            max_scored_n: 0,
        };
    }
    let mut level = leaves;
    let mut depth = 0usize;
    let mut nodes = Vec::new();
    let mut max_scored = 0usize;
    let mut node_id = 0u64;
    loop {
        depth += 1;
        let mut next: Vec<MergeLeaf> = Vec::with_capacity(level.len().div_ceil(fanout.max(1)));
        for group in level.chunks(fanout) {
            node_id += 1;
            // disjoint weighted grounds → one sorted union
            let mut pairs: Vec<(usize, f32)> = Vec::new();
            let mut covered = 0usize;
            for leaf in group {
                covered += leaf.ground.n_full;
                pairs.extend(leaf.ground.ids.iter().copied().zip(leaf.ground.weights.iter().copied()));
            }
            pairs.sort_unstable_by_key(|&(id, _)| id);
            let ground = PrunedGround {
                ids: pairs.iter().map(|&(id, _)| id).collect(),
                weights: pairs.iter().map(|&(_, w)| w).collect(),
                n_full: covered,
            };
            let mut cands: Vec<usize> =
                group.iter().flat_map(|l| l.selected.iter().copied()).collect();
            cands.sort_unstable();
            cands.dedup();
            let ground = cap_ground(
                data,
                ground,
                cfg.max_merge_n,
                &cands,
                cfg.kernel,
                cfg.threads,
                cfg.seed ^ node_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let result = select_at_node(data, &ground, &cands, k, cfg, merge_opt);
            nodes.push(MergeNodeReport {
                level: depth,
                scored_n: ground.len(),
                candidates: cands.len(),
            });
            max_scored = max_scored.max(ground.len());
            next.push(MergeLeaf { selected: result.indices.clone(), ground });
            if next.len() == 1 && level.len() <= fanout {
                // this was the root
                obs::gauge(obs::PRUNE_MERGE_DEPTH, "merge-tree depth of the last sharded run")
                    .set(depth as i64);
                return MergeOutcome { result, depth, nodes, max_scored_n: max_scored };
            }
        }
        level = next;
    }
}

/// Select `k` exemplars at one node. Candidates are global ids; they
/// are always present in `ground` (shard picks come from shard cores,
/// and [`cap_ground`] protects them), and both lists are ascending, so
/// the local candidate pool stays sorted — preserving the greedy
/// tie-break order of the flat merge.
fn select_at_node(
    data: &Matrix,
    ground: &PrunedGround,
    cands: &[usize],
    k: usize,
    cfg: &HierarchyConfig,
    merge_opt: Option<&dyn Optimizer>,
) -> SummaryResult {
    let local: Vec<usize> = cands.iter().filter_map(|&g| ground.locate(g)).collect();
    debug_assert_eq!(local.len(), cands.len(), "merge candidates must survive the cap");
    if local.is_empty() || k == 0 {
        return SummaryResult {
            indices: vec![],
            f_trajectory: vec![],
            f_final: 0.0,
            wall_seconds: 0.0,
            oracle_calls: 0,
            oracle_work: 0,
        };
    }
    let mut oracle = ground.oracle(data, cfg.kernel, cfg.precision, cfg.threads);
    match merge_opt {
        None => {
            let mut r = greedy_over_candidates(&mut oracle, &local, k, cfg.batch);
            r.indices = r.indices.iter().map(|&l| ground.ids[l]).collect();
            r
        }
        Some(opt) => {
            // stage-2 ground = the candidate pool itself, weighted by
            // each pick's charge so dense shards count for more
            let weights: Vec<f32> = local.iter().map(|&l| ground.weights[l]).collect();
            let pool = Arc::new(data.gather(cands));
            let mut pool_oracle =
                CpuOracle::with_kernel_shared(pool, cfg.kernel, cfg.precision, cfg.threads)
                    .with_weights(weights);
            let mut r = opt.run(&mut pool_oracle, k);
            r.indices = r.indices.iter().map(|&p| cands[p]).collect();
            // re-measure f on the node ground for comparability
            let sel_local: Vec<usize> =
                r.indices.iter().filter_map(|&g| ground.locate(g)).collect();
            let f = oracle.eval_sets(&[&sel_local])[0];
            r.f_final = f;
            if let Some(last) = r.f_trajectory.last_mut() {
                *last = f;
            }
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build_optimizer, Greedy};
    use crate::shard::merge::greedy_merge;
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::random_normal(n, 5, &mut rng)
    }

    fn cfg() -> HierarchyConfig {
        HierarchyConfig {
            fanout: 0,
            max_merge_n: 0,
            seed: 0,
            kernel: CpuKernel::Blocked,
            precision: Precision::F32,
            threads: 1,
            batch: 1024,
        }
    }

    /// Stage-1 leaves from a round-robin split with identity grounds.
    fn leaves(v: &Matrix, p: usize, k: usize) -> Vec<MergeLeaf> {
        let n = v.rows();
        (0..p)
            .map(|s| {
                let rows: Vec<usize> = (s..n).step_by(p).collect();
                let g = PrunedGround::identity(&rows);
                let mut o = g.oracle(v, CpuKernel::Blocked, Precision::F32, 1);
                let r = Greedy::default().run(&mut o, k);
                let selected: Vec<usize> = r.indices.iter().map(|&l| g.ids[l]).collect();
                MergeLeaf { ground: g, selected }
            })
            .collect()
    }

    #[test]
    fn single_root_reproduces_the_flat_merge_bitwise() {
        let v = data(48, 1);
        let ls = leaves(&v, 4, 5);
        let mut union: Vec<usize> = ls.iter().flat_map(|l| l.selected.iter().copied()).collect();
        union.sort_unstable();
        union.dedup();
        let mut flat_oracle = CpuOracle::with_kernel_shared(
            Arc::new(v.clone()),
            CpuKernel::Blocked,
            Precision::F32,
            1,
        );
        let flat = greedy_merge(&mut flat_oracle, &union, 5, 1024);
        for fanout in [0usize, 4, 9] {
            let mut c = cfg();
            c.fanout = fanout;
            let out = merge_tree(&v, ls.clone(), 5, &c, None);
            assert_eq!(out.depth, 1, "fanout {fanout}");
            assert_eq!(out.result.indices, flat.indices, "fanout {fanout}");
            assert_eq!(out.result.f_final.to_bits(), flat.f_final.to_bits(), "fanout {fanout}");
            assert_eq!(out.max_scored_n, 48);
        }
    }

    #[test]
    fn fanout_two_builds_the_expected_depth() {
        let v = data(64, 2);
        let ls = leaves(&v, 8, 3);
        let mut c = cfg();
        c.fanout = 2;
        let out = merge_tree(&v, ls, 3, &c, None);
        // 8 → 4 → 2 → 1
        assert_eq!(out.depth, 3);
        assert_eq!(out.nodes.len(), 4 + 2 + 1);
        assert_eq!(out.result.k(), 3);
        assert!(out.result.indices.iter().all(|&i| i < 64));
    }

    #[test]
    fn no_node_scores_more_than_the_cap() {
        let v = data(90, 3);
        let ls = leaves(&v, 6, 4);
        let mut c = cfg();
        c.fanout = 3;
        c.max_merge_n = 25;
        let out = merge_tree(&v, ls, 4, &c, None);
        assert!(out.max_scored_n <= 25, "cap violated: {}", out.max_scored_n);
        for node in &out.nodes {
            assert!(node.scored_n <= 25, "node scored {}", node.scored_n);
        }
        assert_eq!(out.result.k(), 4);
    }

    #[test]
    fn registry_merge_optimizer_selects_from_the_union() {
        let v = data(40, 4);
        let ls = leaves(&v, 4, 4);
        let union: Vec<usize> =
            ls.iter().flat_map(|l| l.selected.iter().copied()).collect();
        let opt = build_optimizer("stochastic_greedy", 64).unwrap();
        let out = merge_tree(&v, ls.clone(), 4, &cfg(), Some(opt.as_ref()));
        assert!(out.result.k() <= 4);
        for i in &out.result.indices {
            assert!(union.contains(i), "{i} not a stage-1 pick");
        }
        assert!(out.result.f_final >= 0.0);
    }

    #[test]
    fn weighted_leaves_flow_through_intermediate_levels() {
        let v = data(120, 5);
        let n = v.rows();
        let p = 6;
        let ls: Vec<MergeLeaf> = (0..p)
            .map(|s| {
                let rows: Vec<usize> = (s..n).step_by(p).collect();
                let (g, _) = crate::prune::prune_rows(
                    &v,
                    &rows,
                    CpuKernel::Blocked,
                    1,
                    &crate::prune::PruneConfig::new(0.5, s as u64),
                );
                let mut o = g.oracle(&v, CpuKernel::Blocked, Precision::F32, 1);
                let r = Greedy::default().run(&mut o, 3);
                let selected: Vec<usize> = r.indices.iter().map(|&l| g.ids[l]).collect();
                MergeLeaf { ground: g, selected }
            })
            .collect();
        let mut c = cfg();
        c.fanout = 2;
        c.max_merge_n = 40;
        let out = merge_tree(&v, ls, 3, &c, None);
        assert_eq!(out.depth, 3);
        assert!(out.max_scored_n <= 40);
        // the root ground still stands in for every covered row
        assert!(out.result.f_final >= 0.0);
    }
}
