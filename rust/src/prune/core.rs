//! [`PrunedGround`] — the surviving core of a sieved ground set — and
//! the weighted-oracle bridge any registry optimizer runs on unchanged.

use crate::linalg::gemm::CpuKernel;
use crate::linalg::Matrix;
use crate::obs;
use crate::prune::graph::{self, PruneConfig, PruneStats};
use crate::runtime::artifact::Precision;
use crate::submodular::CpuOracle;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

fn prune_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| obs::histogram(obs::PRUNE_SECONDS, "per-sieve prune latency (seconds)"))
}

fn dropped_counter() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| {
        obs::counter(obs::PRUNE_DROPPED_TOTAL, "ground rows sieved away across all prunes")
    })
}

/// The surviving core of a (possibly repeatedly) sieved ground set:
/// global row ids, the charge weight each survivor accumulated from the
/// rows dropped onto it, and the size of the ground it stands in for.
/// Invariant: `ids` sorted ascending, `weights.len() == ids.len()`, and
/// `Σ weights == n_full` (charge conservation — see
/// [`crate::prune::graph`]), which is exactly what makes the weighted
/// objective over the core an unbiased estimate of the full-ground one.
#[derive(Clone, Debug, PartialEq)]
pub struct PrunedGround {
    /// Surviving global row ids, ascending.
    pub ids: Vec<usize>,
    /// Charge per survivor (≥ 1.0 after a fresh prune).
    pub weights: Vec<f32>,
    /// Rows of the ground set this core represents.
    pub n_full: usize,
}

impl PrunedGround {
    /// The no-op core: every row survives with unit charge.
    pub fn identity(rows: &[usize]) -> PrunedGround {
        PrunedGround {
            ids: rows.to_vec(),
            weights: vec![1.0; rows.len()],
            n_full: rows.len(),
        }
    }

    /// [`Self::identity`] over the full ground `0..n`.
    pub fn full(n: usize) -> PrunedGround {
        PrunedGround { ids: (0..n).collect(), weights: vec![1.0; n], n_full: n }
    }

    /// Survivors in the core.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Rows sieved away.
    pub fn dropped(&self) -> usize {
        self.n_full - self.ids.len()
    }

    /// Position of a global row id within the core, if it survived.
    pub fn locate(&self, global: usize) -> Option<usize> {
        self.ids.binary_search(&global).ok()
    }

    /// Build a weighted CPU oracle over the gathered core: the
    /// sub-matrix plus the charge weights through the weighted-eval
    /// seam on [`crate::submodular::EbcFunction`] — gains, eval and
    /// f-trajectories all become unbiased full-ground estimates, and
    /// any [`crate::optim::Optimizer`] runs on it unchanged. Selected
    /// indices come back core-local; map them with [`Self::ids`].
    pub fn oracle(
        &self,
        data: &Matrix,
        kernel: CpuKernel,
        precision: Precision,
        threads: usize,
    ) -> CpuOracle {
        let sub = Arc::new(data.gather(&self.ids));
        CpuOracle::with_kernel_shared(sub, kernel, precision, threads)
            .with_weights(self.weights.clone())
    }
}

/// Prune `rows` of `data` down to a `(1 − cfg.rate)` core with unit
/// initial charges — the stage-1 entry point (`rows` = one shard's
/// partition). Returns the identity core untouched when the rate is 0
/// or the target rounds up to everything. Deterministic per
/// (`cfg.seed`, inputs); records `ebc_prune_seconds` /
/// `ebc_prune_dropped_total` and runs under a `prune.build` span.
pub fn prune_rows(
    data: &Matrix,
    rows: &[usize],
    kernel: CpuKernel,
    threads: usize,
    cfg: &PruneConfig,
) -> (PrunedGround, PruneStats) {
    let m = rows.len();
    let keep = m.saturating_sub((m as f64 * cfg.rate).floor() as usize).max(1);
    if cfg.rate <= 0.0 || keep >= m {
        return (PrunedGround::identity(rows), PruneStats::default());
    }
    let _span = obs::span("prune.build");
    let t0 = Instant::now();
    let (ids, weights, stats) =
        graph::sieve(kernel, threads, data, rows, vec![1.0; m], keep, &[], cfg);
    prune_hist().observe(t0.elapsed().as_secs_f64());
    dropped_counter().add(stats.dropped as u64);
    (PrunedGround { ids, weights, n_full: m }, stats)
}

/// Enforce the `max_merge_n` cap on a merge node's ground: sieve an
/// oversized core down to `max_n` survivors, protecting the merge
/// `candidates` (global ids) and carrying the existing charges forward,
/// so the capped node still scores an unbiased estimate of its whole
/// subtree. The dominance guard is disabled — a hard cap must reach its
/// target. No-op when `max_n` is 0 or the core already fits.
pub fn cap_ground(
    data: &Matrix,
    ground: PrunedGround,
    max_n: usize,
    candidates: &[usize],
    kernel: CpuKernel,
    threads: usize,
    seed: u64,
) -> PrunedGround {
    if max_n == 0 || ground.len() <= max_n {
        return ground;
    }
    let _span = obs::span("prune.build");
    let t0 = Instant::now();
    let cfg = PruneConfig { rate: 0.0, seed, probes: 0, slack: f32::INFINITY };
    let (ids, weights, stats) = graph::sieve(
        kernel,
        threads,
        data,
        &ground.ids,
        ground.weights,
        max_n,
        candidates,
        &cfg,
    );
    prune_hist().observe(t0.elapsed().as_secs_f64());
    dropped_counter().add(stats.dropped as u64);
    PrunedGround { ids, weights, n_full: ground.n_full }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build_optimizer, Optimizer};
    use crate::submodular::{CpuOracle, Oracle};
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::random_normal(n, 4, &mut rng)
    }

    #[test]
    fn identity_core_is_a_no_op() {
        let rows: Vec<usize> = (3..19).collect();
        let g = PrunedGround::identity(&rows);
        assert_eq!(g.len(), 16);
        assert_eq!(g.dropped(), 0);
        assert_eq!(g.locate(7), Some(4));
        assert_eq!(g.locate(2), None);
    }

    #[test]
    fn rate_zero_returns_identity() {
        let v = data(30, 1);
        let rows: Vec<usize> = (0..30).collect();
        let (g, stats) =
            prune_rows(&v, &rows, CpuKernel::Blocked, 1, &PruneConfig::new(0.0, 5));
        assert_eq!(g, PrunedGround::identity(&rows));
        assert_eq!(stats, PruneStats::default());
    }

    #[test]
    fn prune_keeps_the_requested_fraction() {
        let v = data(100, 2);
        let rows: Vec<usize> = (0..100).collect();
        let (g, stats) =
            prune_rows(&v, &rows, CpuKernel::Blocked, 2, &PruneConfig::new(0.6, 5));
        assert_eq!(g.len(), 40);
        assert_eq!(g.dropped(), 60);
        assert_eq!(stats.dropped, 60);
        let total: f64 = g.weights.iter().map(|&w| w as f64).sum();
        assert!((total - 100.0).abs() < 1e-3);
    }

    #[test]
    fn cap_protects_candidates_and_charges() {
        let v = data(120, 3);
        let (g, _) = prune_rows(
            &v,
            &(0..120).collect::<Vec<_>>(),
            CpuKernel::Blocked,
            2,
            &PruneConfig::new(0.25, 9),
        );
        let protect = [g.ids[0], g.ids[10], g.ids[20]];
        let capped = cap_ground(&v, g, 30, &protect, CpuKernel::Blocked, 2, 17);
        assert!(capped.len() <= 30);
        assert_eq!(capped.n_full, 120);
        for p in protect {
            assert!(capped.locate(p).is_some(), "candidate {p} was capped away");
        }
        let total: f64 = capped.weights.iter().map(|&w| w as f64).sum();
        assert!((total - 120.0).abs() < 1e-3);
    }

    #[test]
    fn every_registry_optimizer_runs_on_a_pruned_core() {
        let v = data(60, 4);
        let (g, _) = prune_rows(
            &v,
            &(0..60).collect::<Vec<_>>(),
            CpuKernel::Blocked,
            1,
            &PruneConfig::new(0.5, 21),
        );
        for name in crate::optim::ALGORITHMS {
            let opt = build_optimizer(name, 64).unwrap();
            let mut oracle = g.oracle(&v, CpuKernel::Blocked, Precision::F32, 1);
            let res = opt.run(&mut oracle, 4);
            assert!(res.k() <= 4, "{name}");
            // core-local indices map back into the surviving ids
            for &i in &res.indices {
                assert!(i < g.len(), "{name}: local index {i} out of core");
            }
        }
    }

    #[test]
    fn weighted_core_estimates_the_full_objective() {
        // tight clusters: the pruned, weighted estimate of f(S) must
        // land near the exact full-ground value
        let mut rng = Rng::new(11);
        let rows: Vec<Vec<f32>> = (0..80)
            .map(|i| {
                let c = [(i % 4) as f32 * 15.0, ((i % 4) / 2) as f32 * 15.0];
                vec![c[0] + 0.2 * rng.normal(), c[1] + 0.2 * rng.normal()]
            })
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let v = Matrix::from_rows(&refs);
        let (g, _) = prune_rows(
            &v,
            &(0..80).collect::<Vec<_>>(),
            CpuKernel::Blocked,
            1,
            &PruneConfig::new(0.5, 3),
        );
        let set: Vec<usize> = vec![g.ids[0], g.ids[g.len() / 2]];
        let full = CpuOracle::new(v.clone()).function().eval(&set);
        let local: Vec<usize> = set.iter().map(|&s| g.locate(s).unwrap()).collect();
        let mut core = g.oracle(&v, CpuKernel::Blocked, Precision::F32, 1);
        let est = core.eval_sets(&[&local])[0];
        assert!(
            (est - full).abs() <= 0.15 * (1.0 + full.abs()),
            "weighted estimate {est} vs full {full}"
        );
    }
}
