//! Benchmark harness (criterion is unavailable offline — DESIGN.md §4).
//!
//! Provides timed measurement with warmup, a row-oriented reporter that
//! prints paper-style tables and saves CSV next to `bench_output.txt`,
//! and the workload generators for the paper's experiments.

pub mod kernel_scaling;
pub mod report;
pub mod shard_scaling;
pub mod workload;

pub use kernel_scaling::{
    kernel_scaling_sweep, shard_split_sweep, KernelPoint, KernelSweepConfig, SplitPoint,
};
pub use report::Reporter;
pub use shard_scaling::{
    prune_scaling_sweep, save_shard_json, shard_scaling_sweep, PruneSweepPoint,
    ShardScalingPoint, ShardSweepConfig,
};
pub use workload::{fig2_workload, EvalProblem};

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Measurement settings (overridable via env for quick runs:
/// `EBC_BENCH_ITERS`, `EBC_BENCH_MIN_MS`).
#[derive(Debug, Clone, Copy)]
pub struct Settings {
    pub warmup: usize,
    pub min_iters: usize,
    pub min_time: Duration,
    pub max_iters: usize,
}

impl Default for Settings {
    fn default() -> Self {
        let iters = std::env::var("EBC_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5);
        let min_ms = std::env::var("EBC_BENCH_MIN_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(200u64);
        Settings {
            warmup: 1,
            min_iters: iters,
            min_time: Duration::from_millis(min_ms),
            max_iters: 1000,
        }
    }
}

/// Time a closure under the settings; returns per-iteration summaries.
pub fn measure(settings: &Settings, mut f: impl FnMut()) -> Summary {
    for _ in 0..settings.warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < settings.min_iters
        || (start.elapsed() < settings.min_time && samples.len() < settings.max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Quick-mode check: set `EBC_BENCH_QUICK=1` to shrink sweeps (used by
/// `cargo bench` in CI-sized environments).
pub fn quick_mode() -> bool {
    std::env::var("EBC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Full-mode check: `EBC_BENCH_FULL=1` runs the paper-scale sweeps
/// (default is the scaled sweep of DESIGN.md §4 — this container has a
/// single CPU core).
pub fn full_mode() -> bool {
    std::env::var("EBC_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_summary() {
        let s = Settings {
            warmup: 1,
            min_iters: 3,
            min_time: Duration::from_millis(1),
            max_iters: 10,
        };
        let sum = measure(&s, || std::thread::sleep(Duration::from_micros(100)));
        assert!(sum.n >= 3);
        assert!(sum.mean >= 50e-6);
    }
}
