//! Scaling harness for the shard subsystem: sweep shard counts ×
//! optimizers over one dataset and account wall-clock + quality against
//! the single-node run — optionally under a fleet [`ShardPlan`]
//! (planned worker × kernel-thread split + shared engine buckets).
//! Shared by the `shard-bench` CLI subcommand and the `shard_scaling`
//! bench target.

use crate::engine::{PlanRequest, ShardPlan};
use crate::linalg::SharedMatrix;
use crate::optim::build_optimizer;
use crate::shard::{build_partitioner, build_transport, ShardOracleFactory, ShardedSummarizer};
use crate::util::json::{Json, ObjBuilder};
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Plan-builder seam for the sweep: the XLA backend's variant consults
/// the artifact manifest, the CPU one plans the thread split only.
pub type SweepPlanner<'a> = &'a (dyn Fn(&PlanRequest) -> Arc<ShardPlan> + Sync);

/// One (optimizer, shard-count) measurement.
#[derive(Debug, Clone)]
pub struct ShardScalingPoint {
    pub algorithm: String,
    pub shards: usize,
    pub shards_used: usize,
    /// Wall-clock of the parallel per-shard stage.
    pub shard_seconds: f64,
    pub merge_seconds: f64,
    pub total_seconds: f64,
    /// Single-node wall-clock of the same optimizer (the P-independent
    /// reference, measured once per algorithm).
    pub single_seconds: f64,
    pub f_merged: f32,
    pub f_single: f32,
    /// f_merged / f_single.
    pub quality_ratio: f64,
    /// single_seconds / total_seconds.
    pub speedup: f64,
    /// Planned worker × thread split label (`-` for unplanned runs).
    pub plan: String,
    /// Transport the first stage ran over (`inproc` | `loopback`).
    pub transport: String,
    /// Wire bytes this measurement moved (job + result frames).
    pub wire_bytes: u64,
    /// Shards re-queued after replica failures during this measurement.
    pub shard_retries: u64,
}

/// Sweep settings.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    pub k: usize,
    pub shard_counts: Vec<usize>,
    pub algorithms: Vec<String>,
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto); ignored for
    /// planned runs (the plan's split wins).
    pub threads: usize,
    pub seed: u64,
    /// Core budget handed to the planner (0 = auto).
    pub cores: usize,
    /// Shard-stage transport ([`crate::shard::TRANSPORTS`]).
    pub transport: String,
    /// Replica count for the `loopback` transport.
    pub replicas: usize,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            k: 10,
            shard_counts: vec![1, 2, 4, 8],
            algorithms: vec!["greedy".into()],
            partitioner: "round_robin".into(),
            threads: 0,
            seed: 0xEBC,
            cores: 0,
            transport: "inproc".into(),
            replicas: 2,
        }
    }
}

/// Run the sweep. The baseline per algorithm is taken from the P = 1
/// point's reference run, so every row's `speedup` compares against the
/// same single-node measurement. With a `planner`, every P gets a fleet
/// plan (reported per row via `plan`).
pub fn shard_scaling_sweep(
    data: &SharedMatrix,
    factory: &ShardOracleFactory,
    cfg: &ShardSweepConfig,
    planner: Option<SweepPlanner>,
) -> Result<Vec<ShardScalingPoint>> {
    let partitioner = build_partitioner(&cfg.partitioner, cfg.seed)
        .ok_or_else(|| anyhow!("unknown partitioner '{}'", cfg.partitioner))?;
    let transport = build_transport(&cfg.transport, cfg.replicas).ok_or_else(|| {
        anyhow!(
            "unknown transport '{}' (expected one of {:?})",
            cfg.transport,
            crate::shard::TRANSPORTS
        )
    })?;
    let mut out = Vec::new();
    for alg in &cfg.algorithms {
        let optimizer = build_optimizer(alg, 1024)
            .ok_or_else(|| anyhow!("unknown algorithm '{alg}'"))?;
        let mut single: Option<(f64, f32)> = None; // (seconds, f)
        for &p in &cfg.shard_counts {
            let mut s = ShardedSummarizer::new(partitioner.as_ref(), optimizer.as_ref(), p);
            s.threads = cfg.threads;
            s.transport = Some(transport.as_ref());
            let plan_label = match planner {
                Some(build) => {
                    let mut req = PlanRequest::new(data.rows(), data.cols(), p, cfg.k);
                    req.cores = cfg.cores;
                    let plan = build(&req);
                    let label = plan.split_label();
                    s.plan = Some(plan);
                    label
                }
                None => "-".to_string(),
            };
            let res = if single.is_none() {
                let r = s.summarize_with_baseline(data, factory, cfg.k);
                let b = r.baseline.as_ref().expect("baseline requested");
                single = Some((b.wall_seconds, b.f_final));
                r
            } else {
                s.summarize(data, factory, cfg.k)
            };
            let (single_seconds, f_single) = single.expect("baseline set");
            let total = res.total_seconds();
            out.push(ShardScalingPoint {
                algorithm: alg.clone(),
                shards: p,
                shards_used: res.shards_used,
                shard_seconds: res.shard_seconds,
                merge_seconds: res.merge_seconds,
                total_seconds: total,
                single_seconds,
                f_merged: res.merged.f_final,
                f_single,
                quality_ratio: if f_single <= 0.0 {
                    1.0
                } else {
                    res.merged.f_final as f64 / f_single as f64
                },
                speedup: if total > 0.0 { single_seconds / total } else { 0.0 },
                plan: plan_label,
                transport: res.transport.to_string(),
                wire_bytes: res.wire_bytes,
                shard_retries: res.shard_retries,
            });
        }
    }
    Ok(out)
}

/// Persist a sweep as `BENCH_shard.json` (the artifact the CI bench
/// job uploads): the sweep config + one record per measurement,
/// including the transport column and its wire-traffic counters.
pub fn save_shard_json(
    path: &Path,
    cfg: &ShardSweepConfig,
    points: &[ShardScalingPoint],
) -> Result<PathBuf> {
    let records: Vec<Json> = points
        .iter()
        .map(|p| {
            ObjBuilder::new()
                .str("algorithm", p.algorithm.clone())
                .int("shards", p.shards)
                .int("shards_used", p.shards_used)
                .num("shard_seconds", p.shard_seconds)
                .num("merge_seconds", p.merge_seconds)
                .num("total_seconds", p.total_seconds)
                .num("single_seconds", p.single_seconds)
                .num("f_merged", p.f_merged as f64)
                .num("f_single", p.f_single as f64)
                .num("quality_ratio", p.quality_ratio)
                .num("speedup", p.speedup)
                .str("plan", p.plan.clone())
                .str("transport", p.transport.clone())
                .int("wire_bytes", p.wire_bytes as usize)
                .int("shard_retries", p.shard_retries as usize)
                .build()
        })
        .collect();
    let doc = ObjBuilder::new()
        .str("bench", "shard_scaling")
        .int("k", cfg.k)
        .str("partitioner", cfg.partitioner.clone())
        .str("transport", cfg.transport.clone())
        .int("replicas", cfg.replicas)
        .int("seed", cfg.seed as usize)
        .val("points", Json::Arr(records))
        .build();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.dump())?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OracleSpec;
    use crate::linalg::Matrix;
    use crate::submodular::{CpuOracle, Oracle};
    use crate::util::rng::Rng;

    fn factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    }

    #[test]
    fn sweep_produces_one_point_per_cell() {
        let mut rng = Rng::new(1);
        let data = Arc::new(Matrix::random_normal(80, 6, &mut rng));
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 2],
            algorithms: vec!["greedy".into(), "stochastic_greedy".into()],
            ..Default::default()
        };
        let points = shard_scaling_sweep(&data, &factory(), &cfg, None).unwrap();
        assert_eq!(points.len(), 4);
        for pt in &points {
            assert!(pt.total_seconds > 0.0);
            assert!(pt.quality_ratio > 0.5, "{pt:?}");
            assert_eq!(pt.plan, "-");
            assert_eq!(pt.transport, "inproc");
            assert!(pt.wire_bytes > 0);
            assert_eq!(pt.shard_retries, 0);
        }
        // P = 1 greedy is exactly the single-node run
        let p1 = &points[0];
        assert_eq!(p1.shards, 1);
        assert_eq!(p1.f_merged.to_bits(), p1.f_single.to_bits());
    }

    #[test]
    fn planned_sweep_matches_unplanned_selection() {
        let mut rng = Rng::new(5);
        let data = Arc::new(Matrix::random_normal(60, 5, &mut rng));
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 3],
            cores: 4,
            ..Default::default()
        };
        let unplanned = shard_scaling_sweep(&data, &factory(), &cfg, None).unwrap();
        let planner = |req: &PlanRequest| Arc::new(ShardPlan::plan(None, req));
        let planned = shard_scaling_sweep(&data, &factory(), &cfg, Some(&planner)).unwrap();
        assert_eq!(planned.len(), unplanned.len());
        for (a, b) in planned.iter().zip(&unplanned) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_ne!(a.plan, "-");
        }
        assert_eq!(planned[1].plan, "3w x 1t");
    }

    #[test]
    fn loopback_sweep_matches_inproc_and_exports_json() {
        let mut rng = Rng::new(9);
        let data = Arc::new(Matrix::random_normal(50, 4, &mut rng));
        let cfg = ShardSweepConfig {
            k: 3,
            shard_counts: vec![1, 3],
            ..Default::default()
        };
        let inproc = shard_scaling_sweep(&data, &factory(), &cfg, None).unwrap();
        let lb_cfg = ShardSweepConfig {
            transport: "loopback".into(),
            replicas: 3,
            ..cfg.clone()
        };
        let lb = shard_scaling_sweep(&data, &factory(), &lb_cfg, None).unwrap();
        assert_eq!(lb.len(), inproc.len());
        for (a, b) in lb.iter().zip(&inproc) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_eq!(a.transport, "loopback");
        }
        let dir = std::env::temp_dir().join("ebc_shard_bench_test");
        let path = save_shard_json(&dir.join("BENCH_shard.json"), &lb_cfg, &lb).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(parsed.get("transport").unwrap().as_str(), Some("loopback"));
        let pts = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].get("wire_bytes").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn sweep_rejects_unknown_names() {
        let mut rng = Rng::new(2);
        let data = Arc::new(Matrix::random_normal(10, 3, &mut rng));
        let bad_alg = ShardSweepConfig {
            algorithms: vec!["magic".into()],
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory(), &bad_alg, None).is_err());
        let bad_part = ShardSweepConfig {
            partitioner: "psychic".into(),
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory(), &bad_part, None).is_err());
        let bad_transport = ShardSweepConfig {
            transport: "telepathy".into(),
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory(), &bad_transport, None).is_err());
    }
}
