//! Scaling harness for the shard subsystem: sweep shard counts ×
//! optimizers over one dataset and account wall-clock + quality against
//! the single-node run — optionally under a fleet [`ShardPlan`]
//! (planned worker × kernel-thread split + shared engine buckets).
//! Shared by the `shard-bench` CLI subcommand and the `shard_scaling`
//! bench target.

use crate::engine::{PlanRequest, ShardPlan};
use crate::linalg::SharedMatrix;
use crate::optim::build_optimizer;
use crate::shard::{build_partitioner, ShardOracleFactory, ShardedSummarizer};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Plan-builder seam for the sweep: the XLA backend's variant consults
/// the artifact manifest, the CPU one plans the thread split only.
pub type SweepPlanner<'a> = &'a (dyn Fn(&PlanRequest) -> Arc<ShardPlan> + Sync);

/// One (optimizer, shard-count) measurement.
#[derive(Debug, Clone)]
pub struct ShardScalingPoint {
    pub algorithm: String,
    pub shards: usize,
    pub shards_used: usize,
    /// Wall-clock of the parallel per-shard stage.
    pub shard_seconds: f64,
    pub merge_seconds: f64,
    pub total_seconds: f64,
    /// Single-node wall-clock of the same optimizer (the P-independent
    /// reference, measured once per algorithm).
    pub single_seconds: f64,
    pub f_merged: f32,
    pub f_single: f32,
    /// f_merged / f_single.
    pub quality_ratio: f64,
    /// single_seconds / total_seconds.
    pub speedup: f64,
    /// Planned worker × thread split label (`-` for unplanned runs).
    pub plan: String,
}

/// Sweep settings.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    pub k: usize,
    pub shard_counts: Vec<usize>,
    pub algorithms: Vec<String>,
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto); ignored for
    /// planned runs (the plan's split wins).
    pub threads: usize,
    pub seed: u64,
    /// Core budget handed to the planner (0 = auto).
    pub cores: usize,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            k: 10,
            shard_counts: vec![1, 2, 4, 8],
            algorithms: vec!["greedy".into()],
            partitioner: "round_robin".into(),
            threads: 0,
            seed: 0xEBC,
            cores: 0,
        }
    }
}

/// Run the sweep. The baseline per algorithm is taken from the P = 1
/// point's reference run, so every row's `speedup` compares against the
/// same single-node measurement. With a `planner`, every P gets a fleet
/// plan (reported per row via `plan`).
pub fn shard_scaling_sweep(
    data: &SharedMatrix,
    factory: &ShardOracleFactory,
    cfg: &ShardSweepConfig,
    planner: Option<SweepPlanner>,
) -> Result<Vec<ShardScalingPoint>> {
    let partitioner = build_partitioner(&cfg.partitioner, cfg.seed)
        .ok_or_else(|| anyhow!("unknown partitioner '{}'", cfg.partitioner))?;
    let mut out = Vec::new();
    for alg in &cfg.algorithms {
        let optimizer = build_optimizer(alg, 1024)
            .ok_or_else(|| anyhow!("unknown algorithm '{alg}'"))?;
        let mut single: Option<(f64, f32)> = None; // (seconds, f)
        for &p in &cfg.shard_counts {
            let mut s = ShardedSummarizer::new(partitioner.as_ref(), optimizer.as_ref(), p);
            s.threads = cfg.threads;
            let plan_label = match planner {
                Some(build) => {
                    let mut req = PlanRequest::new(data.rows(), data.cols(), p, cfg.k);
                    req.cores = cfg.cores;
                    let plan = build(&req);
                    let label = plan.split_label();
                    s.plan = Some(plan);
                    label
                }
                None => "-".to_string(),
            };
            let res = if single.is_none() {
                let r = s.summarize_with_baseline(data, factory, cfg.k);
                let b = r.baseline.as_ref().expect("baseline requested");
                single = Some((b.wall_seconds, b.f_final));
                r
            } else {
                s.summarize(data, factory, cfg.k)
            };
            let (single_seconds, f_single) = single.expect("baseline set");
            let total = res.total_seconds();
            out.push(ShardScalingPoint {
                algorithm: alg.clone(),
                shards: p,
                shards_used: res.shards_used,
                shard_seconds: res.shard_seconds,
                merge_seconds: res.merge_seconds,
                total_seconds: total,
                single_seconds,
                f_merged: res.merged.f_final,
                f_single,
                quality_ratio: if f_single <= 0.0 {
                    1.0
                } else {
                    res.merged.f_final as f64 / f_single as f64
                },
                speedup: if total > 0.0 { single_seconds / total } else { 0.0 },
                plan: plan_label,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OracleSpec;
    use crate::linalg::Matrix;
    use crate::submodular::{CpuOracle, Oracle};
    use crate::util::rng::Rng;

    fn factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    }

    #[test]
    fn sweep_produces_one_point_per_cell() {
        let mut rng = Rng::new(1);
        let data = Arc::new(Matrix::random_normal(80, 6, &mut rng));
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 2],
            algorithms: vec!["greedy".into(), "stochastic_greedy".into()],
            ..Default::default()
        };
        let points = shard_scaling_sweep(&data, &factory(), &cfg, None).unwrap();
        assert_eq!(points.len(), 4);
        for pt in &points {
            assert!(pt.total_seconds > 0.0);
            assert!(pt.quality_ratio > 0.5, "{pt:?}");
            assert_eq!(pt.plan, "-");
        }
        // P = 1 greedy is exactly the single-node run
        let p1 = &points[0];
        assert_eq!(p1.shards, 1);
        assert_eq!(p1.f_merged.to_bits(), p1.f_single.to_bits());
    }

    #[test]
    fn planned_sweep_matches_unplanned_selection() {
        let mut rng = Rng::new(5);
        let data = Arc::new(Matrix::random_normal(60, 5, &mut rng));
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 3],
            cores: 4,
            ..Default::default()
        };
        let unplanned = shard_scaling_sweep(&data, &factory(), &cfg, None).unwrap();
        let planner = |req: &PlanRequest| Arc::new(ShardPlan::plan(None, req));
        let planned = shard_scaling_sweep(&data, &factory(), &cfg, Some(&planner)).unwrap();
        assert_eq!(planned.len(), unplanned.len());
        for (a, b) in planned.iter().zip(&unplanned) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_ne!(a.plan, "-");
        }
        assert_eq!(planned[1].plan, "3w x 1t");
    }

    #[test]
    fn sweep_rejects_unknown_names() {
        let mut rng = Rng::new(2);
        let data = Arc::new(Matrix::random_normal(10, 3, &mut rng));
        let bad_alg = ShardSweepConfig {
            algorithms: vec!["magic".into()],
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory(), &bad_alg, None).is_err());
        let bad_part = ShardSweepConfig {
            partitioner: "psychic".into(),
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory(), &bad_part, None).is_err());
    }
}
