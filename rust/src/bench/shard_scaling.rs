//! Scaling harness for the shard subsystem: sweep shard counts ×
//! optimizers over one dataset and account wall-clock + quality against
//! the single-node run. Every measurement routes through the
//! [`crate::api`] façade — the sweep builds one [`SummarizeRequest`]
//! per (optimizer, P) cell and reads timings, wire traffic and plan
//! labels from the response's [`crate::api::Provenance`]. Shared by the
//! `shard-bench` CLI subcommand and the `shard_scaling` bench target.

use crate::api::{ApiError, DatasetRef, Service, ShardSpec, SummarizeRequest};
use crate::linalg::CpuKernel;
use crate::util::json::{Json, ObjBuilder};
use std::path::{Path, PathBuf};

/// One (optimizer, shard-count) measurement.
#[derive(Debug, Clone)]
pub struct ShardScalingPoint {
    pub algorithm: String,
    pub shards: usize,
    pub shards_used: usize,
    /// Wall-clock of the parallel per-shard stage.
    pub shard_seconds: f64,
    pub merge_seconds: f64,
    pub total_seconds: f64,
    /// Single-node wall-clock of the same optimizer (the P-independent
    /// reference, measured once per algorithm).
    pub single_seconds: f64,
    pub f_merged: f32,
    pub f_single: f32,
    /// f_merged / f_single.
    pub quality_ratio: f64,
    /// single_seconds / total_seconds.
    pub speedup: f64,
    /// Planned worker × thread split label (`-` for unplanned runs).
    pub plan: String,
    /// Transport the first stage ran over (`inproc` | `loopback`).
    pub transport: String,
    /// Wire bytes this measurement moved (job + result frames).
    pub wire_bytes: u64,
    /// Shards re-queued after replica failures during this measurement.
    pub shard_retries: u64,
}

/// Sweep settings — everything needed to derive the per-cell
/// [`SummarizeRequest`]s.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    pub k: usize,
    pub shard_counts: Vec<usize>,
    pub algorithms: Vec<String>,
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto); ignored for
    /// planned runs (the plan's split wins).
    pub threads: usize,
    pub seed: u64,
    /// Pre-plan every P (shared bucket shape + P·T ≤ cores split).
    pub planned: bool,
    /// Core budget handed to the planner (0 = auto).
    pub cores: usize,
    /// Shard-stage transport ([`crate::shard::TRANSPORTS`]).
    pub transport: String,
    /// Replica count for the `loopback` transport.
    pub replicas: usize,
    /// Endpoints/deadlines/retry knobs for the `tcp` transport
    /// (ignored by the in-process transports).
    pub net: crate::shard::NetOptions,
    /// CPU kernel backend the oracles run on.
    pub cpu_kernel: CpuKernel,
    /// Per-oracle kernel threads (0 = auto).
    pub oracle_threads: usize,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            k: 10,
            shard_counts: vec![1, 2, 4, 8],
            algorithms: vec!["greedy".into()],
            partitioner: "round_robin".into(),
            threads: 0,
            seed: 0xEBC,
            planned: false,
            cores: 0,
            transport: "inproc".into(),
            replicas: 2,
            net: crate::shard::NetOptions::default(),
            cpu_kernel: CpuKernel::Scalar,
            oracle_threads: 1,
        }
    }
}

impl ShardSweepConfig {
    /// The api request for one (algorithm, P) sweep cell.
    /// `with_baseline` is set on the first cell of each algorithm so
    /// every row compares against the same single-node measurement.
    pub fn request(
        &self,
        dataset: &DatasetRef,
        algorithm: &str,
        shards: usize,
        with_baseline: bool,
    ) -> SummarizeRequest {
        SummarizeRequest::new(dataset.clone(), self.k)
            .optimizer(algorithm)
            .cpu_kernel(self.cpu_kernel)
            .threads(self.oracle_threads)
            .seed(self.seed)
            .with_baseline(with_baseline)
            .sharded(
                ShardSpec::new(shards)
                    .partitioner(&self.partitioner)
                    .threads(self.threads)
                    .transport(&self.transport)
                    .replicas(self.replicas)
                    .net(self.net.clone())
                    .plan(self.planned)
                    .cores(self.cores),
            )
    }
}

/// Run the sweep through the façade. The baseline per algorithm is
/// taken from the P = first point's reference run, so every row's
/// `speedup` compares against the same single-node measurement.
/// Invalid names (algorithm / partitioner / transport) surface as
/// typed [`ApiError`]s from request validation.
pub fn shard_scaling_sweep(
    service: &Service,
    dataset: &DatasetRef,
    cfg: &ShardSweepConfig,
) -> Result<Vec<ShardScalingPoint>, ApiError> {
    let mut out = Vec::new();
    for alg in &cfg.algorithms {
        let mut single: Option<(f64, f32)> = None; // (seconds, f)
        for &p in &cfg.shard_counts {
            let req = cfg.request(dataset, alg, p, single.is_none());
            let resp = service.summarize(&req)?;
            if let Some(b) = &resp.baseline {
                single = Some((b.wall_seconds, b.f_final));
            }
            let (single_seconds, f_single) =
                single.expect("first cell runs with_baseline");
            let total = resp.timings.wall_seconds;
            out.push(ShardScalingPoint {
                algorithm: alg.clone(),
                shards: p,
                shards_used: resp.provenance.shards_used,
                shard_seconds: resp.timings.shard_seconds,
                merge_seconds: resp.timings.merge_seconds,
                total_seconds: total,
                single_seconds,
                f_merged: resp.f_final,
                f_single,
                quality_ratio: if f_single <= 0.0 {
                    1.0
                } else {
                    resp.f_final as f64 / f_single as f64
                },
                speedup: if total > 0.0 { single_seconds / total } else { 0.0 },
                plan: resp.provenance.plan_split.clone().unwrap_or_else(|| "-".into()),
                transport: resp
                    .provenance
                    .transport
                    .map(str::to_string)
                    .unwrap_or_else(|| "-".into()),
                wire_bytes: resp.provenance.wire_bytes,
                shard_retries: resp.provenance.shard_retries,
            });
        }
    }
    Ok(out)
}

/// Persist a sweep as `BENCH_shard.json` (the artifact the CI bench
/// job uploads): the sweep config + one record per measurement,
/// including the transport column and its wire-traffic counters.
pub fn save_shard_json(
    path: &Path,
    cfg: &ShardSweepConfig,
    points: &[ShardScalingPoint],
) -> crate::Result<PathBuf> {
    let records: Vec<Json> = points
        .iter()
        .map(|p| {
            ObjBuilder::new()
                .str("algorithm", p.algorithm.clone())
                .int("shards", p.shards)
                .int("shards_used", p.shards_used)
                .num("shard_seconds", p.shard_seconds)
                .num("merge_seconds", p.merge_seconds)
                .num("total_seconds", p.total_seconds)
                .num("single_seconds", p.single_seconds)
                .num("f_merged", p.f_merged as f64)
                .num("f_single", p.f_single as f64)
                .num("quality_ratio", p.quality_ratio)
                .num("speedup", p.speedup)
                .str("plan", p.plan.clone())
                .str("transport", p.transport.clone())
                .int("wire_bytes", p.wire_bytes as usize)
                .int("shard_retries", p.shard_retries as usize)
                .build()
        })
        .collect();
    let doc = ObjBuilder::new()
        .str("bench", "shard_scaling")
        .int("k", cfg.k)
        .str("partitioner", cfg.partitioner.clone())
        .str("transport", cfg.transport.clone())
        .int("replicas", cfg.replicas)
        .int("seed", cfg.seed as usize)
        .val("points", Json::Arr(records))
        // process-wide latency histograms accumulated during the sweep
        // (merge / wire encode+decode / kernel families with p50/p99)
        .val(
            "obs",
            crate::obs::expo::render_json(&crate::obs::global().registry.snapshot()),
        )
        .build();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.dump())?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn dataset(n: usize, d: usize, seed: u64) -> DatasetRef {
        let mut rng = Rng::new(seed);
        DatasetRef::Inline(Arc::new(Matrix::random_normal(n, d, &mut rng)))
    }

    #[test]
    fn sweep_produces_one_point_per_cell() {
        let ds = dataset(80, 6, 1);
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 2],
            algorithms: vec!["greedy".into(), "stochastic_greedy".into()],
            ..Default::default()
        };
        let points = shard_scaling_sweep(&Service::cpu(), &ds, &cfg).unwrap();
        assert_eq!(points.len(), 4);
        for pt in &points {
            assert!(pt.total_seconds > 0.0);
            assert!(pt.quality_ratio > 0.5, "{pt:?}");
            assert_eq!(pt.plan, "-");
            assert_eq!(pt.transport, "inproc");
            assert!(pt.wire_bytes > 0);
            assert_eq!(pt.shard_retries, 0);
        }
        // P = 1 greedy is exactly the single-node run
        let p1 = &points[0];
        assert_eq!(p1.shards, 1);
        assert_eq!(p1.f_merged.to_bits(), p1.f_single.to_bits());
    }

    #[test]
    fn planned_sweep_matches_unplanned_selection() {
        let ds = dataset(60, 5, 5);
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 3],
            cores: 4,
            ..Default::default()
        };
        let service = Service::cpu();
        let unplanned = shard_scaling_sweep(&service, &ds, &cfg).unwrap();
        let planned_cfg = ShardSweepConfig { planned: true, ..cfg };
        let planned = shard_scaling_sweep(&service, &ds, &planned_cfg).unwrap();
        assert_eq!(planned.len(), unplanned.len());
        for (a, b) in planned.iter().zip(&unplanned) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_ne!(a.plan, "-");
        }
        assert_eq!(planned[1].plan, "3w x 1t");
    }

    #[test]
    fn loopback_sweep_matches_inproc_and_exports_json() {
        let ds = dataset(50, 4, 9);
        let cfg = ShardSweepConfig {
            k: 3,
            shard_counts: vec![1, 3],
            ..Default::default()
        };
        let service = Service::cpu();
        let inproc = shard_scaling_sweep(&service, &ds, &cfg).unwrap();
        let lb_cfg = ShardSweepConfig {
            transport: "loopback".into(),
            replicas: 3,
            ..cfg.clone()
        };
        let lb = shard_scaling_sweep(&service, &ds, &lb_cfg).unwrap();
        assert_eq!(lb.len(), inproc.len());
        for (a, b) in lb.iter().zip(&inproc) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_eq!(a.transport, "loopback");
        }
        let dir = std::env::temp_dir().join("ebc_shard_bench_test");
        let path = save_shard_json(&dir.join("BENCH_shard.json"), &lb_cfg, &lb).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(parsed.get("transport").unwrap().as_str(), Some("loopback"));
        let pts = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].get("wire_bytes").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn sweep_rejects_unknown_names_with_typed_errors() {
        let ds = dataset(10, 3, 2);
        let service = Service::cpu();
        let bad_alg = ShardSweepConfig {
            algorithms: vec!["magic".into()],
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_alg),
            Err(ApiError::UnknownName { field: "optimizer", .. })
        ));
        let bad_part = ShardSweepConfig {
            partitioner: "psychic".into(),
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_part),
            Err(ApiError::UnknownName { field: "shard.partitioner", .. })
        ));
        let bad_transport = ShardSweepConfig {
            transport: "telepathy".into(),
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_transport),
            Err(ApiError::UnknownName { field: "shard.transport", .. })
        ));
    }
}
