//! Scaling harness for the shard subsystem: sweep shard counts ×
//! optimizers over one dataset and account wall-clock + quality against
//! the single-node run. Shared by the `shard-bench` CLI subcommand and
//! the `shard_scaling` bench target.

use crate::linalg::Matrix;
use crate::optim::build_optimizer;
use crate::shard::{build_partitioner, ShardOracleFactory, ShardedSummarizer};
use anyhow::{anyhow, Result};

/// One (optimizer, shard-count) measurement.
#[derive(Debug, Clone)]
pub struct ShardScalingPoint {
    pub algorithm: String,
    pub shards: usize,
    pub shards_used: usize,
    /// Wall-clock of the parallel per-shard stage.
    pub shard_seconds: f64,
    pub merge_seconds: f64,
    pub total_seconds: f64,
    /// Single-node wall-clock of the same optimizer (the P-independent
    /// reference, measured once per algorithm).
    pub single_seconds: f64,
    pub f_merged: f32,
    pub f_single: f32,
    /// f_merged / f_single.
    pub quality_ratio: f64,
    /// single_seconds / total_seconds.
    pub speedup: f64,
}

/// Sweep settings.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    pub k: usize,
    pub shard_counts: Vec<usize>,
    pub algorithms: Vec<String>,
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto).
    pub threads: usize,
    pub seed: u64,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            k: 10,
            shard_counts: vec![1, 2, 4, 8],
            algorithms: vec!["greedy".into()],
            partitioner: "round_robin".into(),
            threads: 0,
            seed: 0xEBC,
        }
    }
}

/// Run the sweep. The baseline per algorithm is taken from the P = 1
/// point's reference run, so every row's `speedup` compares against the
/// same single-node measurement.
pub fn shard_scaling_sweep(
    data: &Matrix,
    factory: &ShardOracleFactory,
    cfg: &ShardSweepConfig,
) -> Result<Vec<ShardScalingPoint>> {
    let partitioner = build_partitioner(&cfg.partitioner, cfg.seed)
        .ok_or_else(|| anyhow!("unknown partitioner '{}'", cfg.partitioner))?;
    let mut out = Vec::new();
    for alg in &cfg.algorithms {
        let optimizer = build_optimizer(alg, 1024)
            .ok_or_else(|| anyhow!("unknown algorithm '{alg}'"))?;
        let mut single: Option<(f64, f32)> = None; // (seconds, f)
        for &p in &cfg.shard_counts {
            let mut s = ShardedSummarizer::new(partitioner.as_ref(), optimizer.as_ref(), p);
            s.threads = cfg.threads;
            let res = if single.is_none() {
                let r = s.summarize_with_baseline(data, factory, cfg.k);
                let b = r.baseline.as_ref().expect("baseline requested");
                single = Some((b.wall_seconds, b.f_final));
                r
            } else {
                s.summarize(data, factory, cfg.k)
            };
            let (single_seconds, f_single) = single.expect("baseline set");
            let total = res.total_seconds();
            out.push(ShardScalingPoint {
                algorithm: alg.clone(),
                shards: p,
                shards_used: res.shards_used,
                shard_seconds: res.shard_seconds,
                merge_seconds: res.merge_seconds,
                total_seconds: total,
                single_seconds,
                f_merged: res.merged.f_final,
                f_single,
                quality_ratio: if f_single <= 0.0 {
                    1.0
                } else {
                    res.merged.f_final as f64 / f_single as f64
                },
                speedup: if total > 0.0 { single_seconds / total } else { 0.0 },
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::{CpuOracle, Oracle};
    use crate::util::rng::Rng;

    #[test]
    fn sweep_produces_one_point_per_cell() {
        let mut rng = Rng::new(1);
        let data = Matrix::random_normal(80, 6, &mut rng);
        let factory = |m: Matrix| Box::new(CpuOracle::new(m)) as Box<dyn Oracle>;
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 2],
            algorithms: vec!["greedy".into(), "stochastic_greedy".into()],
            ..Default::default()
        };
        let points = shard_scaling_sweep(&data, &factory, &cfg).unwrap();
        assert_eq!(points.len(), 4);
        for pt in &points {
            assert!(pt.total_seconds > 0.0);
            assert!(pt.quality_ratio > 0.5, "{pt:?}");
        }
        // P = 1 greedy is exactly the single-node run
        let p1 = &points[0];
        assert_eq!(p1.shards, 1);
        assert_eq!(p1.f_merged.to_bits(), p1.f_single.to_bits());
    }

    #[test]
    fn sweep_rejects_unknown_names() {
        let mut rng = Rng::new(2);
        let data = Matrix::random_normal(10, 3, &mut rng);
        let factory = |m: Matrix| Box::new(CpuOracle::new(m)) as Box<dyn Oracle>;
        let bad_alg = ShardSweepConfig {
            algorithms: vec!["magic".into()],
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory, &bad_alg).is_err());
        let bad_part = ShardSweepConfig {
            partitioner: "psychic".into(),
            ..Default::default()
        };
        assert!(shard_scaling_sweep(&data, &factory, &bad_part).is_err());
    }
}
