//! Scaling harness for the shard subsystem: sweep shard counts ×
//! optimizers over one dataset and account wall-clock + quality against
//! the single-node run. Every measurement routes through the
//! [`crate::api`] façade — the sweep builds one [`SummarizeRequest`]
//! per (optimizer, P) cell and reads timings, wire traffic and plan
//! labels from the response's [`crate::api::Provenance`]. Shared by the
//! `shard-bench` CLI subcommand and the `shard_scaling` bench target.

use crate::api::{ApiError, DatasetRef, Service, ShardSpec, SummarizeRequest};
use crate::linalg::CpuKernel;
use crate::util::json::{Json, ObjBuilder};
use std::path::{Path, PathBuf};

/// One (optimizer, shard-count) measurement.
#[derive(Debug, Clone)]
pub struct ShardScalingPoint {
    pub algorithm: String,
    pub shards: usize,
    pub shards_used: usize,
    /// Wall-clock of the parallel per-shard stage.
    pub shard_seconds: f64,
    pub merge_seconds: f64,
    pub total_seconds: f64,
    /// Single-node wall-clock of the same optimizer (the P-independent
    /// reference, measured once per algorithm).
    pub single_seconds: f64,
    pub f_merged: f32,
    pub f_single: f32,
    /// f_merged / f_single.
    pub quality_ratio: f64,
    /// single_seconds / total_seconds.
    pub speedup: f64,
    /// Planned worker × thread split label (`-` for unplanned runs).
    pub plan: String,
    /// Transport the first stage ran over (`inproc` | `loopback`).
    pub transport: String,
    /// Wire bytes this measurement moved (job + result frames).
    pub wire_bytes: u64,
    /// Shards re-queued after replica failures during this measurement.
    pub shard_retries: u64,
}

/// One (prune-rate, shard-count) measurement from the prune sweep.
/// The reference cell for each P is the same request at rate 0 (exact
/// flat two-stage), so `quality_ratio` isolates what pruning costs at
/// a fixed shard topology.
#[derive(Debug, Clone)]
pub struct PruneSweepPoint {
    pub rate: f64,
    pub shards: usize,
    /// Ground rows dropped by the coordinator-side prune stage.
    pub pruned_n: usize,
    /// Wall-clock of the prune stage alone.
    pub prune_seconds: f64,
    /// Merge-tree depth (1 = flat single merge).
    pub merge_depth: usize,
    pub total_seconds: f64,
    pub f_pruned: f32,
    /// Same cell with pruning off (the exact two-stage reference).
    pub f_exact: f32,
    /// f_pruned / f_exact.
    pub quality_ratio: f64,
}

/// Sweep settings — everything needed to derive the per-cell
/// [`SummarizeRequest`]s.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    pub k: usize,
    pub shard_counts: Vec<usize>,
    pub algorithms: Vec<String>,
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto); ignored for
    /// planned runs (the plan's split wins).
    pub threads: usize,
    pub seed: u64,
    /// Pre-plan every P (shared bucket shape + P·T ≤ cores split).
    pub planned: bool,
    /// Core budget handed to the planner (0 = auto).
    pub cores: usize,
    /// Shard-stage transport ([`crate::shard::TRANSPORTS`]).
    pub transport: String,
    /// Replica count for the `loopback` transport.
    pub replicas: usize,
    /// Endpoints/deadlines/retry knobs for the `tcp` transport
    /// (ignored by the in-process transports).
    pub net: crate::shard::NetOptions,
    /// CPU kernel backend the oracles run on.
    pub cpu_kernel: CpuKernel,
    /// Per-oracle kernel threads (0 = auto).
    pub oracle_threads: usize,
    /// Prune rates for [`prune_scaling_sweep`] (empty = skip the
    /// prune section; rate 0 cells reuse the exact reference).
    pub prune_rates: Vec<f64>,
    /// Merge-tree fanout for prune-sweep cells (0 = flat merge).
    pub fanout: usize,
    /// Per-merge-node ground cap for prune-sweep cells (0 = off).
    pub max_merge_n: usize,
    /// Optimizer run at coordinator merge nodes (`greedy` = the exact
    /// lazy path used everywhere else).
    pub merge_optimizer: String,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            k: 10,
            shard_counts: vec![1, 2, 4, 8],
            algorithms: vec!["greedy".into()],
            partitioner: "round_robin".into(),
            threads: 0,
            seed: 0xEBC,
            planned: false,
            cores: 0,
            transport: "inproc".into(),
            replicas: 2,
            net: crate::shard::NetOptions::default(),
            cpu_kernel: CpuKernel::Scalar,
            oracle_threads: 1,
            prune_rates: Vec::new(),
            fanout: 0,
            max_merge_n: 0,
            merge_optimizer: "greedy".into(),
        }
    }
}

impl ShardSweepConfig {
    /// The api request for one (algorithm, P) sweep cell.
    /// `with_baseline` is set on the first cell of each algorithm so
    /// every row compares against the same single-node measurement.
    pub fn request(
        &self,
        dataset: &DatasetRef,
        algorithm: &str,
        shards: usize,
        with_baseline: bool,
    ) -> SummarizeRequest {
        SummarizeRequest::new(dataset.clone(), self.k)
            .optimizer(algorithm)
            .cpu_kernel(self.cpu_kernel)
            .threads(self.oracle_threads)
            .seed(self.seed)
            .with_baseline(with_baseline)
            .sharded(
                ShardSpec::new(shards)
                    .partitioner(&self.partitioner)
                    .threads(self.threads)
                    .transport(&self.transport)
                    .replicas(self.replicas)
                    .net(self.net.clone())
                    .plan(self.planned)
                    .cores(self.cores),
            )
    }

    /// The api request for one prune-sweep cell: the same two-stage
    /// request as [`Self::request`] with the coordinator-side prune
    /// knobs engaged at `rate` (0.0 composes back to the exact flat
    /// path when fanout/cap are also off).
    pub fn pruned_request(
        &self,
        dataset: &DatasetRef,
        algorithm: &str,
        shards: usize,
        rate: f64,
    ) -> SummarizeRequest {
        SummarizeRequest::new(dataset.clone(), self.k)
            .optimizer(algorithm)
            .cpu_kernel(self.cpu_kernel)
            .threads(self.oracle_threads)
            .seed(self.seed)
            .sharded(
                ShardSpec::new(shards)
                    .partitioner(&self.partitioner)
                    .threads(self.threads)
                    .transport(&self.transport)
                    .replicas(self.replicas)
                    .net(self.net.clone())
                    .plan(self.planned)
                    .cores(self.cores)
                    .prune(rate)
                    .fanout(self.fanout)
                    .max_merge_n(self.max_merge_n)
                    .merge_optimizer(&self.merge_optimizer),
            )
    }
}

/// Sweep prune-rate × P through the façade. The first algorithm in
/// the config is used for every cell; each P first runs the rate-0
/// reference so `quality_ratio` compares pruned selections against
/// the exact merge at the same topology.
pub fn prune_scaling_sweep(
    service: &Service,
    dataset: &DatasetRef,
    cfg: &ShardSweepConfig,
) -> Result<Vec<PruneSweepPoint>, ApiError> {
    let alg = cfg.algorithms.first().map(String::as_str).unwrap_or("greedy");
    let mut out = Vec::new();
    for &p in &cfg.shard_counts {
        let exact = service.summarize(&cfg.pruned_request(dataset, alg, p, 0.0))?;
        let f_exact = exact.f_final;
        for &rate in &cfg.prune_rates {
            let pruned;
            let resp = if rate > 0.0 {
                pruned = service.summarize(&cfg.pruned_request(dataset, alg, p, rate))?;
                &pruned
            } else {
                &exact
            };
            out.push(PruneSweepPoint {
                rate,
                shards: p,
                pruned_n: resp.provenance.pruned_n,
                prune_seconds: resp.provenance.prune_seconds,
                merge_depth: resp.provenance.merge_depth,
                total_seconds: resp.timings.wall_seconds,
                f_pruned: resp.f_final,
                f_exact,
                quality_ratio: if f_exact <= 0.0 {
                    1.0
                } else {
                    resp.f_final as f64 / f_exact as f64
                },
            });
        }
    }
    Ok(out)
}

/// Run the sweep through the façade. The baseline per algorithm is
/// taken from the P = first point's reference run, so every row's
/// `speedup` compares against the same single-node measurement.
/// Invalid names (algorithm / partitioner / transport) surface as
/// typed [`ApiError`]s from request validation.
pub fn shard_scaling_sweep(
    service: &Service,
    dataset: &DatasetRef,
    cfg: &ShardSweepConfig,
) -> Result<Vec<ShardScalingPoint>, ApiError> {
    let mut out = Vec::new();
    for alg in &cfg.algorithms {
        let mut single: Option<(f64, f32)> = None; // (seconds, f)
        for &p in &cfg.shard_counts {
            let req = cfg.request(dataset, alg, p, single.is_none());
            let resp = service.summarize(&req)?;
            if let Some(b) = &resp.baseline {
                single = Some((b.wall_seconds, b.f_final));
            }
            let (single_seconds, f_single) =
                single.expect("first cell runs with_baseline");
            let total = resp.timings.wall_seconds;
            out.push(ShardScalingPoint {
                algorithm: alg.clone(),
                shards: p,
                shards_used: resp.provenance.shards_used,
                shard_seconds: resp.timings.shard_seconds,
                merge_seconds: resp.timings.merge_seconds,
                total_seconds: total,
                single_seconds,
                f_merged: resp.f_final,
                f_single,
                quality_ratio: if f_single <= 0.0 {
                    1.0
                } else {
                    resp.f_final as f64 / f_single as f64
                },
                speedup: if total > 0.0 { single_seconds / total } else { 0.0 },
                plan: resp.provenance.plan_split.clone().unwrap_or_else(|| "-".into()),
                transport: resp
                    .provenance
                    .transport
                    .map(str::to_string)
                    .unwrap_or_else(|| "-".into()),
                wire_bytes: resp.provenance.wire_bytes,
                shard_retries: resp.provenance.shard_retries,
            });
        }
    }
    Ok(out)
}

/// Persist a sweep as `BENCH_shard.json` (the artifact the CI bench
/// job uploads): the sweep config + one record per measurement,
/// including the transport column and its wire-traffic counters.
/// `prune` holds the optional prune-sweep section (empty = the sweep
/// was skipped; the `prune` key is still written so consumers can
/// rely on its presence).
pub fn save_shard_json(
    path: &Path,
    cfg: &ShardSweepConfig,
    points: &[ShardScalingPoint],
    prune: &[PruneSweepPoint],
) -> crate::Result<PathBuf> {
    let records: Vec<Json> = points
        .iter()
        .map(|p| {
            ObjBuilder::new()
                .str("algorithm", p.algorithm.clone())
                .int("shards", p.shards)
                .int("shards_used", p.shards_used)
                .num("shard_seconds", p.shard_seconds)
                .num("merge_seconds", p.merge_seconds)
                .num("total_seconds", p.total_seconds)
                .num("single_seconds", p.single_seconds)
                .num("f_merged", p.f_merged as f64)
                .num("f_single", p.f_single as f64)
                .num("quality_ratio", p.quality_ratio)
                .num("speedup", p.speedup)
                .str("plan", p.plan.clone())
                .str("transport", p.transport.clone())
                .int("wire_bytes", p.wire_bytes as usize)
                .int("shard_retries", p.shard_retries as usize)
                .build()
        })
        .collect();
    let prune_records: Vec<Json> = prune
        .iter()
        .map(|p| {
            ObjBuilder::new()
                .num("rate", p.rate)
                .int("shards", p.shards)
                .int("pruned_n", p.pruned_n)
                .num("prune_seconds", p.prune_seconds)
                .int("merge_depth", p.merge_depth)
                .num("total_seconds", p.total_seconds)
                .num("f_pruned", p.f_pruned as f64)
                .num("f_exact", p.f_exact as f64)
                .num("quality_ratio", p.quality_ratio)
                .build()
        })
        .collect();
    let doc = ObjBuilder::new()
        .str("bench", "shard_scaling")
        .int("k", cfg.k)
        .str("partitioner", cfg.partitioner.clone())
        .str("transport", cfg.transport.clone())
        .int("replicas", cfg.replicas)
        .int("seed", cfg.seed as usize)
        .int("fanout", cfg.fanout)
        .int("max_merge_n", cfg.max_merge_n)
        .str("merge_optimizer", cfg.merge_optimizer.clone())
        .val("points", Json::Arr(records))
        .val("prune", Json::Arr(prune_records))
        // process-wide latency histograms accumulated during the sweep
        // (merge / wire encode+decode / kernel families with p50/p99)
        .val(
            "obs",
            crate::obs::expo::render_json(&crate::obs::global().registry.snapshot()),
        )
        .build();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.dump())?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn dataset(n: usize, d: usize, seed: u64) -> DatasetRef {
        let mut rng = Rng::new(seed);
        DatasetRef::Inline(Arc::new(Matrix::random_normal(n, d, &mut rng)))
    }

    #[test]
    fn sweep_produces_one_point_per_cell() {
        let ds = dataset(80, 6, 1);
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 2],
            algorithms: vec!["greedy".into(), "stochastic_greedy".into()],
            ..Default::default()
        };
        let points = shard_scaling_sweep(&Service::cpu(), &ds, &cfg).unwrap();
        assert_eq!(points.len(), 4);
        for pt in &points {
            assert!(pt.total_seconds > 0.0);
            assert!(pt.quality_ratio > 0.5, "{pt:?}");
            assert_eq!(pt.plan, "-");
            assert_eq!(pt.transport, "inproc");
            assert!(pt.wire_bytes > 0);
            assert_eq!(pt.shard_retries, 0);
        }
        // P = 1 greedy is exactly the single-node run
        let p1 = &points[0];
        assert_eq!(p1.shards, 1);
        assert_eq!(p1.f_merged.to_bits(), p1.f_single.to_bits());
    }

    #[test]
    fn planned_sweep_matches_unplanned_selection() {
        let ds = dataset(60, 5, 5);
        let cfg = ShardSweepConfig {
            k: 4,
            shard_counts: vec![1, 3],
            cores: 4,
            ..Default::default()
        };
        let service = Service::cpu();
        let unplanned = shard_scaling_sweep(&service, &ds, &cfg).unwrap();
        let planned_cfg = ShardSweepConfig { planned: true, ..cfg };
        let planned = shard_scaling_sweep(&service, &ds, &planned_cfg).unwrap();
        assert_eq!(planned.len(), unplanned.len());
        for (a, b) in planned.iter().zip(&unplanned) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_ne!(a.plan, "-");
        }
        assert_eq!(planned[1].plan, "3w x 1t");
    }

    #[test]
    fn loopback_sweep_matches_inproc_and_exports_json() {
        let ds = dataset(50, 4, 9);
        let cfg = ShardSweepConfig {
            k: 3,
            shard_counts: vec![1, 3],
            ..Default::default()
        };
        let service = Service::cpu();
        let inproc = shard_scaling_sweep(&service, &ds, &cfg).unwrap();
        let lb_cfg = ShardSweepConfig {
            transport: "loopback".into(),
            replicas: 3,
            ..cfg.clone()
        };
        let lb = shard_scaling_sweep(&service, &ds, &lb_cfg).unwrap();
        assert_eq!(lb.len(), inproc.len());
        for (a, b) in lb.iter().zip(&inproc) {
            assert_eq!(a.f_merged.to_bits(), b.f_merged.to_bits(), "P={}", a.shards);
            assert_eq!(a.transport, "loopback");
        }
        let dir = std::env::temp_dir().join("ebc_shard_bench_test");
        let path = save_shard_json(&dir.join("BENCH_shard.json"), &lb_cfg, &lb, &[]).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(parsed.get("transport").unwrap().as_str(), Some("loopback"));
        let pts = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].get("wire_bytes").unwrap().as_usize().unwrap() > 0);
        // the prune key is always present, even when the sweep is skipped
        assert!(parsed.get("prune").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn prune_sweep_reports_drops_against_exact_reference() {
        let ds = dataset(160, 6, 11);
        let cfg = ShardSweepConfig {
            k: 5,
            shard_counts: vec![4],
            prune_rates: vec![0.0, 0.5],
            fanout: 2,
            ..Default::default()
        };
        let pts = prune_scaling_sweep(&Service::cpu(), &ds, &cfg).unwrap();
        assert_eq!(pts.len(), 2);
        // the rate-0 cell IS the reference: same response, bit-equal f
        let exact = &pts[0];
        assert_eq!(exact.pruned_n, 0);
        assert_eq!(exact.f_pruned.to_bits(), exact.f_exact.to_bits());
        let pruned = &pts[1];
        assert!(pruned.pruned_n > 0 && pruned.pruned_n < 160, "{pruned:?}");
        assert!(pruned.prune_seconds > 0.0);
        assert!(pruned.merge_depth >= 1);
        assert!(pruned.quality_ratio > 0.5, "{pruned:?}");
        // exported json carries the sweep in a dedicated section
        let dir = std::env::temp_dir().join("ebc_prune_sweep_test");
        let path = save_shard_json(&dir.join("BENCH_shard.json"), &cfg, &[], &pts).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let section = parsed.get("prune").unwrap().as_arr().unwrap();
        assert_eq!(section.len(), 2);
        assert!(section[1].get("pruned_n").unwrap().as_usize().unwrap() > 0);
        assert_eq!(parsed.get("fanout").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn sweep_rejects_unknown_names_with_typed_errors() {
        let ds = dataset(10, 3, 2);
        let service = Service::cpu();
        let bad_alg = ShardSweepConfig {
            algorithms: vec!["magic".into()],
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_alg),
            Err(ApiError::UnknownName { field: "optimizer", .. })
        ));
        let bad_part = ShardSweepConfig {
            partitioner: "psychic".into(),
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_part),
            Err(ApiError::UnknownName { field: "shard.partitioner", .. })
        ));
        let bad_transport = ShardSweepConfig {
            transport: "telepathy".into(),
            ..Default::default()
        };
        assert!(matches!(
            shard_scaling_sweep(&service, &ds, &bad_transport),
            Err(ApiError::UnknownName { field: "shard.transport", .. })
        ));
    }
}
