//! Kernel-scaling harness: measure the CPU oracle hot path (`gains`,
//! `dist_col`, `eval`) across kernel backends (scalar baseline vs the
//! blocked Gram-matrix backend of [`crate::linalg::gemm`] vs its
//! explicit-SIMD variant in [`crate::linalg::simd`]), precisions
//! (f32 / software-bf16) and thread counts, against one synthetic
//! workload — plus the planned-vs-unplanned sharded CPU split
//! ([`shard_split_sweep`]): P concurrent shard workers under the
//! planner's P×T ≤ cores budget vs today's oversubscribed
//! `default_threads()`-per-worker default. Shared by the `kernel-bench`
//! CLI subcommand and the `kernel_scaling` bench target; results go to
//! `BENCH_kernel.json` so the perf trajectory is measured, not asserted.

use crate::bench::{measure, Settings};
use crate::engine::plan_cpu_split;
use crate::linalg::gemm::CpuKernel;
use crate::linalg::Matrix;
use crate::runtime::artifact::Precision;
use crate::submodular::{fold_mindist, EbcFunction};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Sweep settings: one N×d ground set, one C-wide candidate batch.
#[derive(Debug, Clone)]
pub struct KernelSweepConfig {
    pub n: usize,
    pub d: usize,
    /// Candidate-batch width for the `gains` op.
    pub c: usize,
    /// Thread counts to sweep (1 is always the scalar-ST baseline row).
    pub thread_counts: Vec<usize>,
    pub seed: u64,
}

impl Default for KernelSweepConfig {
    fn default() -> Self {
        // the acceptance workload: N=20k, d=32, C=1024
        KernelSweepConfig {
            n: 20_000,
            d: 32,
            c: 1024,
            thread_counts: vec![1, 2, 4, 8],
            seed: 7,
        }
    }
}

impl KernelSweepConfig {
    /// Derive the micro-bench workload from a validated
    /// [`crate::api::SummarizeRequest`]: the ground shape from the
    /// request's dataset reference, the candidate width from its batch
    /// — so `kernel-bench` describes its workload the same way every
    /// other entrypoint does. Inline/IMM datasets are rejected (the
    /// sweep generates its own standard-normal ground set).
    pub fn from_request(
        req: &crate::api::SummarizeRequest,
        thread_counts: Vec<usize>,
    ) -> Result<KernelSweepConfig, crate::api::ApiError> {
        req.validate()?;
        match req.dataset {
            crate::api::DatasetRef::Synthetic { n, d, seed } => Ok(KernelSweepConfig {
                n,
                d,
                c: req.batch,
                thread_counts,
                seed,
            }),
            _ => Err(crate::api::ApiError::invalid(
                "dataset",
                "kernel sweeps run on synthetic datasets (the workload is regenerated \
                 per measurement)",
            )),
        }
    }
}

/// One (op, kernel, precision, threads) measurement.
#[derive(Debug, Clone)]
pub struct KernelPoint {
    /// `gains` | `dist_col` | `eval`.
    pub op: &'static str,
    pub kernel: &'static str,
    pub precision: &'static str,
    pub threads: usize,
    pub mean_seconds: f64,
    pub min_seconds: f64,
    /// scalar-ST mean of the same op / this mean.
    pub speedup_vs_scalar_st: f64,
    /// Max absolute deviation of this variant's output from the
    /// scalar-ST reference output (numerical-drift tripwire).
    pub max_abs_dev: f64,
}

fn max_dev(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max)
}

/// Run the sweep. Rows, per op: scalar ST (the baseline), scalar MT
/// (candidate-parallel, `gains` only — the paper's MT axis), then
/// blocked and simd at both precisions and every thread count.
pub fn kernel_scaling_sweep(cfg: &KernelSweepConfig, settings: &Settings) -> Vec<KernelPoint> {
    let mut rng = Rng::new(cfg.seed);
    let data = Matrix::random_normal(cfg.n, cfg.d, &mut rng);
    let scalar = EbcFunction::new(data.clone());
    // resolve 0 = auto up front so report rows record the real width
    let thread_counts: Vec<usize> = cfg
        .thread_counts
        .iter()
        .map(|&t| if t == 0 { crate::util::threadpool::default_threads() } else { t })
        .collect();

    // a realistic optimizer state: mindist after four folded selections
    let mut mindist = scalar.vsq().to_vec();
    for j in 0..4.min(cfg.n) {
        fold_mindist(&mut mindist, &scalar.dist_col(j));
    }
    let cands = rng.sample_indices(cfg.n, cfg.c.min(cfg.n));
    let eval_set = rng.sample_indices(cfg.n, 10.min(cfg.n));
    let probe = cfg.n / 2;

    let ref_gains = scalar.gains(&mindist, &cands);
    let ref_dcol = scalar.dist_col(probe);
    let ref_eval = [scalar.eval(&eval_set)];

    let mut out: Vec<KernelPoint> = Vec::new();
    let mut base: BTreeMap<&'static str, f64> = BTreeMap::new();
    let push = |op: &'static str,
                    kernel: &'static str,
                    precision: &'static str,
                    threads: usize,
                    secs: crate::util::stats::Summary,
                    dev: f64,
                    out: &mut Vec<KernelPoint>,
                    base: &mut BTreeMap<&'static str, f64>| {
        if kernel == "scalar" && threads == 1 {
            base.insert(op, secs.mean);
        }
        let b = base.get(op).copied().unwrap_or(secs.mean);
        out.push(KernelPoint {
            op,
            kernel,
            precision,
            threads,
            mean_seconds: secs.mean,
            min_seconds: secs.min,
            speedup_vs_scalar_st: if secs.mean > 0.0 { b / secs.mean } else { 0.0 },
            max_abs_dev: dev,
        });
    };

    // ---- scalar ST baselines ----------------------------------------
    let s = measure(settings, || {
        std::hint::black_box(scalar.gains(&mindist, &cands));
    });
    push("gains", "scalar", "f32", 1, s, 0.0, &mut out, &mut base);
    let s = measure(settings, || {
        std::hint::black_box(scalar.dist_col(probe));
    });
    push("dist_col", "scalar", "f32", 1, s, 0.0, &mut out, &mut base);
    let s = measure(settings, || {
        std::hint::black_box(scalar.eval(&eval_set));
    });
    push("eval", "scalar", "f32", 1, s, 0.0, &mut out, &mut base);

    // ---- scalar MT (the paper's candidate-parallel axis) ------------
    for &t in thread_counts.iter().filter(|&&t| t > 1) {
        let dev = max_dev(&scalar.gains_mt(&mindist, &cands, t), &ref_gains);
        let s = measure(settings, || {
            std::hint::black_box(scalar.gains_mt(&mindist, &cands, t));
        });
        push("gains", "scalar", "f32", t, s, dev, &mut out, &mut base);
    }

    // ---- gemm family (blocked / simd), both precisions, ------------
    // ---- ground-parallel                                 ------------
    for &(kernel, kname) in &[(CpuKernel::Blocked, "blocked"), (CpuKernel::Simd, "simd")] {
        for &(precision, pname) in &[(Precision::F32, "f32"), (Precision::Bf16, "bf16")] {
            for &t in &thread_counts {
                let f = EbcFunction::with_kernel(data.clone(), kernel, precision, t);
                let dev = max_dev(&f.gains(&mindist, &cands), &ref_gains);
                let s = measure(settings, || {
                    std::hint::black_box(f.gains(&mindist, &cands));
                });
                push("gains", kname, pname, t, s, dev, &mut out, &mut base);

                let dev = max_dev(&f.dist_col(probe), &ref_dcol);
                let s = measure(settings, || {
                    std::hint::black_box(f.dist_col(probe));
                });
                push("dist_col", kname, pname, t, s, dev, &mut out, &mut base);

                let dev = max_dev(&[f.eval(&eval_set)], &ref_eval);
                let s = measure(settings, || {
                    std::hint::black_box(f.eval(&eval_set));
                });
                push("eval", kname, pname, t, s, dev, &mut out, &mut base);
            }
        }
    }
    out
}

/// One planned-vs-unplanned shard-split measurement: P concurrent
/// shard workers, each running blocked-f32 `gains` over its own shard,
/// once with the planner's split (P·T ≤ cores, [`plan_cpu_split`]) and
/// once with today's unplanned default (every worker ground-parallel
/// over all cores — P-fold oversubscription).
#[derive(Debug, Clone)]
pub struct SplitPoint {
    pub shards: usize,
    pub cores: usize,
    /// Concurrent workers under the plan (min(P, cores)) — shards
    /// beyond the cap run in waves, exactly like the summarizer's
    /// bounded worker pool.
    pub planned_workers: usize,
    /// Kernel threads per worker under the plan (cores / workers).
    pub planned_threads: usize,
    /// Kernel threads per worker without a plan (`default_threads()`).
    pub unplanned_threads: usize,
    pub planned_seconds: f64,
    pub unplanned_seconds: f64,
    /// unplanned / planned — the headline planned-vs-unplanned speedup.
    pub planned_speedup: f64,
}

/// Measure the sharded CPU split: for each P, run P concurrent
/// blocked-f32 `gains` workers over disjoint contiguous shards of the
/// (n, d) ground set, planned (P·T ≤ cores) vs unplanned (P × cores).
pub fn shard_split_sweep(
    cfg: &KernelSweepConfig,
    shard_counts: &[usize],
    settings: &Settings,
) -> Vec<SplitPoint> {
    let mut rng = Rng::new(cfg.seed);
    let data = Matrix::random_normal(cfg.n, cfg.d, &mut rng);
    let cores = crate::util::threadpool::default_threads();
    let mut out = Vec::new();
    for &p in shard_counts {
        let p = p.max(1).min(cfg.n.max(1));
        let rows = cfg.n.div_ceil(p);
        let shards: Vec<Vec<usize>> = (0..p)
            .map(|s| (s * rows..((s + 1) * rows).min(cfg.n)).collect())
            .filter(|part: &Vec<usize>| !part.is_empty())
            .collect();
        // one measured pass per split mode; oracles built outside the
        // timer. `max_workers` caps concurrency like the summarizer's
        // worker pool — shards beyond the cap run in waves.
        let run = |threads_per: usize, max_workers: usize| -> f64 {
            let workers: Vec<(EbcFunction, Vec<usize>)> = shards
                .iter()
                .map(|part| {
                    let f = EbcFunction::with_kernel(
                        data.gather(part),
                        CpuKernel::Blocked,
                        Precision::F32,
                        threads_per,
                    );
                    let cands: Vec<usize> = (0..cfg.c.min(part.len())).collect();
                    (f, cands)
                })
                .collect();
            measure(settings, || {
                for wave in workers.chunks(max_workers.max(1)) {
                    std::thread::scope(|scope| {
                        for (f, cands) in wave {
                            scope.spawn(move || {
                                std::hint::black_box(f.gains(f.vsq(), cands));
                            });
                        }
                    });
                }
            })
            .mean
        };
        let (planned_workers, planned_threads) = plan_cpu_split(p, cores);
        let planned_seconds = run(planned_threads, planned_workers);
        // legacy unplanned fan-out: all P at once, each cores-wide
        let unplanned_seconds = run(cores, p);
        out.push(SplitPoint {
            shards: p,
            cores,
            planned_workers,
            planned_threads,
            unplanned_threads: cores,
            planned_seconds,
            unplanned_seconds,
            planned_speedup: if planned_seconds > 0.0 {
                unplanned_seconds / planned_seconds
            } else {
                0.0
            },
        });
    }
    out
}

/// Render the shard-split comparison as a console table.
pub fn split_report(title: &str, points: &[SplitPoint]) -> crate::bench::Reporter {
    let mut rep = crate::bench::Reporter::new(
        title,
        &["P", "cores", "planned", "unplanned", "planned_s", "unplanned_s", "speedup"],
    );
    for p in points {
        rep.row(&[
            p.shards.to_string(),
            p.cores.to_string(),
            format!("{}w x {}t", p.planned_workers, p.planned_threads),
            format!("{}w x {}t", p.shards, p.unplanned_threads),
            crate::bench::report::fmt_secs(p.planned_seconds),
            crate::bench::report::fmt_secs(p.unplanned_seconds),
            format!("{:.2}x", p.planned_speedup),
        ]);
    }
    rep
}

/// Render the sweep as the shared op × kernel × threads console table —
/// one source of truth for the `kernel-bench` subcommand and the
/// `kernel_scaling` bench target.
pub fn kernel_report(title: &str, points: &[KernelPoint]) -> crate::bench::Reporter {
    let mut rep = crate::bench::Reporter::new(
        title,
        &["op", "kernel", "precision", "threads", "mean", "min", "speedup", "max_dev"],
    );
    for p in points {
        rep.row(&[
            p.op.to_string(),
            p.kernel.to_string(),
            p.precision.to_string(),
            p.threads.to_string(),
            crate::bench::report::fmt_secs(p.mean_seconds),
            crate::bench::report::fmt_secs(p.min_seconds),
            format!("{:.2}x", p.speedup_vs_scalar_st),
            format!("{:.2e}", p.max_abs_dev),
        ]);
    }
    rep
}

/// Render the sweep as the `BENCH_kernel.json` document. `splits` adds
/// the planned-vs-unplanned sharded CPU-split comparison.
pub fn bench_json(
    cfg: &KernelSweepConfig,
    points: &[KernelPoint],
    splits: &[SplitPoint],
) -> Json {
    let workload = Json::Obj(BTreeMap::from([
        ("n".to_string(), Json::Num(cfg.n as f64)),
        ("d".to_string(), Json::Num(cfg.d as f64)),
        ("c".to_string(), Json::Num(cfg.c as f64)),
        ("seed".to_string(), Json::Num(cfg.seed as f64)),
        // which vector ISA the `simd` rows actually ran on — the perf
        // gate refuses to compare simd rows across different levels
        (
            "simd_level".to_string(),
            Json::Str(crate::linalg::simd::detected().name().to_string()),
        ),
    ]));
    let pts = points
        .iter()
        .map(|p| {
            Json::Obj(BTreeMap::from([
                ("op".to_string(), Json::Str(p.op.to_string())),
                ("kernel".to_string(), Json::Str(p.kernel.to_string())),
                ("precision".to_string(), Json::Str(p.precision.to_string())),
                ("threads".to_string(), Json::Num(p.threads as f64)),
                ("mean_seconds".to_string(), Json::Num(p.mean_seconds)),
                ("min_seconds".to_string(), Json::Num(p.min_seconds)),
                (
                    "speedup_vs_scalar_st".to_string(),
                    Json::Num(p.speedup_vs_scalar_st),
                ),
                ("max_abs_dev".to_string(), Json::Num(p.max_abs_dev)),
            ]))
        })
        .collect();
    let sp = splits
        .iter()
        .map(|s| {
            Json::Obj(BTreeMap::from([
                ("shards".to_string(), Json::Num(s.shards as f64)),
                ("cores".to_string(), Json::Num(s.cores as f64)),
                ("planned_workers".to_string(), Json::Num(s.planned_workers as f64)),
                ("planned_threads".to_string(), Json::Num(s.planned_threads as f64)),
                (
                    "unplanned_threads".to_string(),
                    Json::Num(s.unplanned_threads as f64),
                ),
                ("planned_seconds".to_string(), Json::Num(s.planned_seconds)),
                ("unplanned_seconds".to_string(), Json::Num(s.unplanned_seconds)),
                ("planned_speedup".to_string(), Json::Num(s.planned_speedup)),
            ]))
        })
        .collect();
    Json::Obj(BTreeMap::from([
        ("workload".to_string(), workload),
        ("points".to_string(), Json::Arr(pts)),
        ("shard_split".to_string(), Json::Arr(sp)),
        // process-wide latency histograms accumulated during the sweep
        // (gains / gemm / engine families with p50/p90/p99)
        (
            "obs".to_string(),
            crate::obs::expo::render_json(&crate::obs::global().registry.snapshot()),
        ),
    ]))
}

/// Write `BENCH_kernel.json` (or another path) for the sweep.
pub fn save_bench_json(
    path: &std::path::Path,
    cfg: &KernelSweepConfig,
    points: &[KernelPoint],
    splits: &[SplitPoint],
) -> std::io::Result<()> {
    std::fs::write(path, bench_json(cfg, points, splits).dump())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KernelSweepConfig {
        KernelSweepConfig {
            n: 60,
            d: 9,
            c: 16,
            thread_counts: vec![1, 2],
            seed: 3,
        }
    }

    fn fast() -> Settings {
        Settings {
            warmup: 0,
            min_iters: 1,
            min_time: std::time::Duration::from_millis(0),
            max_iters: 2,
        }
    }

    #[test]
    fn sweep_covers_every_variant() {
        let cfg = tiny();
        let pts = kernel_scaling_sweep(&cfg, &fast());
        // 3 scalar-ST + 1 scalar-MT
        //   + 2 kernels × 2 precisions × 2 threads × 3 ops
        assert_eq!(pts.len(), 3 + 1 + 2 * 2 * 2 * 3);
        for p in &pts {
            assert!(p.mean_seconds >= 0.0 && p.min_seconds >= 0.0, "{p:?}");
            assert!(p.speedup_vs_scalar_st > 0.0, "{p:?}");
        }
        // gemm-family f32 stays numerically on top of the scalar reference
        for p in pts.iter().filter(|p| p.kernel != "scalar" && p.precision == "f32") {
            assert!(p.max_abs_dev <= 1e-3, "{p:?}");
        }
        // bf16 drifts, but boundedly (documented looser bound)
        for p in pts.iter().filter(|p| p.precision == "bf16") {
            assert!(p.max_abs_dev <= 1.0, "{p:?}");
        }
    }

    #[test]
    fn json_document_shape() {
        let cfg = tiny();
        let pts = kernel_scaling_sweep(&cfg, &fast());
        let splits = shard_split_sweep(&cfg, &[2], &fast());
        let doc = bench_json(&cfg, &pts, &splits);
        assert_eq!(doc.get("workload").and_then(|w| w.get("n")).and_then(Json::as_usize), Some(60));
        let lvl = doc
            .get("workload")
            .and_then(|w| w.get("simd_level"))
            .and_then(Json::as_str)
            .unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&lvl), "{lvl}");
        let arr = doc.get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), pts.len());
        assert!(arr[0].get("op").and_then(Json::as_str).is_some());
        let sp = doc.get("shard_split").and_then(Json::as_arr).unwrap();
        assert_eq!(sp.len(), 1);
        assert!(sp[0].get("planned_speedup").and_then(Json::as_f64).is_some());
        // round-trips through the in-tree parser
        let re = Json::parse(&doc.dump()).unwrap();
        assert_eq!(re, doc);
    }

    #[test]
    fn shard_split_sweep_respects_core_budget() {
        let cfg = tiny();
        let splits = shard_split_sweep(&cfg, &[1, 2, 4], &fast());
        assert_eq!(splits.len(), 3);
        for s in &splits {
            assert!(s.planned_workers >= 1 && s.planned_threads >= 1);
            // the planned split never oversubscribes the core budget
            assert!(s.planned_workers * s.planned_threads <= s.cores, "{s:?}");
            assert!(s.planned_workers <= s.shards, "{s:?}");
            assert!(s.planned_seconds > 0.0 && s.unplanned_seconds > 0.0, "{s:?}");
        }
    }
}
