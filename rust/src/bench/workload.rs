//! Workload generators for the paper's experiments.
//!
//! Fig. 2 / Table 1 problems: a random Gaussian ground set of N vectors
//! (d=100) and l evaluation sets of k vectors each, drawn uniformly from
//! the ground set — "Every problem is randomly generated" (§5); data
//! generation is excluded from the measured runtime, as in the paper.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// One multi-set evaluation problem instance.
pub struct EvalProblem {
    pub ground: Matrix,
    pub sets: Vec<Vec<usize>>,
}

impl EvalProblem {
    pub fn set_refs(&self) -> Vec<&[usize]> {
        self.sets.iter().map(|s| s.as_slice()).collect()
    }
}

/// Generate the paper's Fig. 2 workload: N ground vectors of dim `d`,
/// `l` sets of `k` member indices.
pub fn fig2_workload(n: usize, l: usize, k: usize, d: usize, seed: u64) -> EvalProblem {
    let mut rng = Rng::new(seed);
    let ground = Matrix::random_normal(n, d, &mut rng);
    let sets = (0..l)
        .map(|_| rng.sample_indices(n, k.min(n)))
        .collect();
    EvalProblem { ground, sets }
}

/// The paper's sweep values, scaled to this testbed. The paper used
/// N ∈ {1000, ..., 400000}, l ∈ {1000, ..., 26070}, k ∈ {10, ..., 430}
/// around the base point (N=50000, l=5000, k=10, d=100); we keep the
/// base-point proportions but cap sizes (DESIGN.md §4, substitution 6).
pub struct Fig2Sweep {
    pub base_n: usize,
    pub base_l: usize,
    pub base_k: usize,
    pub d: usize,
    pub n_values: Vec<usize>,
    pub l_values: Vec<usize>,
    pub k_values: Vec<usize>,
}

impl Fig2Sweep {
    pub fn scaled(quick: bool) -> Fig2Sweep {
        if quick {
            Fig2Sweep {
                base_n: 2000,
                base_l: 32,
                base_k: 10,
                d: 100,
                n_values: vec![500, 1000, 2000, 4000],
                l_values: vec![8, 16, 32, 64],
                k_values: vec![10, 16, 32, 64],
            }
        } else {
            Fig2Sweep {
                base_n: 4000,
                base_l: 64,
                base_k: 10,
                d: 100,
                n_values: vec![1000, 2000, 4000, 8000, 16000],
                l_values: vec![16, 32, 64, 128, 256],
                k_values: vec![10, 16, 32, 64],
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_shapes() {
        let p = fig2_workload(100, 7, 5, 10, 1);
        assert_eq!(p.ground.rows(), 100);
        assert_eq!(p.ground.cols(), 10);
        assert_eq!(p.sets.len(), 7);
        assert!(p.sets.iter().all(|s| s.len() == 5));
        assert!(p.sets.iter().flatten().all(|&i| i < 100));
    }

    #[test]
    fn reproducible() {
        let a = fig2_workload(50, 3, 4, 6, 9);
        let b = fig2_workload(50, 3, 4, 6, 9);
        assert_eq!(a.ground, b.ground);
        assert_eq!(a.sets, b.sets);
    }

    #[test]
    fn k_capped_at_n() {
        let p = fig2_workload(5, 2, 10, 3, 2);
        assert!(p.sets.iter().all(|s| s.len() == 5));
    }
}
