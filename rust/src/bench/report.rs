//! Bench reporter: aligned console tables (the paper's figure/table
//! shapes) + CSV export under `bench_results/`.

use crate::util::csv::Table;

/// Collects rows for one experiment and renders them.
pub struct Reporter {
    title: String,
    table: Table,
    widths: Vec<usize>,
}

impl Reporter {
    pub fn new(title: &str, columns: &[&str]) -> Reporter {
        let widths = columns.iter().map(|c| c.len().max(10)).collect();
        Reporter { title: title.to_string(), table: Table::new(columns), widths }
    }

    pub fn row(&mut self, cells: &[String]) {
        for (i, c) in cells.iter().enumerate() {
            if i < self.widths.len() {
                self.widths[i] = self.widths[i].max(c.len());
            }
        }
        self.table.push(cells.to_vec());
    }

    /// Render the aligned table to stdout.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let hdr: Vec<String> = self
            .table
            .header
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = self.widths[i]))
            .collect();
        println!("{}", hdr.join("  "));
        println!("{}", "-".repeat(hdr.join("  ").len()));
        for r in &self.table.rows {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = self.widths[i]))
                .collect();
            println!("{}", cells.join("  "));
        }
    }

    /// Save under `bench_results/<slug>.csv` (relative to repo root).
    pub fn save_csv(&self, slug: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("EBC_BENCH_OUT").unwrap_or_else(|_| "bench_results".into());
        let path = std::path::Path::new(&dir).join(format!("{slug}.csv"));
        self.table.save(&path)?;
        Ok(path)
    }

    pub fn table(&self) -> &Table {
        &self.table
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Format a speedup factor.
pub fn fmt_x(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.1}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reporter_rows_and_csv() {
        let mut r = Reporter::new("t", &["a", "b"]);
        r.row(&["1".into(), "2".into()]);
        r.row(&["333333333333".into(), "4".into()]);
        assert_eq!(r.table().rows.len(), 2);
        r.print(); // visual smoke
        let csv = r.table().to_csv();
        assert!(csv.starts_with("a,b\n"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(5e-6), "5.0µs");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_x(3.14), "3.1x");
        assert_eq!(fmt_x(452.0), "452x");
    }
}
