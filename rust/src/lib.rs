//! # ebc-summarizer
//!
//! Production reproduction of *"Providing Meaningful Data Summarizations
//! Using Exemplar-based Clustering in Industry 4.0"* (Honysz,
//! Schulze-Struchtrup, Buschjäger, Morik — 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2** (build-time Python, `python/compile/`): Pallas work-matrix
//!   kernels + JAX graphs, AOT-lowered to HLO text under `artifacts/`.
//! * **L3** (this crate): the coordinator — submodular optimizers, the
//!   batched accelerator engine driving the AOT artifacts through PJRT,
//!   the injection-molding case-study substrate, and a streaming
//!   summarization service for machine fleets.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `ebc-summarizer` binary is self-contained.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`api`] | the typed request/response façade — the only way work enters |
//! | [`util`] | std-only infra: PRNG, stats, JSON, CSV, thread pool, timers |
//! | [`linalg`] | dense row-major matrices + squared-Euclidean distances |
//! | [`submodular`] | EBC (ST/MT CPU baselines, paper Alg. 1) + IVM |
//! | [`optim`] | Greedy family + sieve-family streaming optimizers |
//! | [`reduce`] | dimensionality reduction (JL projection, PCA) — paper §7 future work |
//! | [`runtime`] | PJRT client, artifact manifest, loaded executables |
//! | [`engine`] | the paper's contribution: batched multi-set evaluation |
//! | [`gpumodel`] | analytical device model (Quadro/TX2/Xeon/A72) |
//! | [`imm`] | injection-molding process simulator (case-study substrate) |
//! | [`shard`] | sharded two-stage summarization (partition → optimize → merge) |
//! | [`prune`] | pruned submodularity graphs + hierarchical shards-of-shards merge |
//! | [`coordinator`] | streaming summarization service + router + fleet queries |
//! | [`daemon`] | actor-style production daemon: job queues, scheduler, retry, reload, drain, status |
//! | [`obs`] | observability: metrics registry, spans + flight recorder, exposition |
//! | [`bench`] | bench harness (criterion unavailable offline) |
//! | [`config`] | TOML-subset config system |
//! | [`cli`] | argument parsing for the launcher binary |

pub mod api;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod engine;
pub mod gpumodel;
pub mod imm;
pub mod linalg;
pub mod obs;
pub mod optim;
pub mod prune;
pub mod reduce;
pub mod runtime;
pub mod shard;
pub mod submodular;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the `artifacts/` directory: `$EBC_ARTIFACTS` override, else
/// walk up from the current dir / executable looking for
/// `artifacts/manifest.json`.
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("EBC_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").is_file() {
            return Some(p);
        }
    }
    let mut starts = vec![];
    if let Ok(cwd) = std::env::current_dir() {
        starts.push(cwd);
    }
    if let Ok(exe) = std::env::current_exe() {
        if let Some(dir) = exe.parent() {
            starts.push(dir.to_path_buf());
        }
    }
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        starts.push(std::path::PathBuf::from(md));
    }
    for start in starts {
        let mut cur = Some(start.as_path());
        while let Some(dir) = cur {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").is_file() {
                return Some(cand);
            }
            cur = dir.parent();
        }
    }
    None
}
