//! Artifact manifest: the JSON index `aot.py` writes next to the HLO
//! files. The engine uses it to pick the smallest bucket that fits a
//! request (see `engine::tiling`).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Graph family of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Batched greedy marginal gains: inputs (v, vsq, vmask, mindist, c, cmask).
    Gains,
    /// Post-selection state update: inputs (v, vsq, vmask, mindist, s).
    Update,
    /// Multi-set work-matrix evaluation: inputs (v, vsq, vmask, s_flat, smask_flat).
    EvalMulti,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "gains" => ArtifactKind::Gains,
            "update" => ArtifactKind::Update,
            "eval_multi" => ArtifactKind::EvalMulti,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::Gains => "gains",
            ArtifactKind::Update => "update",
            ArtifactKind::EvalMulti => "eval_multi",
        }
    }
}

/// Compute precision of an artifact (interface is always f32; bf16
/// variants cast inside the graph — DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    Bf16,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f32" => Precision::F32,
            "bf16" => Precision::Bf16,
            other => bail!("unknown precision '{other}'"),
        })
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

/// Kernel implementation of an artifact (DESIGN.md §Perf): `Pallas` is
/// the L1 tiled work-matrix kernel (TPU-shaped; interpret-mode on CPU),
/// `Jnp` the fused matmul formulation XLA-CPU vectorizes (fast path on
/// this testbed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelImpl {
    Pallas,
    Jnp,
}

impl KernelImpl {
    pub fn parse(s: &str) -> Result<KernelImpl> {
        Ok(match s {
            "pallas" => KernelImpl::Pallas,
            "jnp" => KernelImpl::Jnp,
            other => bail!("unknown kernel impl '{other}'"),
        })
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelImpl::Pallas => "pallas",
            KernelImpl::Jnp => "jnp",
        }
    }
}

/// One manifest entry = one fixed-shape HLO module on disk.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub imp: KernelImpl,
    pub precision: Precision,
    pub n: usize,
    pub d: usize,
    pub c: usize,
    pub l: usize,
    pub k: usize,
    pub inputs: Vec<String>,
    /// Static perf estimates recorded by aot.py (DESIGN.md §Perf).
    pub vmem_bytes: usize,
    pub mxu_flops: f64,
    pub grid_programs: usize,
}

/// The parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

/// The bucket set a fleet plan pins: at most one entry per graph family
/// (see [`Manifest::pick_for_max_shape`]). Empty fields mean no bucket
/// of that family fits the planned shape — the engine then falls back
/// to its per-call manifest pick or the CPU evaluator.
#[derive(Debug, Clone, Default)]
pub struct PlanBuckets {
    pub gains: Option<ArtifactEntry>,
    pub update: Option<ArtifactEntry>,
    pub eval_multi: Option<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let raw = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            entries.push(Self::parse_entry(e, &dir)?);
        }
        Ok(Manifest { dir, entries })
    }

    fn parse_entry(e: &Json, dir: &Path) -> Result<ArtifactEntry> {
        let s = |k: &str| -> Result<String> {
            e.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("entry missing string field '{k}'"))
        };
        let u = |k: &str| -> Result<usize> {
            e.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("entry missing int field '{k}'"))
        };
        let inputs = e
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("entry missing inputs"))?
            .iter()
            .map(|x| x.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("non-string input name"))?;
        Ok(ArtifactEntry {
            name: s("name")?,
            file: dir.join(s("file")?),
            kind: ArtifactKind::parse(&s("kind")?)?,
            imp: KernelImpl::parse(
                e.get("impl").and_then(Json::as_str).unwrap_or("pallas"),
            )?,
            precision: Precision::parse(&s("dtype")?)?,
            n: u("n")?,
            d: u("d")?,
            c: u("c")?,
            l: u("l")?,
            k: u("k")?,
            inputs,
            vmem_bytes: u("vmem_bytes").unwrap_or(0),
            mxu_flops: e.get("mxu_flops").and_then(Json::as_f64).unwrap_or(0.0),
            grid_programs: u("grid_programs").unwrap_or(0),
        })
    }

    /// Smallest-fitting gains bucket for (n, d, c) at the given precision
    /// and preferred kernel impl (falls back to the other impl if the
    /// preferred one has no fitting bucket).
    pub fn pick_gains(
        &self,
        n: usize,
        d: usize,
        c: usize,
        p: Precision,
        imp: KernelImpl,
    ) -> Option<&ArtifactEntry> {
        let pick = |want: Option<KernelImpl>| {
            self.entries
                .iter()
                .filter(|e| {
                    e.kind == ArtifactKind::Gains
                        && e.precision == p
                        && want.is_none_or(|w| e.imp == w)
                        && e.n >= n
                        && e.d >= d
                        && e.c >= c
                })
                .min_by_key(|e| (e.n as u64) * (e.d as u64) + (e.c as u64) * (e.d as u64))
        };
        pick(Some(imp)).or_else(|| pick(None))
    }

    /// The gains bucket with the largest candidate capacity that fits
    /// (n, d) — used by the engine to chunk oversized candidate batches.
    pub fn pick_gains_largest_c(
        &self,
        n: usize,
        d: usize,
        p: Precision,
        imp: KernelImpl,
    ) -> Option<&ArtifactEntry> {
        let pick = |want: Option<KernelImpl>| {
            self.entries
                .iter()
                .filter(|e| {
                    e.kind == ArtifactKind::Gains
                        && e.precision == p
                        && want.is_none_or(|w| e.imp == w)
                        && e.n >= n
                        && e.d >= d
                })
                // prefer max C, then the tightest (n, d)
                .max_by_key(|e| (e.c, std::cmp::Reverse((e.n as u64) * (e.d as u64))))
        };
        pick(Some(imp)).or_else(|| pick(None))
    }

    /// Smallest-fitting update bucket for (n, d) (impl-agnostic: the
    /// update graph is pure jnp in every variant).
    pub fn pick_update(&self, n: usize, d: usize, p: Precision) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Update && e.precision == p && e.n >= n && e.d >= d)
            .min_by_key(|e| (e.n as u64) * (e.d as u64))
    }

    /// One bucket per graph family, picked for the **maximum** shape any
    /// stage of a fleet run requests: the merge stage evaluates against
    /// the full (n, d) ground set and every shard holds at most n rows,
    /// so a single (n, d)-fitting pick serves all P shard oracles and
    /// the merge oracle — one executable compiled and loaded per family
    /// instead of one per distinct shard shape. A gains request whose
    /// candidate batch exceeds every C bucket falls back to the widest-C
    /// (n, d)-fitting bucket so the engine can chunk over it.
    pub fn pick_for_max_shape(
        &self,
        n: usize,
        d: usize,
        c: usize,
        l: usize,
        k: usize,
        p: Precision,
        imp: KernelImpl,
    ) -> PlanBuckets {
        PlanBuckets {
            gains: self
                .pick_gains(n, d, c, p, imp)
                .or_else(|| self.pick_gains_largest_c(n, d, p, imp))
                .cloned(),
            update: self.pick_update(n, d, p).cloned(),
            eval_multi: self.pick_eval_multi(l, k, n, d, p, imp).cloned(),
        }
    }

    /// Smallest-fitting eval_multi bucket for (l, k, n, d).
    pub fn pick_eval_multi(
        &self,
        l: usize,
        k: usize,
        n: usize,
        d: usize,
        p: Precision,
        imp: KernelImpl,
    ) -> Option<&ArtifactEntry> {
        let pick = |want: Option<KernelImpl>| {
            self.entries
                .iter()
                .filter(|e| {
                    e.kind == ArtifactKind::EvalMulti
                        && e.precision == p
                        && want.is_none_or(|w| e.imp == w)
                        && e.l >= l
                        && e.k >= k
                        && e.n >= n
                        && e.d >= d
                })
                .min_by_key(|e| (e.n as u64 + e.l as u64 * e.k as u64) * e.d as u64)
        };
        pick(Some(imp)).or_else(|| pick(None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "gains_n1024_d128_c256_f32", "file": "g.hlo.txt", "kind": "gains",
         "dtype": "f32", "n": 1024, "d": 128, "c": 256, "l": 0, "k": 0,
         "block_n": 256, "block_c": 128, "block_l": 8,
         "inputs": ["v","vsq","vmask","mindist","c","cmask"],
         "vmem_bytes": 345678, "mxu_flops": 6.7e7, "grid_programs": 8},
        {"name": "gains_n4096_d128_c1024_f32", "file": "g2.hlo.txt", "kind": "gains",
         "dtype": "f32", "n": 4096, "d": 128, "c": 1024, "l": 0, "k": 0,
         "inputs": ["v","vsq","vmask","mindist","c","cmask"],
         "vmem_bytes": 345678, "mxu_flops": 1.0e9, "grid_programs": 128},
        {"name": "eval_multi_l64_k16_n1024_d128_bf16", "file": "e.hlo.txt",
         "kind": "eval_multi", "dtype": "bf16", "n": 1024, "d": 128, "c": 0,
         "l": 64, "k": 16, "inputs": ["v","vsq","vmask","s_flat","smask_flat"],
         "vmem_bytes": 10, "mxu_flops": 1.0, "grid_programs": 32}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entries[0].kind, ArtifactKind::Gains);
        assert_eq!(m.entries[0].n, 1024);
        assert_eq!(m.entries[2].precision, Precision::Bf16);
        assert_eq!(m.entries[0].file, PathBuf::from("/tmp/a/g.hlo.txt"));
    }

    #[test]
    fn picks_smallest_fitting_bucket() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let e = m
            .pick_gains(1000, 100, 200, Precision::F32, KernelImpl::Pallas)
            .unwrap();
        assert_eq!(e.name, "gains_n1024_d128_c256_f32");
        let e = m
            .pick_gains(2000, 100, 200, Precision::F32, KernelImpl::Pallas)
            .unwrap();
        assert_eq!(e.name, "gains_n4096_d128_c1024_f32");
        assert!(m
            .pick_gains(100_000, 100, 200, Precision::F32, KernelImpl::Pallas)
            .is_none());
        assert!(m
            .pick_gains(100, 100, 100, Precision::Bf16, KernelImpl::Pallas)
            .is_none());
        // impl fallback: no jnp gains in the sample -> falls back to pallas
        let e = m
            .pick_gains(1000, 100, 200, Precision::F32, KernelImpl::Jnp)
            .unwrap();
        assert_eq!(e.imp, KernelImpl::Pallas);
    }

    #[test]
    fn pick_eval_multi_dims() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m
            .pick_eval_multi(60, 10, 1000, 128, Precision::Bf16, KernelImpl::Pallas)
            .is_some());
        assert!(m
            .pick_eval_multi(65, 10, 1000, 128, Precision::Bf16, KernelImpl::Pallas)
            .is_none());
    }

    #[test]
    fn pick_for_max_shape_pins_one_bucket_per_family() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let b = m.pick_for_max_shape(2000, 100, 200, 1, 1, Precision::F32, KernelImpl::Pallas);
        assert_eq!(b.gains.as_ref().unwrap().name, "gains_n4096_d128_c1024_f32");
        assert!(b.update.is_none(), "no update entries in the sample");
        assert!(b.eval_multi.is_none(), "sample eval_multi is bf16 only");
        // candidate batch wider than every C bucket: widest-C fallback
        let b = m.pick_for_max_shape(1000, 100, 9999, 1, 1, Precision::F32, KernelImpl::Pallas);
        assert_eq!(b.gains.as_ref().unwrap().name, "gains_n4096_d128_c1024_f32");
        // nothing fits (n too large): empty plan buckets
        let b = m.pick_for_max_shape(100_000, 100, 10, 1, 1, Precision::F32, KernelImpl::Pallas);
        assert!(b.gains.is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
