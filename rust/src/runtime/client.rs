//! The PJRT runtime: one CPU client + a cache of compiled executables.
//!
//! Compilation (HLO text → `HloModuleProto` → `XlaComputation` →
//! `PjRtLoadedExecutable`) happens lazily on first use of each variant
//! and is cached for the lifetime of the runtime — the paper's
//! "algorithm initialization" step.

use crate::runtime::artifact::{ArtifactEntry, Manifest};
use crate::runtime::executable::LoadedGraph;
use crate::runtime::xla;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared handle to the PJRT client + executable cache.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<Inner>,
}

struct Inner {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedGraph>>>,
}

impl Runtime {
    /// Create a runtime over the given artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.entries.len()
        );
        Ok(Runtime {
            inner: Arc::new(Inner { client, manifest, cache: Mutex::new(HashMap::new()) }),
        })
    }

    /// Create a runtime by discovering the artifacts directory
    /// (`$EBC_ARTIFACTS` or walking up from cwd/exe).
    pub fn discover() -> Result<Runtime> {
        let dir = crate::artifacts_dir()
            .context("artifacts/manifest.json not found; run `make artifacts`")?;
        Self::new(dir)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.inner.client
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    /// Fetch (compiling + caching on first use) the executable for an entry.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<Arc<LoadedGraph>> {
        {
            let cache = self.inner.cache.lock().unwrap();
            if let Some(g) = cache.get(&entry.name) {
                return Ok(Arc::clone(g));
            }
        }
        // compile outside the lock (slow); racing compiles are benign
        let g = Arc::new(LoadedGraph::compile(&self.inner.client, entry)?);
        let mut cache = self.inner.cache.lock().unwrap();
        Ok(Arc::clone(cache.entry(entry.name.clone()).or_insert(g)))
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// Upload an f32 host slice as a device buffer.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.inner
            .client
            .buffer_from_host_buffer(data, dims, None)
            .context("host->device transfer")
    }
}
