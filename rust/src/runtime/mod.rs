//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see DESIGN.md §1) and executes
//! them on the CPU PJRT client via the `xla` crate.
//!
//! Python never runs here: this module is the only boundary between the
//! Rust coordinator and the compiled L1/L2 compute graphs.

pub mod artifact;
pub mod client;
pub mod executable;
// Offline stand-in for the `xla` (PJRT) crate; replace with
// `pub use ::xla;` when the real bindings are available (see the
// module docs for the swap recipe).
pub mod xla;

pub use artifact::{ArtifactEntry, ArtifactKind, Manifest, PlanBuckets};
pub use client::Runtime;
pub use executable::LoadedGraph;
