//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build environment has no network and no PJRT shared library, so
//! the real bindings cannot be vendored. This module mirrors the exact
//! API surface [`super::client`], [`super::executable`] and
//! [`crate::engine::dataset`] consume, and fails *at runtime* — at
//! [`PjRtClient::cpu`], the single entry point — with a clear error, so
//! everything CPU-backed builds and runs while the XLA backend reports
//! itself unavailable instead of breaking the build.
//!
//! To swap the real crate back in: add `xla` to `Cargo.toml`, replace
//! `pub mod xla;` in `runtime/mod.rs` with `pub use ::xla;`, and delete
//! this file. No other source changes are needed — all call sites
//! already resolve `xla::` through `crate::runtime::xla`.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: this binary was built with the offline \
         xla stub (rust/src/runtime/xla.rs); use --backend cpu, or rebuild \
         with the real `xla` crate"
            .to_string(),
    )
}

/// Stand-in for `xla::PjRtClient`. [`Self::cpu`] is the only
/// constructor and always fails, so the remaining methods are
/// unreachable but keep every call site type-checking.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtBuffer` (device-resident array).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }

    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}

/// Stand-in for `xla::Literal` (host-resident array).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn error_is_anyhow_compatible() {
        fn takes_anyhow(e: impl Into<anyhow::Error>) -> anyhow::Error {
            e.into()
        }
        let e = takes_anyhow(unavailable());
        assert!(format!("{e:#}").contains("xla stub"));
    }
}
