//! A compiled artifact: HLO text parsed, ids reassigned by the text
//! parser (the reason text is the interchange format — DESIGN.md §1),
//! compiled for the CPU PJRT client.

use crate::runtime::artifact::ArtifactEntry;
use crate::runtime::xla;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// One compiled, ready-to-execute graph.
pub struct LoadedGraph {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    pub compile_seconds: f64,
}

impl LoadedGraph {
    pub fn compile(client: &xla::PjRtClient, entry: &ArtifactEntry) -> Result<LoadedGraph> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.name))?;
        let compile_seconds = t0.elapsed().as_secs_f64();
        log::debug!("compiled {} in {:.2}s", entry.name, compile_seconds);
        Ok(LoadedGraph { entry: entry.clone(), exe, compile_seconds })
    }

    /// Execute with device-resident buffers; returns the un-tupled
    /// output literals (graphs are lowered with `return_tuple=True`).
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}: expected {} inputs ({:?}), got {}",
                self.entry.name,
                self.entry.inputs.len(),
                self.entry.inputs,
                args.len()
            );
        }
        let outs = self.exe.execute_b(args).context("execute_b")?;
        let lit = outs[0][0].to_literal_sync().context("device->host transfer")?;
        Ok(lit.to_tuple().context("un-tupling output")?)
    }

    /// Execute with host literals (uploads every argument; the engine
    /// prefers [`Self::execute_buffers`] with a device-resident ground set).
    pub fn execute_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let outs = self.exe.execute::<xla::Literal>(args).context("execute")?;
        let lit = outs[0][0].to_literal_sync().context("device->host transfer")?;
        Ok(lit.to_tuple().context("un-tupling output")?)
    }
}

/// Read an f32 vector out of an output literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
