//! Command-line argument parsing for the launcher (clap is unavailable
//! offline). Subcommand + `--flag value` / `--flag` / `--flag=value`
//! style, with typed accessors and a generated usage string.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A declared flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A declared subcommand.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// The application spec: named subcommands with flags.
#[derive(Debug, Clone, Default)]
pub struct AppSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl AppSpec {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.help));
        }
        s.push_str("\nRun `<command> --help` for flags.\n");
        s
    }

    pub fn command_usage(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.name, cmd.name, cmd.help);
        for f in &cmd.flags {
            let arg = if f.takes_value { format!("--{} <v>", f.name) } else { format!("--{}", f.name) };
            let def = f.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", arg, f.help, def));
        }
        s
    }

    /// Parse argv (without the program name). Returns (command, matches)
    /// or Err with a usage message.
    pub fn parse(&self, args: &[String]) -> Result<(String, Matches)> {
        let Some(cmd_name) = args.first() else {
            bail!("{}", self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            bail!("{}", self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| anyhow!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;

        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut present: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.command_usage(cmd));
            }
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (raw.to_string(), None),
                };
                let spec = cmd
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow!("unknown flag '--{name}'\n\n{}", self.command_usage(cmd))
                    })?;
                present.push(name.clone());
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .ok_or_else(|| anyhow!("flag '--{name}' expects a value"))?
                                .clone()
                        }
                    };
                    values.insert(name, v);
                } else if inline.is_some() {
                    bail!("flag '--{name}' takes no value");
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // fill defaults
        for f in &cmd.flags {
            if f.takes_value && !values.contains_key(f.name) {
                if let Some(d) = f.default {
                    values.insert(f.name.to_string(), d.to_string());
                }
            }
        }
        Ok((cmd_name.clone(), Matches { values, present, positional }))
    }
}

/// Parsed flag values for one invocation.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    present: Vec<String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }
    pub fn has(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name)
    }
    pub fn usize(&self, name: &str) -> Result<usize> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow!("missing flag '--{name}'"))?;
        v.parse().map_err(|_| anyhow!("flag '--{name}': '{v}' is not a non-negative integer"))
    }
    pub fn f64(&self, name: &str) -> Result<f64> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow!("missing flag '--{name}'"))?;
        v.parse().map_err(|_| anyhow!("flag '--{name}': '{v}' is not a number"))
    }
    pub fn str(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing flag '--{name}'"))
    }
}

/// Convenience: flag spec constructors.
pub fn flag(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, takes_value: false, default: None }
}
pub fn opt(name: &'static str, help: &'static str, default: &'static str) -> FlagSpec {
    FlagSpec { name, help, takes_value: true, default: Some(default) }
}
pub fn req(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, takes_value: true, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> AppSpec {
        AppSpec {
            name: "ebc-summarizer",
            about: "test",
            commands: vec![CommandSpec {
                name: "bench",
                help: "run benches",
                flags: vec![
                    opt("n", "ground size", "1000"),
                    opt("out", "output file", "out.csv"),
                    flag("full", "full sweep"),
                    req("seed", "rng seed"),
                ],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let (cmd, m) = app()
            .parse(&sv(&["bench", "--n", "500", "--full", "--seed=42"]))
            .unwrap();
        assert_eq!(cmd, "bench");
        assert_eq!(m.usize("n").unwrap(), 500);
        assert_eq!(m.str("out").unwrap(), "out.csv"); // default
        assert!(m.has("full"));
        assert_eq!(m.usize("seed").unwrap(), 42);
    }

    #[test]
    fn missing_required_flag_errors_on_access() {
        let (_, m) = app().parse(&sv(&["bench"])).unwrap();
        assert!(m.usize("seed").is_err());
    }

    #[test]
    fn unknown_command_and_flag() {
        assert!(app().parse(&sv(&["nope"])).is_err());
        assert!(app().parse(&sv(&["bench", "--bogus"])).is_err());
    }

    #[test]
    fn value_for_boolean_flag_rejected() {
        assert!(app().parse(&sv(&["bench", "--full=yes"])).is_err());
    }

    #[test]
    fn help_surfaces_usage() {
        let err = app().parse(&sv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("COMMANDS"));
        let err = app().parse(&sv(&["bench", "--help"])).unwrap_err().to_string();
        assert!(err.contains("--seed"));
    }

    #[test]
    fn positional_args_collected() {
        let (_, m) = app().parse(&sv(&["bench", "pos1", "--n", "5", "pos2"])).unwrap();
        assert_eq!(m.positional, vec!["pos1", "pos2"]);
    }
}
