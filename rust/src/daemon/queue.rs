//! The daemon's job queue: keyed coalescing, single-flight execution,
//! bounded pending with load shedding, and delayed retry entries.
//!
//! Jobs are *idempotent recomputations* (fold the ingest queue, refresh
//! one machine's summary, recompute the fleet summary), so the queue
//! coalesces by [`JobKey`]: a push whose key is already pending is
//! dropped (the pending run will see the newer state anyway), and a
//! push whose key is currently **executing** is deferred — re-enqueued
//! once the active run finishes, because that run may have read state
//! from before the push. This gives single-flight semantics per key
//! without ever losing a "data changed" signal.
//!
//! The pending set is bounded; pushes beyond capacity are **shed** and
//! counted by the daemon (`ebc_daemon_jobs_shed_total`) — under burst
//! the daemon prefers dropping duplicate recompute requests over
//! unbounded memory. Retries re-enter with a `not_before` deadline so
//! backoff never blocks a worker thread.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One unit of daemon work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobKind {
    /// Drain a batch from the coordinator ingest queue into machine
    /// windows ([`crate::coordinator::Coordinator::fold`]).
    Ingest,
    /// Refresh one machine's cached summary.
    Refresh(String),
    /// Recompute the cached fleet-wide summary (`@fleet`).
    Fleet,
    /// Occupy a worker for `sleep_ms` (test seam: proves slow jobs
    /// never block admission). `id` keeps probe keys distinct so
    /// probes are never coalesced.
    Probe { id: u64, sleep_ms: u64 },
}

impl JobKind {
    /// Coalescing identity of this job.
    pub fn key(&self) -> JobKey {
        match self {
            JobKind::Ingest => JobKey::Ingest,
            JobKind::Refresh(name) => JobKey::Refresh(name.clone()),
            JobKind::Fleet => JobKey::Fleet,
            JobKind::Probe { id, .. } => JobKey::Probe(*id),
        }
    }

    /// Span / log label (static for the obs layer).
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::Ingest => "daemon.ingest",
            JobKind::Refresh(_) => "daemon.refresh",
            JobKind::Fleet => "daemon.fleet",
            JobKind::Probe { .. } => "daemon.probe",
        }
    }
}

/// Coalescing key: at most one pending and one executing job per key.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobKey {
    Ingest,
    Refresh(String),
    Fleet,
    Probe(u64),
}

/// A queued job: its kind, how many times it already failed, and the
/// earliest instant it may run (retry backoff).
#[derive(Debug, Clone)]
pub struct Job {
    pub kind: JobKind,
    pub attempt: u32,
    pub not_before: Option<Instant>,
}

/// Outcome of a push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Push {
    /// Enqueued as a fresh job.
    Queued,
    /// Folded into an already-pending or just-executing job.
    Coalesced,
    /// Dropped: the queue is at capacity (or closed).
    Shed,
}

/// Point-in-time queue state (exported as `ebc_daemon_jobs_*` gauges).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobQueueStats {
    pub pending: usize,
    pub in_flight: usize,
    pub capacity: usize,
}

struct State {
    pending: VecDeque<Job>,
    /// Keys of pending jobs (coalescing set).
    keys: BTreeSet<JobKey>,
    /// Keys currently executing on a worker.
    active: BTreeSet<JobKey>,
    /// Keys pushed while active: re-enqueued when the active run ends.
    deferred: BTreeMap<JobKey, JobKind>,
    in_flight: usize,
    capacity: usize,
    closed: bool,
}

/// Bounded multi-producer job queue with per-key single-flight (see
/// module docs). All methods take `&self`; workers block in
/// [`JobQueue::next`].
pub struct JobQueue {
    state: Mutex<State>,
    cv: Condvar,
}

impl JobQueue {
    pub fn new(capacity: usize) -> JobQueue {
        JobQueue {
            state: Mutex::new(State {
                pending: VecDeque::new(),
                keys: BTreeSet::new(),
                active: BTreeSet::new(),
                deferred: BTreeMap::new(),
                in_flight: 0,
                capacity: capacity.max(1),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue (or coalesce, or shed — see [`Push`]).
    pub fn push(&self, kind: JobKind) -> Push {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Push::Shed;
        }
        let key = kind.key();
        if s.keys.contains(&key) {
            return Push::Coalesced;
        }
        if s.active.contains(&key) {
            s.deferred.insert(key, kind);
            return Push::Coalesced;
        }
        if s.pending.len() >= s.capacity {
            return Push::Shed;
        }
        s.keys.insert(key);
        s.pending.push_back(Job { kind, attempt: 0, not_before: None });
        drop(s);
        self.cv.notify_one();
        Push::Queued
    }

    /// Claim the next runnable job, blocking up to `timeout`. Returns
    /// `None` on timeout or when the queue is closed and empty — the
    /// caller distinguishes via [`JobQueue::is_shutdown`]. The claimed
    /// key moves to the active set; the worker must hand it back with
    /// [`JobQueue::finish`] or [`JobQueue::requeue`].
    pub fn next(&self, timeout: Duration) -> Option<Job> {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let ready = s
                .pending
                .iter()
                .position(|j| j.not_before.map_or(true, |t| t <= now));
            if let Some(i) = ready {
                let job = s.pending.remove(i).expect("position in bounds");
                let key = job.kind.key();
                s.keys.remove(&key);
                s.active.insert(key);
                s.in_flight += 1;
                return Some(job);
            }
            if s.closed && s.pending.is_empty() {
                return None;
            }
            if now >= deadline {
                return None;
            }
            // sleep until the deadline or the earliest delayed retry
            let mut wake = deadline;
            for j in &s.pending {
                if let Some(t) = j.not_before {
                    wake = wake.min(t);
                }
            }
            let dur = wake
                .saturating_duration_since(now)
                .max(Duration::from_millis(1));
            let (guard, _) = self.cv.wait_timeout(s, dur).unwrap();
            s = guard;
        }
    }

    /// Mark a claimed job done. A key deferred while it ran re-enters
    /// the pending set (the capacity bound still applies — a shed
    /// deferred job is safe because the *next* state change re-pushes).
    pub fn finish(&self, key: &JobKey) {
        let mut s = self.state.lock().unwrap();
        s.active.remove(key);
        s.in_flight = s.in_flight.saturating_sub(1);
        if let Some(kind) = s.deferred.remove(key) {
            if !s.closed && s.pending.len() < s.capacity {
                s.keys.insert(kind.key());
                s.pending.push_back(Job { kind, attempt: 0, not_before: None });
            }
        }
        drop(s);
        self.cv.notify_all();
    }

    /// Hand a failed claimed job back for a delayed retry. Retries keep
    /// their slot even at capacity — shedding an accepted job's retry
    /// would turn a transient failure into silent loss. Works after
    /// close (graceful drain finishes its retries).
    pub fn requeue(&self, job: Job, delay: Duration) {
        let mut s = self.state.lock().unwrap();
        let key = job.kind.key();
        s.active.remove(&key);
        s.in_flight = s.in_flight.saturating_sub(1);
        s.keys.insert(key);
        s.pending.push_back(Job {
            kind: job.kind,
            attempt: job.attempt + 1,
            not_before: Some(Instant::now() + delay),
        });
        drop(s);
        self.cv.notify_all();
    }

    /// Block until no job is pending, deferred or executing (true) or
    /// `timeout` elapses (false).
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            if s.pending.is_empty() && s.deferred.is_empty() && s.in_flight == 0 {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    /// Stop accepting pushes. `discard` additionally drops everything
    /// pending (abortive shutdown); without it queued jobs drain.
    pub fn close(&self, discard: bool) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        if discard {
            s.pending.clear();
            s.keys.clear();
            s.deferred.clear();
        }
        drop(s);
        self.cv.notify_all();
    }

    /// Closed with nothing left to run — workers exit on this.
    pub fn is_shutdown(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.closed && s.pending.is_empty()
    }

    /// Live-resize the pending bound (config reload). Already-queued
    /// jobs always survive; only future pushes see the new bound.
    pub fn set_capacity(&self, capacity: usize) {
        self.state.lock().unwrap().capacity = capacity.max(1);
    }

    pub fn stats(&self) -> JobQueueStats {
        let s = self.state.lock().unwrap();
        JobQueueStats {
            pending: s.pending.len(),
            in_flight: s.in_flight,
            capacity: s.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const TICK: Duration = Duration::from_millis(10);

    #[test]
    fn pending_pushes_coalesce_by_key() {
        let q = JobQueue::new(8);
        assert_eq!(q.push(JobKind::Refresh("m1".into())), Push::Queued);
        assert_eq!(q.push(JobKind::Refresh("m1".into())), Push::Coalesced);
        assert_eq!(q.push(JobKind::Refresh("m2".into())), Push::Queued);
        assert_eq!(q.push(JobKind::Fleet), Push::Queued);
        assert_eq!(q.push(JobKind::Fleet), Push::Coalesced);
        assert_eq!(q.stats().pending, 3);
    }

    #[test]
    fn active_key_defers_and_reenters_after_finish() {
        let q = JobQueue::new(8);
        q.push(JobKind::Refresh("m1".into()));
        let job = q.next(TICK).unwrap();
        let key = job.kind.key();
        // while executing: a new push for the key defers, not drops
        assert_eq!(q.push(JobKind::Refresh("m1".into())), Push::Coalesced);
        assert_eq!(q.stats().pending, 0);
        q.finish(&key);
        // the deferred push re-entered: the post-finish state gets rerun
        let again = q.next(TICK).expect("deferred job re-enqueued");
        assert_eq!(again.kind, JobKind::Refresh("m1".into()));
        assert_eq!(again.attempt, 0);
        q.finish(&again.kind.key());
        assert!(q.next(TICK).is_none());
    }

    #[test]
    fn capacity_sheds_fresh_pushes_but_never_retries() {
        let q = JobQueue::new(2);
        assert_eq!(q.push(JobKind::Probe { id: 1, sleep_ms: 0 }), Push::Queued);
        assert_eq!(q.push(JobKind::Probe { id: 2, sleep_ms: 0 }), Push::Queued);
        assert_eq!(q.push(JobKind::Probe { id: 3, sleep_ms: 0 }), Push::Shed);
        // a claimed job's retry re-enters even with pending at capacity
        let job = q.next(TICK).unwrap();
        q.push(JobKind::Probe { id: 4, sleep_ms: 0 }); // refill to capacity
        q.requeue(job, Duration::from_millis(0));
        assert_eq!(q.stats().pending, 3);
    }

    #[test]
    fn requeue_respects_not_before() {
        let q = JobQueue::new(4);
        q.push(JobKind::Fleet);
        let job = q.next(TICK).unwrap();
        q.requeue(job, Duration::from_millis(60));
        // not yet runnable
        assert!(q.next(Duration::from_millis(5)).is_none());
        // blocks until the backoff elapses, then hands it out
        let retried = q.next(Duration::from_millis(500)).expect("retry became runnable");
        assert_eq!(retried.attempt, 1);
        assert_eq!(retried.kind, JobKind::Fleet);
    }

    #[test]
    fn close_drains_then_shuts_down() {
        let q = JobQueue::new(4);
        q.push(JobKind::Ingest);
        q.push(JobKind::Fleet);
        q.close(false);
        assert_eq!(q.push(JobKind::Fleet), Push::Shed);
        assert!(!q.is_shutdown(), "closed queue still has jobs to drain");
        let a = q.next(TICK).unwrap();
        q.finish(&a.kind.key());
        let b = q.next(TICK).unwrap();
        q.finish(&b.kind.key());
        assert!(q.is_shutdown());
        assert!(q.next(TICK).is_none());
    }

    #[test]
    fn close_discard_drops_pending() {
        let q = JobQueue::new(4);
        q.push(JobKind::Ingest);
        q.push(JobKind::Fleet);
        q.close(true);
        assert!(q.is_shutdown());
        assert!(q.next(TICK).is_none());
    }

    #[test]
    fn wait_idle_sees_in_flight_work() {
        let q = Arc::new(JobQueue::new(4));
        q.push(JobKind::Ingest);
        assert!(!q.wait_idle(Duration::from_millis(5)), "pending job is not idle");
        let job = q.next(TICK).unwrap();
        assert!(!q.wait_idle(Duration::from_millis(5)), "in-flight job is not idle");
        let q2 = Arc::clone(&q);
        let key = job.kind.key();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.finish(&key);
        });
        assert!(q.wait_idle(Duration::from_millis(2000)), "finish did not wake wait_idle");
        h.join().unwrap();
    }

    #[test]
    fn set_capacity_applies_to_future_pushes() {
        let q = JobQueue::new(1);
        assert_eq!(q.push(JobKind::Probe { id: 1, sleep_ms: 0 }), Push::Queued);
        assert_eq!(q.push(JobKind::Probe { id: 2, sleep_ms: 0 }), Push::Shed);
        q.set_capacity(3);
        assert_eq!(q.push(JobKind::Probe { id: 2, sleep_ms: 0 }), Push::Queued);
    }
}
