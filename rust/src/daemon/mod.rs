//! `ebc::daemon` — the actor-style production daemon over the
//! streaming coordinator.
//!
//! The [`crate::coordinator::Coordinator`] is a shareable state core
//! (every method `&self` behind fine-grained locks); this module gives
//! it a runtime: a bounded [`queue::JobQueue`] of coalesced jobs, a
//! worker pool executing them, a deterministic [`scheduler::Scheduler`]
//! heartbeat, jittered-backoff [`retry::RetryPolicy`] for failed jobs,
//! live config [`reload`], SIGINT-driven graceful drain
//! ([`shutdown`]), and an HTTP [`status`] endpoint.
//!
//! The design invariant, end to end: **ingest is never blocked by
//! summarization.** [`Daemon::offer`] touches only the coordinator's
//! ingest-queue mutex and a job-queue push; folds, summary refreshes
//! and `@fleet` merges all run on worker threads, and operator queries
//! ([`Daemon::query`]) serve cached state only. Load shedding under
//! burst is observable, not silent: the once-dark
//! `BoundedQueue::{accepted, evicted}` counters surface here as
//! `ebc_daemon_ingest_*` metrics.
//!
//! ```no_run
//! use ebc::api::Service;
//! use ebc::config::schema::ServiceConfig;
//! use ebc::daemon::Daemon;
//!
//! let mut cfg = ServiceConfig::default();
//! cfg.daemon.status_addr = "127.0.0.1:9180".into();
//! let daemon = Daemon::start(Service::cpu().coordinator(cfg)).unwrap();
//! // ... offer records, serve queries ...
//! let report = daemon.drain(std::time::Duration::from_secs(5));
//! assert!(report.drained);
//! ```

pub mod queue;
pub mod reload;
pub mod retry;
pub mod scheduler;
pub mod shutdown;
pub mod status;

pub use queue::{Job, JobKey, JobKind, JobQueue, JobQueueStats, Push};
pub use reload::{plan_reload, Knobs, ReloadPlan};
pub use retry::RetryPolicy;
pub use scheduler::{Scheduler, TickPlan};
pub use shutdown::{install as install_signals, ShutdownFlags};
pub use status::{StatusRoutes, StatusServer};

use crate::coordinator::backpressure::Admission;
use crate::coordinator::snapshot;
use crate::coordinator::stream::CycleRecord;
use crate::coordinator::{Coordinator, FleetSummary, RouteResult, FLEET_QUERY};
use crate::config::schema::ServiceConfig;
use crate::obs;
use crate::util::json::{Json, ObjBuilder};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a worker blocks in [`JobQueue::next`] before re-checking
/// shutdown state.
const WORKER_POLL: Duration = Duration::from_millis(25);

/// Daemon-level metrics on a dedicated registry (`ebc_daemon_*`,
/// disjoint from the global `ebc_*` and coordinator `coord_*` families
/// so the `/metrics` exposition can concatenate all three).
pub struct DaemonMetrics {
    registry: obs::Registry,
    /// Live ingest-queue depth / capacity / watermark state.
    pub ingest_depth: obs::Gauge,
    pub ingest_capacity: obs::Gauge,
    pub ingest_above_watermark: obs::Gauge,
    /// The once-dark [`crate::coordinator::backpressure::BoundedQueue`]
    /// counters, exported (synced by delta every scheduler tick and on
    /// drain).
    pub ingest_accepted: obs::Counter,
    pub ingest_evicted: obs::Counter,
    pub jobs_enqueued: obs::Counter,
    pub jobs_coalesced: obs::Counter,
    pub jobs_shed: obs::Counter,
    pub jobs_pending: obs::Gauge,
    pub jobs_in_flight: obs::Gauge,
    /// Job execution latency (all kinds).
    pub job_seconds: obs::Histogram,
    pub job_retries: obs::Counter,
    /// Jobs that exhausted their retry budget.
    pub job_failures: obs::Counter,
    pub ticks: obs::Counter,
    pub reloads: obs::Counter,
    /// Admission latency of [`Daemon::offer`] — the soak test's proof
    /// that ingest stays fast while summarization runs.
    pub offer_seconds: obs::Histogram,
    pub drain_seconds: obs::Histogram,
}

impl Default for DaemonMetrics {
    fn default() -> DaemonMetrics {
        let r = obs::Registry::new();
        DaemonMetrics {
            ingest_depth: r.gauge("ebc_daemon_ingest_depth", "records queued for ingest"),
            ingest_capacity: r.gauge("ebc_daemon_ingest_capacity", "ingest queue capacity"),
            ingest_above_watermark: r.gauge(
                "ebc_daemon_ingest_above_watermark",
                "1 when the ingest queue is past its high watermark",
            ),
            ingest_accepted: r
                .counter("ebc_daemon_ingest_accepted_total", "records accepted at admission"),
            ingest_evicted: r.counter(
                "ebc_daemon_ingest_evicted_total",
                "records evicted under backpressure",
            ),
            jobs_enqueued: r.counter("ebc_daemon_jobs_enqueued_total", "jobs enqueued"),
            jobs_coalesced: r
                .counter("ebc_daemon_jobs_coalesced_total", "jobs folded into a pending key"),
            jobs_shed: r.counter("ebc_daemon_jobs_shed_total", "jobs dropped at capacity"),
            jobs_pending: r.gauge("ebc_daemon_jobs_pending", "jobs waiting for a worker"),
            jobs_in_flight: r.gauge("ebc_daemon_jobs_in_flight", "jobs executing now"),
            job_seconds: r.histogram("ebc_daemon_job_seconds", "job execution latency (seconds)"),
            job_retries: r.counter("ebc_daemon_job_retries_total", "failed jobs retried"),
            job_failures: r
                .counter("ebc_daemon_job_failures_total", "jobs failed past their retry budget"),
            ticks: r.counter("ebc_daemon_ticks_total", "scheduler heartbeats"),
            reloads: r.counter("ebc_daemon_reloads_total", "live config reloads applied"),
            offer_seconds: r
                .histogram("ebc_daemon_offer_seconds", "offer() admission latency (seconds)"),
            drain_seconds: r.histogram("ebc_daemon_drain_seconds", "graceful drain duration"),
            registry: r,
        }
    }
}

impl DaemonMetrics {
    /// The backing registry (for exposition / snapshots).
    pub fn registry(&self) -> &obs::Registry {
        &self.registry
    }
}

impl std::fmt::Debug for DaemonMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DaemonMetrics")
            .field("ingest_accepted", &self.ingest_accepted.get())
            .field("ingest_evicted", &self.ingest_evicted.get())
            .field("jobs_enqueued", &self.jobs_enqueued.get())
            .field("jobs_coalesced", &self.jobs_coalesced.get())
            .field("jobs_shed", &self.jobs_shed.get())
            .field("job_retries", &self.job_retries.get())
            .field("job_failures", &self.job_failures.get())
            .field("ticks", &self.ticks.get())
            .field("reloads", &self.reloads.get())
            .finish()
    }
}

/// State shared by the daemon handle, its workers, the scheduler thread
/// and the status-endpoint closures.
struct Shared {
    coord: Arc<Coordinator>,
    jobs: Arc<JobQueue>,
    metrics: Arc<DaemonMetrics>,
    knobs: Arc<Knobs>,
    /// Set on drain: offers are refused, the scheduler exits.
    stop: AtomicBool,
    /// The `@fleet` answer served to operators — recomputed by Fleet
    /// jobs off the query path.
    fleet_cache: Mutex<Option<FleetSummary>>,
    /// Last permanently-failed job (surfaced in `/status`).
    last_error: Mutex<Option<String>>,
    /// Fault-injection seam: the next N refresh/fleet jobs fail.
    inject_failures: AtomicU32,
    probe_seq: AtomicU64,
    /// Previous BoundedQueue counter readings (delta sync).
    prev_accepted: AtomicU64,
    prev_evicted: AtomicU64,
}

impl Shared {
    /// Push with metric accounting.
    fn enqueue(&self, kind: JobKind) -> Push {
        let p = self.jobs.push(kind);
        match p {
            Push::Queued => self.metrics.jobs_enqueued.inc(),
            Push::Coalesced => self.metrics.jobs_coalesced.inc(),
            Push::Shed => self.metrics.jobs_shed.inc(),
        }
        p
    }

    /// Export ingest-queue + job-queue state to the daemon registry.
    fn sync_queue_metrics(&self) {
        let st = self.coord.queue_stats();
        self.metrics.ingest_depth.set(st.len as i64);
        self.metrics.ingest_capacity.set(st.capacity as i64);
        self.metrics.ingest_above_watermark.set(st.above_watermark as i64);
        let pa = self.prev_accepted.swap(st.accepted, Ordering::SeqCst);
        self.metrics.ingest_accepted.add(st.accepted.saturating_sub(pa));
        let pe = self.prev_evicted.swap(st.evicted, Ordering::SeqCst);
        self.metrics.ingest_evicted.add(st.evicted.saturating_sub(pe));
        let js = self.jobs.stats();
        self.metrics.jobs_pending.set(js.pending as i64);
        self.metrics.jobs_in_flight.set(js.in_flight as i64);
    }

    /// Consume one armed injected failure (test seam).
    fn take_injected_failure(&self) -> Result<(), String> {
        let armed = self
            .inject_failures
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok();
        if armed {
            Err("injected job failure".into())
        } else {
            Ok(())
        }
    }

    /// Concatenated text exposition of every registry in the process:
    /// global (`ebc_*`: api/shard/net/kernel), coordinator (`coord_*`)
    /// and daemon (`ebc_daemon_*`).
    fn metrics_text(&self) -> String {
        let mut out = obs::expo::render_text(&obs::global().registry.snapshot());
        out.push_str(&obs::expo::render_text(&self.coord.metrics.registry().snapshot()));
        out.push_str(&obs::expo::render_text(&self.metrics.registry.snapshot()));
        out
    }

    fn status_json(&self) -> Json {
        let js = self.jobs.stats();
        let mut b = ObjBuilder::new()
            .str(
                "state",
                if self.stop.load(Ordering::SeqCst) { "draining" } else { "running" },
            )
            .int("ticks", self.metrics.ticks.get() as usize)
            .int("jobs_pending", js.pending)
            .int("jobs_in_flight", js.in_flight)
            .int("job_failures", self.metrics.job_failures.get() as usize)
            .bool("fleet_cached", self.fleet_cache.lock().unwrap().is_some());
        if let Some(e) = self.last_error.lock().unwrap().as_ref() {
            b = b.str("last_error", e.clone());
        }
        b.val("snapshot", snapshot::snapshot(&self.coord)).build()
    }
}

/// Outcome of a graceful drain (see [`Daemon::drain`]).
#[derive(Debug, Clone, PartialEq)]
pub struct DrainReport {
    /// Everything accepted was folded and every job finished in time.
    pub drained: bool,
    /// Ingest records still queued when the deadline hit (0 on success).
    pub queue_len: usize,
    /// Jobs still pending/executing when the deadline hit (0, 0 on
    /// success).
    pub pending_jobs: usize,
    pub in_flight_jobs: usize,
    /// Wall-clock the drain took (seconds).
    pub seconds: f64,
    /// Final snapshot location, when `[daemon] snapshot_path` is set
    /// and the write succeeded.
    pub snapshot_path: Option<String>,
}

/// The running daemon: worker pool + scheduler + optional status
/// endpoint over an `Arc<Coordinator>`. See the module docs.
pub struct Daemon {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    scheduler: Option<JoinHandle<()>>,
    status: Option<StatusServer>,
}

impl Daemon {
    /// Start workers, scheduler and (when `[daemon] status_addr` is
    /// set) the status endpoint for `coord`. Fails only on a status
    /// bind error.
    pub fn start(coord: Coordinator) -> std::io::Result<Daemon> {
        Self::start_arc(Arc::new(coord))
    }

    /// [`Daemon::start`] over a coordinator the caller keeps a handle
    /// to (tests asserting on coordinator state mid-run).
    pub fn start_arc(coord: Arc<Coordinator>) -> std::io::Result<Daemon> {
        let d = coord.config().daemon;
        let shared = Arc::new(Shared {
            jobs: Arc::new(JobQueue::new(d.job_capacity)),
            metrics: Arc::new(DaemonMetrics::default()),
            knobs: Arc::new(Knobs::from_section(&d)),
            coord,
            stop: AtomicBool::new(false),
            fleet_cache: Mutex::new(None),
            last_error: Mutex::new(None),
            inject_failures: AtomicU32::new(0),
            probe_seq: AtomicU64::new(0),
            prev_accepted: AtomicU64::new(0),
            prev_evicted: AtomicU64::new(0),
        });
        let workers = (0..d.workers.max(1))
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ebc-daemon-w{i}"))
                    .spawn(move || worker_loop(sh, i as u64))
                    .expect("spawn daemon worker")
            })
            .collect();
        let sh = Arc::clone(&shared);
        let scheduler = std::thread::Builder::new()
            .name("ebc-daemon-sched".into())
            .spawn(move || scheduler_loop(sh))
            .expect("spawn daemon scheduler");
        let status = if d.status_addr.is_empty() {
            None
        } else {
            let m = Arc::clone(&shared);
            let s = Arc::clone(&shared);
            Some(StatusServer::start(
                &d.status_addr,
                StatusRoutes {
                    metrics: Box::new(move || m.metrics_text()),
                    status: Box::new(move || s.status_json().dump()),
                },
            )?)
        };
        Ok(Daemon { shared, workers, scheduler: Some(scheduler), status })
    }

    /// Offer one record (sensor push path). `None` once draining —
    /// producers must stop. Touches only the ingest-queue mutex plus a
    /// coalesced job push: never blocked by summarization.
    pub fn offer(&self, rec: CycleRecord) -> Option<Admission> {
        if self.shared.stop.load(Ordering::SeqCst) {
            return None;
        }
        let t0 = Instant::now();
        let adm = self.shared.coord.offer(rec);
        self.shared.enqueue(JobKind::Ingest);
        self.shared.metrics.offer_seconds.observe(t0.elapsed().as_secs_f64());
        Some(adm)
    }

    /// Operator query from cached state only. Per-machine summaries
    /// come from the router; [`FLEET_QUERY`] serves the cached fleet
    /// summary (enqueuing a recompute on a cold cache) — a merge never
    /// runs on the query path.
    pub fn query(&self, name: &str) -> RouteResult {
        if name == FLEET_QUERY {
            self.shared.coord.metrics.queries.inc();
            if let Some(f) = self.shared.fleet_cache.lock().unwrap().clone() {
                return RouteResult::Fleet(f);
            }
            self.shared.enqueue(JobKind::Fleet);
            let ingested = self
                .shared
                .coord
                .with_machines(|ms| ms.values().map(|m| m.total_ingested).sum());
            return RouteResult::NotReady { ingested };
        }
        self.shared.coord.query_cached(name)
    }

    /// Apply a new config live (see [`plan_reload`] for what applies,
    /// [`Coordinator::apply_config`] for the window/queue-preserving
    /// swap). Returns the plan that was applied.
    pub fn reload(&self, new: ServiceConfig) -> Result<ReloadPlan, String> {
        let old = self.shared.coord.config();
        let plan = plan_reload(&old, &new)?;
        if plan.is_noop() {
            return Ok(plan);
        }
        self.shared.jobs.set_capacity(new.daemon.job_capacity);
        self.shared.knobs.apply(&new.daemon);
        for knob in &plan.restart_required {
            log::warn!("reload: {knob} changed but only applies on restart");
        }
        self.shared.coord.apply_config(new)?;
        self.shared.metrics.reloads.inc();
        log::info!("config reloaded: {:?}", plan.sections);
        Ok(plan)
    }

    /// Graceful drain: refuse new offers, fold everything accepted,
    /// finish (or time out on) in-flight jobs, write the final
    /// snapshot, and only then stop the status endpoint — it serves
    /// `/metrics` throughout the drain.
    pub fn drain(mut self, timeout: Duration) -> DrainReport {
        let t0 = Instant::now();
        let deadline = t0 + timeout;
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        // flush: keep ingest jobs flowing until the queue is empty
        // (each fold drains one adaptive batch)
        while self.shared.coord.queue_len() > 0 && Instant::now() < deadline {
            self.shared.enqueue(JobKind::Ingest);
            std::thread::sleep(Duration::from_millis(2));
        }
        self.shared.jobs.close(false);
        let idle = self
            .shared
            .jobs
            .wait_idle(deadline.saturating_duration_since(Instant::now()));
        if idle {
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        } else {
            // a wedged job must not wedge shutdown: leave the workers
            // detached (close(true) in Drop keeps them from picking up
            // anything new) and report the truth
            log::error!("drain timed out with jobs still running");
            self.workers.clear();
        }
        let queue_len = self.shared.coord.queue_len();
        let js = self.shared.jobs.stats();
        self.shared.sync_queue_metrics();
        let path = self.shared.knobs.snapshot_path();
        let snapshot_path = if path.is_empty() {
            None
        } else {
            match snapshot::save(&self.shared.coord, &path) {
                Ok(()) => Some(path),
                Err(e) => {
                    log::error!("final snapshot failed: {e}");
                    None
                }
            }
        };
        let seconds = t0.elapsed().as_secs_f64();
        self.shared.metrics.drain_seconds.observe(seconds);
        // the status endpoint goes down last
        if let Some(mut s) = self.status.take() {
            s.shutdown();
        }
        DrainReport {
            drained: idle && queue_len == 0,
            queue_len,
            pending_jobs: js.pending,
            in_flight_jobs: js.in_flight,
            seconds,
            snapshot_path,
        }
    }

    /// The coordinator this daemon runs (read-side: snapshots, tests).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    pub fn metrics(&self) -> &DaemonMetrics {
        &self.shared.metrics
    }

    /// Owned handle to the metrics (outlives [`Daemon::drain`], which
    /// consumes the daemon).
    pub fn metrics_arc(&self) -> Arc<DaemonMetrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// The status endpoint's bound address, when one is serving.
    pub fn status_addr(&self) -> Option<std::net::SocketAddr> {
        self.status.as_ref().map(|s| s.addr())
    }

    /// The `/status` JSON document (also served over HTTP).
    pub fn status_json(&self) -> Json {
        self.shared.status_json()
    }

    /// The `/metrics` text exposition (also served over HTTP).
    pub fn metrics_text(&self) -> String {
        self.shared.metrics_text()
    }

    /// Last permanently-failed job, if any (retry budget exhausted).
    pub fn last_error(&self) -> Option<String> {
        self.shared.last_error.lock().unwrap().clone()
    }

    /// Arm the fault-injection seam: the next `n` refresh/fleet jobs
    /// fail (then retry per policy). Test-only by intent, but harmless
    /// in production.
    pub fn inject_job_failures(&self, n: u32) {
        self.shared.inject_failures.store(n, Ordering::SeqCst);
    }

    /// Enqueue a job that occupies one worker for `sleep_ms` (test
    /// seam: prove slow jobs never block admission).
    pub fn probe(&self, sleep_ms: u64) -> Push {
        let id = self.shared.probe_seq.fetch_add(1, Ordering::SeqCst);
        self.shared.enqueue(JobKind::Probe { id, sleep_ms })
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        // abortive path (drain() already took scheduler/status/workers
        // on the graceful one): stop everything without flushing
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.jobs.close(true);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(mut s) = self.status.take() {
            s.shutdown();
        }
    }
}

fn worker_loop(sh: Arc<Shared>, seed: u64) {
    // deterministic per-worker jitter (the soak test fixes seeds)
    let mut rng = Rng::new(0xDAE304 ^ (seed.wrapping_mul(0x9E3779B97F4A7C15)));
    loop {
        match sh.jobs.next(WORKER_POLL) {
            Some(job) => run_job(&sh, job, &mut rng),
            None => {
                if sh.jobs.is_shutdown() {
                    break;
                }
            }
        }
    }
}

fn run_job(sh: &Shared, job: Job, rng: &mut Rng) {
    let key = job.kind.key();
    let t0 = Instant::now();
    let res = {
        // every job gets its own root so obs traces show one tree per
        // job; the coordinator/api/shard spans nest underneath
        let _root = obs::root_span("daemon.job");
        let _kind = obs::span(job.kind.label());
        execute_kind(sh, &job.kind)
    };
    sh.metrics.job_seconds.observe(t0.elapsed().as_secs_f64());
    match res {
        Ok(()) => sh.jobs.finish(&key),
        Err(e) => {
            let policy = RetryPolicy {
                retries: sh.knobs.retries(),
                backoff_ms: sh.knobs.backoff_ms(),
            };
            if policy.should_retry(job.attempt) {
                let delay = policy.delay(job.attempt, rng);
                log::warn!(
                    "{} failed (attempt {}): {e}; retrying in {delay:?}",
                    job.kind.label(),
                    job.attempt + 1
                );
                sh.metrics.job_retries.inc();
                sh.jobs.requeue(job, delay);
            } else {
                log::error!(
                    "{} failed permanently after {} attempt(s): {e}",
                    job.kind.label(),
                    job.attempt + 1
                );
                sh.metrics.job_failures.inc();
                *sh.last_error.lock().unwrap() =
                    Some(format!("{}: {e}", job.kind.label()));
                sh.jobs.finish(&key);
            }
        }
    }
}

fn execute_kind(sh: &Shared, kind: &JobKind) -> Result<(), String> {
    match kind {
        JobKind::Ingest => {
            let (_, due) = sh.coord.fold();
            for name in due {
                sh.enqueue(JobKind::Refresh(name));
            }
            // backlog: fold again (deferred behind this run's finish)
            if sh.coord.queue_len() > 0 {
                sh.enqueue(JobKind::Ingest);
            }
            Ok(())
        }
        JobKind::Refresh(name) => {
            sh.take_injected_failure()?;
            sh.coord.refresh(name); // false = machine gone; not an error
            Ok(())
        }
        JobKind::Fleet => {
            sh.take_injected_failure()?;
            match sh.coord.fleet_summary() {
                RouteResult::Fleet(f) => {
                    *sh.fleet_cache.lock().unwrap() = Some(f);
                    Ok(())
                }
                // nothing pooled yet (or the backend answered NotReady):
                // keep the previous cache, try again next cadence
                RouteResult::NotReady { .. } => Ok(()),
                other => Err(format!("unexpected fleet route: {other:?}")),
            }
        }
        JobKind::Probe { sleep_ms, .. } => {
            std::thread::sleep(Duration::from_millis(*sleep_ms));
            Ok(())
        }
    }
}

fn scheduler_loop(sh: Arc<Shared>) {
    let mut sched = Scheduler::new();
    while !sh.stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(sh.knobs.tick_ms()));
        sh.metrics.ticks.inc();
        sh.sync_queue_metrics();
        let plan = sched.on_tick(
            sh.knobs.refresh_ticks(),
            sh.knobs.fleet_ticks(),
            sh.coord.queue_len(),
        );
        if plan.ingest {
            sh.enqueue(JobKind::Ingest);
        }
        if plan.refresh {
            let refresh_every = sh.coord.config().summary.refresh_every;
            let due = sh.coord.with_machines(|ms| {
                ms.iter()
                    .filter(|(_, m)| m.needs_refresh(refresh_every))
                    .map(|(n, _)| n.clone())
                    .collect::<Vec<_>>()
            });
            for name in due {
                sh.enqueue(JobKind::Refresh(name));
            }
        }
        if plan.fleet {
            sh.enqueue(JobKind::Fleet);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Service;

    fn fast_cfg() -> ServiceConfig {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 2;
        cfg.summary.refresh_every = 5;
        cfg.summary.window = 100;
        cfg.daemon.tick_ms = 2;
        cfg.daemon.refresh_ticks = 2;
        cfg.daemon.fleet_ticks = 0;
        cfg.daemon.backoff_ms = 2;
        cfg
    }

    fn rec(m: &str, seq: u64) -> CycleRecord {
        CycleRecord { machine: m.into(), seq, values: vec![seq as f32, 1.0, 0.5] }
    }

    fn wait_for(mut pred: impl FnMut() -> bool, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !pred() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn offers_become_summaries_off_the_query_path() {
        let daemon = Daemon::start(Service::cpu().coordinator(fast_cfg())).unwrap();
        for s in 0..30u64 {
            assert!(daemon.offer(rec("m1", s)).is_some());
        }
        wait_for(
            || matches!(daemon.query("m1"), RouteResult::Summary(_)),
            "a summary for m1",
        );
        assert!(daemon.metrics().job_seconds.snapshot().count > 0);
        assert_eq!(daemon.coordinator().metrics.ingested.get(), 30);
        let report = daemon.drain(Duration::from_secs(5));
        assert!(report.drained, "{report:?}");
        assert_eq!(report.queue_len, 0);
    }

    #[test]
    fn fleet_queries_serve_from_cache_only() {
        let mut cfg = fast_cfg();
        cfg.daemon.fleet_ticks = 3;
        let daemon = Daemon::start(Service::cpu().coordinator(cfg)).unwrap();
        // cold cache: NotReady + a recompute enqueued, never inline
        assert!(matches!(daemon.query(FLEET_QUERY), RouteResult::NotReady { .. }));
        for m in ["m1", "m2"] {
            for s in 0..10u64 {
                daemon.offer(rec(m, s));
            }
        }
        wait_for(
            || matches!(daemon.query(FLEET_QUERY), RouteResult::Fleet(_)),
            "a cached fleet summary",
        );
        match daemon.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => assert_eq!(f.machines, 2),
            other => panic!("{other:?}"),
        }
        drop(daemon);
    }

    #[test]
    fn injected_failures_retry_then_surface() {
        let mut cfg = fast_cfg();
        cfg.daemon.retries = 1;
        let daemon = Daemon::start(Service::cpu().coordinator(cfg)).unwrap();
        for s in 0..10u64 {
            daemon.offer(rec("m1", s));
        }
        wait_for(
            || matches!(daemon.query("m1"), RouteResult::Summary(_)),
            "initial summary",
        );
        // 2 failures = first attempt + its only retry → surfaced
        daemon.inject_job_failures(2);
        for s in 10..20u64 {
            daemon.offer(rec("m1", s));
        }
        wait_for(|| daemon.metrics().job_failures.get() >= 1, "a surfaced failure");
        assert!(daemon.metrics().job_retries.get() >= 1);
        let err = daemon.last_error().expect("last_error recorded");
        assert!(err.contains("injected"), "{err}");
        // the daemon keeps working after a surfaced failure
        for s in 20..40u64 {
            daemon.offer(rec("m1", s));
        }
        let report = daemon.drain(Duration::from_secs(5));
        assert!(report.drained, "{report:?}");
    }

    #[test]
    fn reload_applies_live_and_preserves_windows() {
        let daemon = Daemon::start(Service::cpu().coordinator(fast_cfg())).unwrap();
        for s in 0..20u64 {
            daemon.offer(rec("m1", s));
        }
        wait_for(|| daemon.coordinator().metrics.ingested.get() == 20, "ingest of 20");
        let mut new = daemon.coordinator().config();
        new.summary.k = 3;
        new.daemon.refresh_ticks = 7;
        new.daemon.job_capacity = 128;
        let plan = daemon.reload(new).unwrap();
        assert!(plan.sections.contains(&"summary"));
        assert!(plan.sections.contains(&"daemon"));
        assert_eq!(daemon.metrics().reloads.get(), 1);
        assert_eq!(
            daemon.coordinator().with_machines(|ms| ms["m1"].window_len()),
            20,
            "reload dropped the window"
        );
        // engine change rejected, nothing applied
        let mut bad = daemon.coordinator().config();
        bad.engine.batch = 7;
        assert!(daemon.reload(bad).is_err());
        assert_eq!(daemon.metrics().reloads.get(), 1);
        drop(daemon);
    }

    #[test]
    fn drain_timeout_reports_truthfully() {
        let daemon = Daemon::start(Service::cpu().coordinator(fast_cfg())).unwrap();
        assert_eq!(daemon.probe(400), Push::Queued);
        // give a worker time to claim the probe
        std::thread::sleep(Duration::from_millis(50));
        let report = daemon.drain(Duration::from_millis(60));
        assert!(!report.drained, "a 400ms probe cannot drain in 60ms: {report:?}");
        assert!(report.seconds < 2.0, "drain blocked far past its deadline");
    }

    #[test]
    fn status_endpoint_serves_all_metric_families() {
        let mut cfg = fast_cfg();
        cfg.daemon.status_addr = "127.0.0.1:0".into();
        let daemon = Daemon::start(Service::cpu().coordinator(cfg)).unwrap();
        for s in 0..10u64 {
            daemon.offer(rec("m1", s));
        }
        wait_for(
            || matches!(daemon.query("m1"), RouteResult::Summary(_)),
            "a summary",
        );
        // the global ebc_* families only register once api::execute has
        // run, which a fleet merge drives — force one through the cache
        daemon.query(FLEET_QUERY);
        wait_for(
            || matches!(daemon.query(FLEET_QUERY), RouteResult::Fleet(_)),
            "a cached fleet summary",
        );
        let text = daemon.metrics_text();
        for family in ["ebc_requests_total", "coord_ingested_total", "ebc_daemon_job_seconds"] {
            assert!(text.contains(family), "{family} missing from exposition");
        }
        let status = daemon.status_json().dump();
        assert!(status.contains("\"state\""), "{status}");
        assert!(status.contains("\"snapshot\""), "{status}");
        // and over HTTP
        let addr = daemon.status_addr().expect("status endpoint bound");
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        let mut body = String::new();
        s.read_to_string(&mut body).unwrap();
        assert!(body.contains("ebc_daemon_offer_seconds"), "{body}");
        let report = daemon.drain(Duration::from_secs(5));
        assert!(report.drained);
    }
}
