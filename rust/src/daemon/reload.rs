//! Live config reload: diff planning + the daemon's hot knobs.
//!
//! A reload is split in three:
//! 1. [`plan_reload`] — pure diff of old vs new [`ServiceConfig`],
//!    rejecting changes that cannot apply live (the `[engine]` section
//!    is baked into the oracle factory at startup) and flagging daemon
//!    knobs that need a restart (worker count, status address);
//! 2. [`crate::coordinator::Coordinator::apply_config`] — swaps the
//!    coordinator-owned sections without dropping machine windows or
//!    queued records;
//! 3. [`Knobs::apply`] — the daemon's cadence/retry knobs live in
//!    atomics the scheduler and workers re-read every tick, so they
//!    flip between ticks with no locking.

use crate::config::schema::{DaemonSection, ServiceConfig};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// What a reload will do, per [`plan_reload`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReloadPlan {
    /// Config sections that differ and will apply live.
    pub sections: Vec<&'static str>,
    /// Daemon knobs that differ but only take effect on restart.
    pub restart_required: Vec<&'static str>,
}

impl ReloadPlan {
    /// Nothing differs — the reload is a no-op.
    pub fn is_noop(&self) -> bool {
        self.sections.is_empty() && self.restart_required.is_empty()
    }
}

/// Diff `old` → `new` without touching anything. `Err` when the change
/// cannot be applied live at all (engine section).
pub fn plan_reload(old: &ServiceConfig, new: &ServiceConfig) -> Result<ReloadPlan, String> {
    if new.engine != old.engine {
        return Err(
            "the [engine] section is baked into the oracle factory at startup and cannot \
             be live-reloaded (restart the daemon to change precision/kernel/threads)"
                .into(),
        );
    }
    let mut sections = Vec::new();
    if new.name != old.name {
        sections.push("name");
    }
    if new.summary != old.summary {
        sections.push("summary");
    }
    if new.coordinator != old.coordinator {
        sections.push("coordinator");
    }
    if new.shard != old.shard {
        sections.push("shard");
    }
    if new.obs != old.obs {
        sections.push("obs");
    }
    if new.machines != old.machines {
        sections.push("machines");
    }
    let mut restart_required = Vec::new();
    if new.daemon != old.daemon {
        sections.push("daemon");
        if new.daemon.workers != old.daemon.workers {
            restart_required.push("daemon.workers");
        }
        if new.daemon.status_addr != old.daemon.status_addr {
            restart_required.push("daemon.status_addr");
        }
    }
    Ok(ReloadPlan { sections, restart_required })
}

/// The daemon's hot knobs: lock-free reads on the scheduler/worker hot
/// path, swapped atomically by reload. Knobs that configure threads or
/// sockets at startup (worker count, status address) are *not* here —
/// they need a restart and [`plan_reload`] says so.
#[derive(Debug)]
pub struct Knobs {
    tick_ms: AtomicU64,
    refresh_ticks: AtomicU64,
    fleet_ticks: AtomicU64,
    retries: AtomicU32,
    backoff_ms: AtomicU64,
    drain_timeout_ms: AtomicU64,
    snapshot_path: Mutex<String>,
}

impl Knobs {
    pub fn from_section(d: &DaemonSection) -> Knobs {
        Knobs {
            tick_ms: AtomicU64::new(d.tick_ms.max(1)),
            refresh_ticks: AtomicU64::new(d.refresh_ticks.max(1)),
            fleet_ticks: AtomicU64::new(d.fleet_ticks),
            retries: AtomicU32::new(d.retries),
            backoff_ms: AtomicU64::new(d.backoff_ms.max(1)),
            drain_timeout_ms: AtomicU64::new(d.drain_timeout_ms.max(1)),
            snapshot_path: Mutex::new(d.snapshot_path.clone()),
        }
    }

    /// Swap every hot knob to `d`'s values (between two scheduler
    /// ticks; in-flight jobs finish under the old values).
    pub fn apply(&self, d: &DaemonSection) {
        self.tick_ms.store(d.tick_ms.max(1), Ordering::SeqCst);
        self.refresh_ticks.store(d.refresh_ticks.max(1), Ordering::SeqCst);
        self.fleet_ticks.store(d.fleet_ticks, Ordering::SeqCst);
        self.retries.store(d.retries, Ordering::SeqCst);
        self.backoff_ms.store(d.backoff_ms.max(1), Ordering::SeqCst);
        self.drain_timeout_ms.store(d.drain_timeout_ms.max(1), Ordering::SeqCst);
        *self.snapshot_path.lock().unwrap() = d.snapshot_path.clone();
    }

    pub fn tick_ms(&self) -> u64 {
        self.tick_ms.load(Ordering::SeqCst)
    }
    pub fn refresh_ticks(&self) -> u64 {
        self.refresh_ticks.load(Ordering::SeqCst)
    }
    pub fn fleet_ticks(&self) -> u64 {
        self.fleet_ticks.load(Ordering::SeqCst)
    }
    pub fn retries(&self) -> u32 {
        self.retries.load(Ordering::SeqCst)
    }
    pub fn backoff_ms(&self) -> u64 {
        self.backoff_ms.load(Ordering::SeqCst)
    }
    pub fn drain_timeout_ms(&self) -> u64 {
        self.drain_timeout_ms.load(Ordering::SeqCst)
    }
    pub fn snapshot_path(&self) -> String {
        self.snapshot_path.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_plan_for_identical_configs() {
        let c = ServiceConfig::default();
        let plan = plan_reload(&c, &c.clone()).unwrap();
        assert!(plan.is_noop());
    }

    #[test]
    fn live_sections_are_listed() {
        let old = ServiceConfig::default();
        let mut new = old.clone();
        new.summary.k = 9;
        new.shard.shards = 7;
        new.machines.push("m-new".into());
        let plan = plan_reload(&old, &new).unwrap();
        assert_eq!(plan.sections, vec!["summary", "shard", "machines"]);
        assert!(plan.restart_required.is_empty());
    }

    #[test]
    fn engine_changes_are_rejected() {
        let old = ServiceConfig::default();
        let mut new = old.clone();
        new.engine.batch = 1;
        let err = plan_reload(&old, &new).unwrap_err();
        assert!(err.contains("[engine]"), "{err}");
    }

    #[test]
    fn structural_daemon_knobs_need_restart() {
        let old = ServiceConfig::default();
        let mut new = old.clone();
        new.daemon.workers += 2;
        new.daemon.status_addr = "127.0.0.1:9180".into();
        new.daemon.tick_ms = 5; // hot knob: applies live, not listed
        let plan = plan_reload(&old, &new).unwrap();
        assert_eq!(plan.sections, vec!["daemon"]);
        assert_eq!(plan.restart_required, vec!["daemon.workers", "daemon.status_addr"]);
    }

    #[test]
    fn knobs_apply_swaps_values_and_clamps() {
        let mut d = DaemonSection::default();
        let k = Knobs::from_section(&d);
        assert_eq!(k.tick_ms(), 20);
        assert_eq!(k.retries(), 2);
        d.tick_ms = 0; // clamps to 1 rather than busy-spinning
        d.refresh_ticks = 3;
        d.fleet_ticks = 0;
        d.retries = 5;
        d.backoff_ms = 10;
        d.drain_timeout_ms = 250;
        d.snapshot_path = "/tmp/x.json".into();
        k.apply(&d);
        assert_eq!(k.tick_ms(), 1);
        assert_eq!(k.refresh_ticks(), 3);
        assert_eq!(k.fleet_ticks(), 0);
        assert_eq!(k.retries(), 5);
        assert_eq!(k.backoff_ms(), 10);
        assert_eq!(k.drain_timeout_ms(), 250);
        assert_eq!(k.snapshot_path(), "/tmp/x.json");
    }
}
