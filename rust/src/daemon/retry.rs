//! Jittered exponential backoff for failed daemon jobs.
//!
//! Reuses the replica-transport backoff shape (`shard/net.rs`):
//! `backoff_ms * 2^attempt * U[0.5, 1.5)`. Jitter comes from a caller
//! owned [`Rng`] so workers stay deterministic under a fixed seed —
//! the concurrency soak test depends on reproducible retry timing.

use crate::config::schema::DaemonSection;
use crate::util::rng::Rng;
use std::time::Duration;

/// Retry budget + backoff base for one job class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first attempt before the failure is surfaced
    /// (0 = fail fast).
    pub retries: u32,
    /// Base backoff (ms), doubled per attempt.
    pub backoff_ms: u64,
}

impl RetryPolicy {
    pub fn from_config(d: &DaemonSection) -> RetryPolicy {
        RetryPolicy { retries: d.retries, backoff_ms: d.backoff_ms }
    }

    /// Should attempt `attempt` (0-based) be retried after a failure?
    pub fn should_retry(&self, attempt: u32) -> bool {
        attempt < self.retries
    }

    /// Backoff before re-running attempt `attempt + 1`:
    /// `backoff_ms * 2^attempt`, jittered by `U[0.5, 1.5)` to keep
    /// retries from synchronizing across workers. The shift saturates
    /// so a pathological attempt count cannot overflow.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let base = self.backoff_ms.max(1).saturating_mul(1u64 << attempt.min(16));
        let jitter = 0.5 + rng.f64();
        Duration::from_millis(((base as f64) * jitter).round() as u64)
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy::from_config(&DaemonSection::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_doubles_within_jitter_bounds() {
        let p = RetryPolicy { retries: 3, backoff_ms: 40 };
        let mut rng = Rng::new(7);
        for attempt in 0..4u32 {
            let base = 40u64 << attempt;
            for _ in 0..50 {
                let d = p.delay(attempt, &mut rng).as_millis() as u64;
                assert!(
                    d >= base / 2 && d <= base + base / 2 + 1,
                    "attempt {attempt}: {d}ms outside [{}, {}]",
                    base / 2,
                    base + base / 2
                );
            }
        }
    }

    #[test]
    fn delay_is_deterministic_per_seed() {
        let p = RetryPolicy { retries: 2, backoff_ms: 50 };
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for attempt in 0..3 {
            assert_eq!(p.delay(attempt, &mut a), p.delay(attempt, &mut b));
        }
    }

    #[test]
    fn retry_budget_is_respected() {
        let p = RetryPolicy { retries: 2, backoff_ms: 1 };
        assert!(p.should_retry(0));
        assert!(p.should_retry(1));
        assert!(!p.should_retry(2));
        let fail_fast = RetryPolicy { retries: 0, backoff_ms: 1 };
        assert!(!fail_fast.should_retry(0));
    }

    #[test]
    fn huge_attempt_does_not_overflow() {
        let p = RetryPolicy { retries: u32::MAX, backoff_ms: u64::MAX / 2 };
        let mut rng = Rng::new(1);
        let _ = p.delay(u32::MAX, &mut rng); // must not panic
    }
}
