//! Signal-driven graceful shutdown without a signal-handling crate.
//!
//! A tiny `extern "C"` shim over libc's `signal(2)` installs handlers
//! that do the only async-signal-safe thing possible: set a static
//! atomic flag. The daemon's scheduler thread and the CLI serve loops
//! poll the flags; SIGINT/SIGTERM request a graceful drain, SIGHUP a
//! config reload. Fixes the `serve-replica` bug where the stop flag was
//! never set by anything, so "stop" meant `kill -9` mid-frame.
//!
//! On non-unix targets the shim compiles to a no-op install; the flags
//! can still be set programmatically ([`ShutdownFlags::request_stop`]),
//! which is also how tests and the drain path drive them.

use std::sync::atomic::{AtomicBool, Ordering};

pub const SIGHUP: i32 = 1;
pub const SIGINT: i32 = 2;
pub const SIGTERM: i32 = 15;

static STOP: AtomicBool = AtomicBool::new(false);
static RELOAD: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    pub type Handler = extern "C" fn(i32);
    extern "C" {
        pub fn signal(signum: i32, handler: Handler) -> usize;
        pub fn raise(signum: i32) -> i32;
    }
}

extern "C" fn on_stop(_sig: i32) {
    STOP.store(true, Ordering::SeqCst);
}

extern "C" fn on_reload(_sig: i32) {
    RELOAD.store(true, Ordering::SeqCst);
}

/// Handles to the process-wide shutdown/reload flags. The flags are
/// static (signal handlers cannot capture state), so every install
/// returns views of the same two atomics.
#[derive(Debug, Clone, Copy)]
pub struct ShutdownFlags {
    pub stop: &'static AtomicBool,
    pub reload: &'static AtomicBool,
}

impl ShutdownFlags {
    /// Has SIGINT/SIGTERM (or [`Self::request_stop`]) fired?
    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Consume a pending SIGHUP reload request (true at most once per
    /// signal).
    pub fn take_reload(&self) -> bool {
        self.reload.swap(false, Ordering::SeqCst)
    }

    /// Programmatic stop (tests, embedding without signals).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Re-arm the flags (tests; a fresh serve loop after a drain).
    pub fn reset(&self) {
        self.stop.store(false, Ordering::SeqCst);
        self.reload.store(false, Ordering::SeqCst);
    }
}

/// Install the handlers: SIGINT/SIGTERM → stop, SIGHUP → reload.
/// Idempotent; returns the flag handles either way.
pub fn install() -> ShutdownFlags {
    #[cfg(unix)]
    unsafe {
        sys::signal(SIGINT, on_stop);
        sys::signal(SIGTERM, on_stop);
        sys::signal(SIGHUP, on_reload);
    }
    ShutdownFlags { stop: &STOP, reload: &RELOAD }
}

/// Deliver `sig` to the current process (test helper — proves the
/// installed handler path, not just the atomics).
#[cfg(unix)]
pub fn raise_signal(sig: i32) {
    unsafe {
        sys::raise(sig);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test owns the static flags: cargo runs tests in threads of
    // one process, so flag assertions must not interleave
    #[test]
    fn signals_set_flags_and_resets_clear_them() {
        let flags = install();
        flags.reset();
        assert!(!flags.stop_requested());
        assert!(!flags.take_reload());

        #[cfg(unix)]
        {
            raise_signal(SIGHUP);
            assert!(flags.take_reload(), "SIGHUP did not set the reload flag");
            assert!(!flags.take_reload(), "reload flag not consumed");
            assert!(!flags.stop_requested(), "SIGHUP must not stop the daemon");

            raise_signal(SIGINT);
            assert!(flags.stop_requested(), "SIGINT did not set the stop flag");
            flags.reset();

            raise_signal(SIGTERM);
            assert!(flags.stop_requested(), "SIGTERM did not set the stop flag");
        }

        flags.reset();
        flags.request_stop();
        assert!(flags.stop_requested());
        flags.reset();
        assert!(!flags.stop_requested());
    }
}
