//! Deterministic tick scheduler for recurring daemon work.
//!
//! The scheduler is **pure state**: the daemon's driver thread calls
//! [`Scheduler::on_tick`] once per `tick_ms` heartbeat and acts on the
//! returned [`TickPlan`]. Keeping the decision logic free of clocks and
//! threads makes the cadence unit-testable (tick 100 always behaves
//! like tick 100) and lets live reload change the cadence knobs between
//! any two ticks. Duplicate work is coalesced downstream by the
//! [`crate::daemon::queue::JobQueue`] key set — the scheduler can ask
//! for a refresh that is already pending and nothing runs twice.

/// What the daemon should enqueue on one tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TickPlan {
    /// Fold queued ingest records into machine windows.
    pub ingest: bool,
    /// Enqueue summary refreshes for machines whose policy is due.
    pub refresh: bool,
    /// Recompute the cached fleet-wide summary.
    pub fleet: bool,
}

/// Tick counter + cadence logic (see module docs).
#[derive(Debug, Default)]
pub struct Scheduler {
    tick: u64,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler { tick: 0 }
    }

    /// Ticks elapsed since construction.
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// Advance one tick and decide what recurs now. `refresh_ticks`
    /// gates the per-machine refresh sweep, `fleet_ticks` the fleet
    /// summary recompute (0 = on-demand only); `queue_depth` is the
    /// coordinator ingest-queue depth (a non-empty queue always asks
    /// for an ingest fold, so records never sit waiting for a cadence).
    pub fn on_tick(&mut self, refresh_ticks: u64, fleet_ticks: u64, queue_depth: usize) -> TickPlan {
        self.tick += 1;
        TickPlan {
            ingest: queue_depth > 0,
            refresh: self.tick % refresh_ticks.max(1) == 0,
            fleet: fleet_ticks > 0 && self.tick % fleet_ticks == 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadences_fire_on_their_multiples() {
        let mut s = Scheduler::new();
        let mut refreshes = 0;
        let mut fleets = 0;
        for _ in 0..100 {
            let p = s.on_tick(10, 25, 0);
            assert!(!p.ingest);
            if p.refresh {
                refreshes += 1;
                assert_eq!(s.ticks() % 10, 0);
            }
            if p.fleet {
                fleets += 1;
                assert_eq!(s.ticks() % 25, 0);
            }
        }
        assert_eq!(refreshes, 10);
        assert_eq!(fleets, 4);
    }

    #[test]
    fn ingest_follows_queue_depth_not_cadence() {
        let mut s = Scheduler::new();
        assert!(s.on_tick(5, 0, 3).ingest);
        assert!(!s.on_tick(5, 0, 0).ingest);
    }

    #[test]
    fn fleet_zero_means_on_demand_only() {
        let mut s = Scheduler::new();
        for _ in 0..200 {
            assert!(!s.on_tick(10, 0, 0).fleet);
        }
    }

    #[test]
    fn refresh_zero_clamps_to_every_tick() {
        let mut s = Scheduler::new();
        assert!(s.on_tick(0, 0, 0).refresh);
        assert!(s.on_tick(0, 0, 0).refresh);
    }

    #[test]
    fn cadence_can_change_between_ticks() {
        // live reload: the knobs are re-read every tick
        let mut s = Scheduler::new();
        for _ in 0..9 {
            assert!(!s.on_tick(10, 0, 0).refresh);
        }
        assert!(s.on_tick(10, 0, 0).refresh); // tick 10
        assert!(!s.on_tick(3, 0, 0).refresh); // tick 11
        assert!(s.on_tick(3, 0, 0).refresh); // tick 12 % 3 == 0
    }
}
