//! Minimal HTTP/1.1 status endpoint (std-only, no framework).
//!
//! Serves three read-only routes from a background accept thread:
//!
//! | route | body |
//! |---|---|
//! | `GET /healthz` | `ok` (liveness probe) |
//! | `GET /metrics` | Prometheus-style text exposition of every obs registry (global + coordinator + daemon) |
//! | `GET /` or `/status` | daemon state + coordinator snapshot JSON |
//!
//! The listener is non-blocking and polls a stop flag between accepts,
//! so the endpoint keeps serving *during* a graceful drain (operators
//! watch the queues empty) and is shut down last. Bodies come from
//! injected closures — the server knows nothing about the daemon, which
//! keeps the dependency arrow pointing one way.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Body producers for the two dynamic routes.
pub struct StatusRoutes {
    /// `/metrics`: text exposition (Prometheus-style).
    pub metrics: Box<dyn Fn() -> String + Send + Sync>,
    /// `/status` and `/`: JSON document.
    pub status: Box<dyn Fn() -> String + Send + Sync>,
}

/// Handle to the background status server; dropping it (or calling
/// [`StatusServer::shutdown`]) stops the accept loop and joins the
/// thread.
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving. Fails fast on bind errors — a daemon asked for a status
    /// endpoint it cannot open should not start silently degraded.
    pub fn start(addr: &str, routes: StatusRoutes) -> std::io::Result<StatusServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("ebc-status".into())
            .spawn(move || accept_loop(listener, routes, stop2))
            .expect("spawn status thread");
        log::info!("status endpoint on http://{local}");
        Ok(StatusServer { addr: local, stop, thread: Some(thread) })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, routes: StatusRoutes, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(e) = serve_one(stream, &routes) {
                    log::debug!("status request failed: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("status accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn serve_one(mut stream: TcpStream, routes: &StatusRoutes) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(2000)))?;
    // requests are tiny ("GET /path HTTP/1.1" + headers); one read of
    // the first segment is enough to route — we never need the headers
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let head = String::from_utf8_lossy(&buf[..n]);
    let mut parts = head.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "text/plain", "GET only\n");
    }
    match path {
        "/healthz" => respond(&mut stream, "200 OK", "text/plain; charset=utf-8", "ok\n"),
        "/metrics" => {
            let body = (routes.metrics)();
            respond(&mut stream, "200 OK", "text/plain; version=0.0.4; charset=utf-8", &body)
        }
        "/" | "/status" => {
            let body = (routes.status)();
            respond(&mut stream, "200 OK", "application/json", &body)
        }
        _ => respond(&mut stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

fn respond(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn test_server() -> StatusServer {
        StatusServer::start(
            "127.0.0.1:0",
            StatusRoutes {
                metrics: Box::new(|| "ebc_daemon_up 1\n".into()),
                status: Box::new(|| "{\"state\":\"running\"}".into()),
            },
        )
        .unwrap()
    }

    #[test]
    fn routes_respond_with_expected_bodies() {
        let srv = test_server();
        let health = get(srv.addr(), "/healthz");
        assert!(health.starts_with("HTTP/1.1 200"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let metrics = get(srv.addr(), "/metrics");
        assert!(metrics.contains("ebc_daemon_up 1"), "{metrics}");
        assert!(metrics.contains("Content-Type: text/plain"), "{metrics}");

        for path in ["/", "/status"] {
            let status = get(srv.addr(), path);
            assert!(status.contains("application/json"), "{status}");
            assert!(status.ends_with("{\"state\":\"running\"}"), "{status}");
        }

        let missing = get(srv.addr(), "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
    }

    #[test]
    fn non_get_is_rejected() {
        let srv = test_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 405"), "{out}");
    }

    #[test]
    fn shutdown_joins_and_stops_serving() {
        let mut srv = test_server();
        let addr = srv.addr();
        srv.shutdown();
        srv.shutdown(); // idempotent
        // the listener is gone: connects fail or are refused quickly
        assert!(
            TcpStream::connect_timeout(&addr, Duration::from_millis(300)).is_err(),
            "listener still accepting after shutdown"
        );
    }
}
