//! Horizontal scaling: sharded two-stage submodular summarization.
//!
//! The paper scales EBC by batching oracle evaluations on *one*
//! accelerator; fleets of machines need the orthogonal axis — spreading
//! the ground set over many workers. This module implements the
//! partition/merge ("two-stage") scheme of Mitrovic et al. 2018 and the
//! GreeDi line of work, composed from the crate's existing seams:
//!
//! ```text
//!              ┌── shard 0 ── Optimizer ── k exemplars ──┐
//!   Partitioner├── shard 1 ── Optimizer ── k exemplars ──┤ union ── greedy
//!   (ground set│      ...        (any crate::optim,      │          merge ── S
//!    split)    └── shard P-1 ─ each via OracleFactory) ──┘   (full-set f)
//! ```
//!
//! * [`Partitioner`] — pluggable split strategies: [`RoundRobinPartitioner`],
//!   content-addressed [`HashPartitioner`], and [`LocalityPartitioner`]
//!   (contiguous chunks along a `reduce::RandomProjection` axis);
//! * stage 1 runs any [`crate::optim::Optimizer`] per shard, concurrently
//!   on [`crate::util::threadpool`] workers, each shard getting its own
//!   oracle through the same factory seam the coordinator uses;
//! * stage 2 ([`merge::greedy_merge`]) greedily re-selects k exemplars
//!   from the union of shard picks, scored against the **full** ground
//!   set, so merged f-values are comparable to single-node runs — and
//!   with P = 1 the pipeline reproduces single-node greedy bit for bit.
//!
//! Inside a shard, `StochasticGreedy` keeps per-shard cost linear
//! (Mirzasoleiman et al. 2015); across shards this module keeps
//! wall-clock ~1/P for the dominant first stage. The coordinator wires
//! this up as the fleet-level summary query (`@fleet`), and `shard-bench`
//! sweeps P for the scaling story.
//!
//! A fleet run can carry a [`ShardPlan`] (see [`crate::engine::plan`]):
//! one pre-picked engine bucket shape shared by every shard oracle and
//! the merge stage, plus a P-worker × T-kernel-thread CPU split with
//! P·T ≤ cores — instead of P independently-planned, oversubscribed
//! engines.
//!
//! For sublinear ground-set scaling the summarizer composes with
//! [`crate::prune`]: each shard's ground can be sieved to a weighted
//! core before stage 1 (jobs then ship only the surviving rows — no
//! wire change), and the flat merge generalizes to a shards-of-shards
//! tree whose nodes never score more than `max_merge_n` rows. With
//! every prune knob off the legacy flat path runs verbatim.
//!
//! Stage 1 is dispatched through the [`transport`] seam: jobs and
//! results travel as [`wire`]-format frames (versioned, checksummed)
//! whether the executor is the local threadpool
//! ([`InProcessTransport`]), a registered worker replica
//! ([`LoopbackReplicaTransport`]), or a real TCP replica fleet
//! ([`net::TcpReplicaTransport`] talking to [`net::ReplicaServer`]
//! processes, hardened with deadlines, retries and the [`fault`] chaos
//! layer). Every sharded run round-trips its shards through
//! encode/decode, so the wire contract is continuously exercised.

pub mod fault;
pub mod merge;
pub mod net;
pub mod partition;
pub mod summarizer;
pub mod transport;
pub mod wire;

pub use crate::engine::{plan_cpu_split, OracleSpec, PlanRequest, PlanSource, ShardPlan};
pub use merge::greedy_merge;
pub use partition::{
    build_partitioner, validate_partition, HashPartitioner, LocalityPartitioner,
    Partitioner, RoundRobinPartitioner, PARTITIONERS,
};
pub use fault::{ChaosConfig, ChaosStream, FaultyTransport, FrameMangler};
pub use net::{
    read_frame, spawn_replica, write_frame, NetError, NetOptions, ReplicaServer, ServerHandle,
    TcpReplicaTransport,
};
pub use summarizer::{ShardOracleFactory, ShardRun, ShardedResult, ShardedSummarizer};
pub use transport::{
    build_transport, build_transport_with, ExecCtx, InProcessTransport, JobSource,
    LoopbackReplicaTransport, ShardTransport, TransportError, TransportSnapshot, TRANSPORTS,
};
pub use wire::{
    ShardJobMsg, ShardResultMsg, WireDataset, WireError, WireGoodbye, WireHeartbeat, WireHello,
    WirePlan, WireRequest, WireShardSpec,
};
