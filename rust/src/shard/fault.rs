//! Deterministic fault injection for hostile-network testing.
//!
//! Everything here is seeded ([`crate::util::rng::Rng`]) so a failing
//! chaos run reproduces byte-for-byte from its seed. Two layers:
//!
//! * [`ChaosStream`] — wraps any `Read`/`Write` stream (a `TcpStream`
//!   on the coordinator's side of the socket leg) and injects the
//!   faults a hostile network produces: bit flips, truncating short
//!   reads, mid-frame disconnects, duplicate frame writes and delays.
//!   The peer sees corrupt bytes; the wire decoders must answer with a
//!   typed [`WireError`], never a panic.
//! * [`FaultyTransport`] — a frame-level chaos variant of the in-process
//!   transport: every job/result frame passes through a seeded
//!   [`FrameMangler`] before it is decoded, and a corrupted frame is
//!   retried (bounded) exactly like a real transport would retransmit.
//!
//! Both are live behind the `[shard] chaos = <seed>` config knob (see
//! [`crate::shard::transport::build_transport_with`]): `tcp` wraps its
//! client streams in [`ChaosStream`], `inproc` swaps in
//! [`FaultyTransport`]. A seed of 0 means no chaos.

use crate::shard::transport::{
    execute_job, ExecCtx, JobSource, ShardTransport, TransportError, TransportSnapshot,
    TransportStats,
};
use crate::shard::wire::{decode_job, decode_result, encode_job, encode_result, ShardResultMsg};
use crate::util::rng::Rng;
use std::io::{self, Read, Write};
use std::sync::Mutex;
use std::time::Duration;

/// Fault mix for one chaos source. Each rate is the probability (per
/// frame for [`FrameMangler`], per read/write call for [`ChaosStream`])
/// of that fault firing; at most one fault fires per event, checked in
/// field order, so the schedule is a pure function of the seed.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// Seed for the fault schedule (0 is still a valid, fixed schedule —
    /// gate chaos off at the call site, not here).
    pub seed: u64,
    /// Flip one random bit.
    pub flip: f64,
    /// Drop trailing bytes (short read / truncated frame).
    pub truncate: f64,
    /// Repeat bytes (duplicate frame on a stream, doubled tail in a
    /// mangled frame).
    pub duplicate: f64,
    /// Pretend the peer vanished: EOF on read, reset after a partial
    /// write.
    pub disconnect: f64,
    /// Stall for [`ChaosConfig::delay_ms`] before the operation.
    pub delay: f64,
    /// Injected stall length (kept tiny so chaos tests stay fast while
    /// still exercising the deadline handling).
    pub delay_ms: u64,
}

impl ChaosConfig {
    /// The standard test mix: every fault class enabled at 5%, 1 ms
    /// delays.
    pub fn from_seed(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            flip: 0.05,
            truncate: 0.05,
            duplicate: 0.05,
            disconnect: 0.05,
            delay: 0.05,
            delay_ms: 1,
        }
    }

    /// All rates zero — a chaos source that never fires (useful as a
    /// control in tests).
    pub fn silent(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            flip: 0.0,
            truncate: 0.0,
            duplicate: 0.0,
            disconnect: 0.0,
            delay: 0.0,
            delay_ms: 0,
        }
    }
}

/// Seeded whole-frame corruption: [`FrameMangler::mangle`] applies at
/// most one fault (flip / truncate / duplicate-tail) per frame and
/// counts it, so a test can reconcile observed retries against the
/// injected schedule.
#[derive(Debug)]
pub struct FrameMangler {
    rng: Rng,
    cfg: ChaosConfig,
    faults: u64,
}

impl FrameMangler {
    pub fn new(cfg: ChaosConfig) -> FrameMangler {
        FrameMangler { rng: Rng::new(cfg.seed), cfg, faults: 0 }
    }

    /// Faults injected so far.
    pub fn faults(&self) -> u64 {
        self.faults
    }

    /// Pass one frame through the chaos schedule.
    pub fn mangle(&mut self, mut frame: Vec<u8>) -> Vec<u8> {
        let roll = self.rng.f64();
        let mut edge = self.cfg.flip;
        if roll < edge && !frame.is_empty() {
            let i = self.rng.below(frame.len());
            frame[i] ^= 1 << self.rng.below(8);
            self.faults += 1;
            return frame;
        }
        edge += self.cfg.truncate;
        if roll < edge && !frame.is_empty() {
            frame.truncate(self.rng.below(frame.len()));
            self.faults += 1;
            return frame;
        }
        edge += self.cfg.duplicate;
        if roll < edge && !frame.is_empty() {
            let tail = self.rng.below(frame.len()) + 1;
            frame.extend_from_within(frame.len() - tail..);
            self.faults += 1;
        }
        frame
    }
}

/// A `Read`/`Write` stream with deterministic network hostility layered
/// on top. Wrap the coordinator's side of a socket and the replica sees
/// exactly what a lossy, corrupting network would deliver.
///
/// Faults per call, in precedence order (one per call): delay, then
/// disconnect (reads answer EOF; writes land half the buffer and fail
/// with `ConnectionReset`), then bit flip, then truncation on reads /
/// frame duplication on writes.
pub struct ChaosStream<S> {
    inner: S,
    rng: Rng,
    cfg: ChaosConfig,
    faults: u64,
}

impl<S> ChaosStream<S> {
    pub fn new(inner: S, cfg: ChaosConfig) -> ChaosStream<S> {
        ChaosStream { inner, rng: Rng::new(cfg.seed), cfg, faults: 0 }
    }

    /// Faults injected so far (both directions).
    pub fn faults(&self) -> u64 {
        self.faults
    }

    /// Unwrap the underlying stream.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let roll = self.rng.f64();
        let c = self.cfg.clone();
        if roll < c.delay {
            std::thread::sleep(Duration::from_millis(c.delay_ms));
            return self.inner.read(buf);
        }
        let mut edge = c.delay + c.disconnect;
        if roll < edge {
            // mid-frame disconnect: a clean EOF while the reader still
            // expects bytes
            self.faults += 1;
            return Ok(0);
        }
        let n = self.inner.read(buf)?;
        edge += c.flip;
        if roll < edge && n > 0 {
            let i = self.rng.below(n);
            buf[i] ^= 1 << self.rng.below(8);
            self.faults += 1;
            return Ok(n);
        }
        edge += c.truncate;
        if roll < edge && n > 1 {
            // short read that *loses* the tail: the stream desyncs and
            // the next frame header is garbage — exactly what a
            // truncating middlebox does
            self.faults += 1;
            return Ok(n / 2);
        }
        Ok(n)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let roll = self.rng.f64();
        let c = self.cfg.clone();
        if roll < c.delay {
            std::thread::sleep(Duration::from_millis(c.delay_ms));
            return self.inner.write(buf);
        }
        let mut edge = c.delay + c.disconnect;
        if roll < edge && !buf.is_empty() {
            // land half the frame, then die: the peer sees a mid-frame
            // disconnect
            self.faults += 1;
            let _ = self.inner.write(&buf[..buf.len() / 2]);
            let _ = self.inner.flush();
            return Err(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "chaos: injected disconnect",
            ));
        }
        edge += c.flip;
        if roll < edge && !buf.is_empty() {
            let mut bad = buf.to_vec();
            let i = self.rng.below(bad.len());
            bad[i] ^= 1 << self.rng.below(8);
            self.faults += 1;
            self.inner.write_all(&bad)?;
            return Ok(buf.len());
        }
        edge += c.duplicate;
        if roll < edge && !buf.is_empty() {
            // the whole buffer lands twice — with whole-frame writes
            // this is a duplicated frame on the stream
            self.faults += 1;
            self.inner.write_all(buf)?;
            self.inner.write_all(buf)?;
            return Ok(buf.len());
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Frame-level chaos transport: the in-process execution path with
/// every job and result frame passed through a seeded [`FrameMangler`]
/// before decoding. A corrupted frame is a typed [`WireError`] and the
/// job is retransmitted (rebuilt from the [`JobSource`]) up to
/// [`FaultyTransport::MAX_ATTEMPTS`] times — mirroring how the socket
/// transport retries a corrupt link — after which the last wire error
/// is returned. Retransmissions count as `shard_retries`.
///
/// Jobs run sequentially so the fault schedule is a pure function of
/// the seed.
pub struct FaultyTransport {
    mangler: Mutex<FrameMangler>,
    stats: TransportStats,
}

impl FaultyTransport {
    /// Attempts per job before the last wire error becomes final.
    pub const MAX_ATTEMPTS: u32 = 8;

    pub fn new(cfg: ChaosConfig) -> FaultyTransport {
        FaultyTransport { mangler: Mutex::new(FrameMangler::new(cfg)), stats: TransportStats::default() }
    }

    /// Faults injected so far.
    pub fn faults(&self) -> u64 {
        self.mangler.lock().unwrap().faults()
    }
}

impl ShardTransport for FaultyTransport {
    fn name(&self) -> &'static str {
        "inproc+chaos"
    }

    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError> {
        let mut results = Vec::with_capacity(jobs.len());
        for i in 0..jobs.len() {
            let mut last = None;
            let mut ok = None;
            for attempt in 0..Self::MAX_ATTEMPTS {
                if attempt > 0 {
                    self.stats.add_retries(1);
                }
                let job = jobs.job(i);
                let frame = encode_job(&job);
                drop(job);
                let frame = self.mangler.lock().unwrap().mangle(frame);
                self.stats.add_bytes(frame.len());
                let decoded = match decode_job(&frame) {
                    Ok(j) => j,
                    Err(e) => {
                        jobs.complete(i);
                        last = Some(e);
                        continue;
                    }
                };
                drop(frame);
                // a job-level error (unknown optimizer) is deterministic:
                // retransmitting the frame cannot help
                let result = match execute_job(decoded, ctx) {
                    Ok(r) => r,
                    Err(e) => {
                        jobs.complete(i);
                        return Err(e);
                    }
                };
                jobs.complete(i);
                let rframe = self.mangler.lock().unwrap().mangle(encode_result(&result));
                self.stats.add_bytes(rframe.len());
                match decode_result(&rframe) {
                    Ok(r) => {
                        ok = Some(r);
                        break;
                    }
                    Err(e) => last = Some(e),
                }
            }
            match ok {
                Some(r) => results.push(r),
                None => {
                    return Err(TransportError::Wire(
                        last.expect("no success implies a recorded wire error"),
                    ))
                }
            }
        }
        Ok(results)
    }

    fn stats(&self) -> TransportSnapshot {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{OracleSpec, Precision};
    use crate::linalg::gemm::CpuKernel;
    use crate::linalg::{Matrix, SharedMatrix};
    use crate::optim::Greedy;
    use crate::runtime::artifact::KernelImpl;
    use crate::shard::transport::InProcessTransport;
    use crate::shard::wire::ShardJobMsg;
    use crate::submodular::{CpuOracle, Oracle};
    use std::io::Cursor;

    fn factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    }

    fn jobs(n_jobs: usize, rows: usize, seed: u64) -> Vec<ShardJobMsg> {
        let mut rng = Rng::new(seed);
        (0..n_jobs)
            .map(|s| ShardJobMsg {
                shard: s as u32,
                k: 3,
                batch: 64,
                optimizer: "greedy".into(),
                payload: Precision::F32,
                precision: Precision::F32,
                cpu_kernel: CpuKernel::Scalar,
                kernel: KernelImpl::Jnp,
                threads: None,
                plan: None,
                ground_ids: (0..rows as u64).map(|i| i + 100 * s as u64).collect(),
                data: Matrix::random_normal(rows, 4, &mut rng),
            })
            .collect()
    }

    fn same_outcome(a: &[ShardResultMsg], b: &[ShardResultMsg]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.shard == y.shard
                    && x.indices == y.indices
                    && x.f_final.to_bits() == y.f_final.to_bits()
            })
    }

    #[test]
    fn mangler_is_deterministic_in_its_seed() {
        let frame: Vec<u8> = (0..200u16).map(|i| i as u8).collect();
        let mut a = FrameMangler::new(ChaosConfig::from_seed(7));
        let mut b = FrameMangler::new(ChaosConfig::from_seed(7));
        for _ in 0..100 {
            assert_eq!(a.mangle(frame.clone()), b.mangle(frame.clone()));
        }
        assert_eq!(a.faults(), b.faults());
        assert!(a.faults() > 0, "15% fault mix over 100 frames never fired");
    }

    #[test]
    fn silent_config_never_mutates() {
        let frame: Vec<u8> = (0..64u8).collect();
        let mut m = FrameMangler::new(ChaosConfig::silent(9));
        for _ in 0..50 {
            assert_eq!(m.mangle(frame.clone()), frame);
        }
        assert_eq!(m.faults(), 0);
    }

    #[test]
    fn chaos_stream_write_side_corrupts_deterministically() {
        let frame: Vec<u8> = (0..100u8).collect();
        let run = |seed| {
            let mut s = ChaosStream::new(Vec::new(), ChaosConfig::from_seed(seed));
            let mut wrote_err = 0u32;
            for _ in 0..200 {
                if s.write_all(&frame).is_err() {
                    wrote_err += 1;
                }
            }
            let faults = s.faults();
            (s.into_inner(), faults, wrote_err)
        };
        let (a, fa, ea) = run(0xFEED);
        let (b, fb, eb) = run(0xFEED);
        assert_eq!(a, b);
        assert_eq!((fa, ea), (fb, eb));
        assert!(fa > 0, "20% fault mix over 200 writes never fired");
        // the sink holds something other than 200 clean copies
        assert_ne!(a, frame.repeat(200));
    }

    #[test]
    fn chaos_stream_read_side_corrupts_deterministically() {
        let data: Vec<u8> = (0..255u8).collect::<Vec<u8>>().repeat(20);
        let run = |seed| {
            let mut s = ChaosStream::new(Cursor::new(data.clone()), ChaosConfig::from_seed(seed));
            let mut out = Vec::new();
            let mut buf = [0u8; 64];
            loop {
                match s.read(&mut buf) {
                    Ok(0) => break, // injected or real EOF
                    Ok(n) => out.extend_from_slice(&buf[..n]),
                    Err(_) => break,
                }
            }
            let faults = s.faults();
            (out, faults)
        };
        let (a, fa) = run(3);
        let (b, fb) = run(3);
        assert_eq!(a, b);
        assert_eq!(fa, fb);
    }

    #[test]
    fn faulty_transport_matches_clean_inproc_or_errors_typed() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let js = jobs(5, 10, 17);
        let clean = InProcessTransport::default().run_jobs(&js, &ctx).unwrap();
        for seed in 1..20u64 {
            let t = FaultyTransport::new(ChaosConfig::from_seed(seed));
            match t.run_jobs(&js, &ctx) {
                // bounded retransmits almost always get the frames
                // through — and then the answer must be bit-identical
                Ok(out) => assert!(same_outcome(&out, &clean), "seed {seed}"),
                // or the corruption won 8 rounds in a row: typed error
                Err(TransportError::Wire(_)) => {}
                Err(other) => panic!("seed {seed}: {other:?}"),
            }
            // every retransmission traces back to an injected fault
            let s = t.stats();
            assert!(
                s.shard_retries <= t.faults(),
                "retries {} cannot exceed injected faults {}",
                s.shard_retries,
                t.faults()
            );
        }
    }

    #[test]
    fn faulty_transport_with_silent_chaos_is_plain_inproc() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 1);
        let js = jobs(3, 8, 5);
        let clean = InProcessTransport::default().run_jobs(&js, &ctx).unwrap();
        let t = FaultyTransport::new(ChaosConfig::silent(1));
        let out = t.run_jobs(&js, &ctx).unwrap();
        assert!(same_outcome(&out, &clean));
        assert_eq!(t.stats().shard_retries, 0);
        assert_eq!(t.faults(), 0);
    }
}
