//! Versioned, self-describing binary wire format for remote shard
//! execution — the transport seam's on-the-wire contract.
//!
//! A shard job carries everything a remote coordinator replica needs to
//! reproduce local execution: the shard's sub-matrix rows, the global
//! ground ids they map back to, the optimizer id + budget, and the
//! oracle knobs (precision / kernel / thread split) including the
//! serialized scalar core of a fleet [`ShardPlan`]. A shard result
//! carries the selection mapped back to ground ids, the per-accept
//! f-trajectory and the timing/work counters.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//!   offset  size  field
//!   ------  ----  ----------------------------------------------
//!        0     4  magic  "EBCW"  (45 42 43 57)
//!        4     2  version        (u16, currently 1)
//!        6     1  kind           (1 = job, 2 = result)
//!        7     1  reserved       (0)
//!        8     4  payload_len    (u32)
//!       12     N  payload        (kind-specific, see below)
//!     12+N     4  crc32          (IEEE/zlib CRC-32 of bytes [0, 12+N))
//! ```
//!
//! Job payload v1:
//!
//! ```text
//!   u32 shard · u32 k · u32 batch · str optimizer
//!   u8 payload_precision · u8 precision · u8 cpu_kernel · u8 kernel_impl
//!   u8 has_threads · u32 threads
//!   u8 has_plan · [u32 n · u32 d · u32 shards · u32 k · u8 precision ·
//!                  u8 kernel_impl · u8 cpu_kernel · u32 cores ·
//!                  u32 shard_workers · u32 oracle_threads · u32 merge_threads]
//!   u32 id_count · id_count × u64 ground ids
//!   u32 rows · u32 cols · rows·cols × (f32 | bf16-as-u16) sub-matrix
//! ```
//!
//! Result payload v1:
//!
//! ```text
//!   u32 shard · u32 size
//!   u32 idx_count  · idx_count  × u64 exemplar ground ids (selection order)
//!   u32 traj_count · traj_count × f32 f-trajectory
//!   f32 f_final · f64 wall_seconds · u64 oracle_calls · u64 oracle_work
//! ```
//!
//! Strings are `u32 len + UTF-8 bytes`. A `bf16` payload ships each
//! value as the upper 16 bits of its [`bf16_round`]-ed f32 (2 bytes per
//! scalar — the edge-link option); decoding widens back losslessly, so
//! `decode(encode(x)) == x` exactly for payloads that are already
//! bf16-representable, and equals `demote_bf16(x)` otherwise.
//!
//! The format is frozen per version: the golden conformance suite
//! (`rust/tests/wire_golden.rs`) pins the exact bytes, so any layout
//! change must bump [`WIRE_VERSION`] consciously. Decoding is total —
//! truncated, bit-flipped or unknown-version frames yield a typed
//! [`WireError`], never a panic.
//!
//! The plan section serializes only the scalar core of a
//! [`ShardPlan`]; pre-picked engine buckets are host-local handles, so
//! a remote worker re-picks them from **its** artifact manifest for the
//! plan's (n, d, P) shape — the local transports instead reuse the live
//! plan handle (see [`crate::shard::transport::ExecCtx`]).

use crate::engine::{KernelImpl, Precision, ShardPlan};
use crate::linalg::gemm::{bf16_round, CpuKernel};
use crate::linalg::Matrix;
use crate::runtime::artifact::PlanBuckets;
use std::fmt;

/// Frame magic: "EBCW".
pub const WIRE_MAGIC: [u8; 4] = *b"EBCW";
/// Current (and only) wire format version.
pub const WIRE_VERSION: u16 = 1;
/// Fixed frame header size (magic + version + kind + reserved + len).
pub const HEADER_LEN: usize = 12;
/// Trailing checksum size.
pub const TRAILER_LEN: usize = 4;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Job,
    Result,
}

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Job => 1,
            FrameKind::Result => 2,
        }
    }
}

/// Typed decode failure. Every variant is reachable from corrupted or
/// foreign input; none of them panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than a field (or the fixed header) needs.
    TooShort { need: usize, have: usize },
    /// First four bytes are not [`WIRE_MAGIC`].
    BadMagic { found: [u8; 4] },
    /// Version field is newer/older than this decoder speaks.
    UnsupportedVersion { found: u16, supported: u16 },
    /// Kind byte is none of the known frame kinds.
    UnknownKind(u8),
    /// Declared payload length disagrees with the frame size.
    LengthMismatch { declared: usize, available: usize },
    /// CRC-32 trailer does not match the received bytes.
    ChecksumMismatch { stored: u32, computed: u32 },
    /// A payload field failed validation (bad enum byte, bad UTF-8,
    /// inconsistent counts, trailing bytes, ...).
    Malformed { field: &'static str, detail: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::TooShort { need, have } => {
                write!(f, "frame too short: need {need} bytes, have {have}")
            }
            WireError::BadMagic { found } => write!(f, "bad magic {found:02x?}"),
            WireError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported wire version {found} (decoder speaks {supported})")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::LengthMismatch { declared, available } => {
                write!(f, "payload length {declared} disagrees with frame ({available} available)")
            }
            WireError::ChecksumMismatch { stored, computed } => {
                write!(f, "checksum mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            WireError::Malformed { field, detail } => write!(f, "malformed {field}: {detail}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Byte-indexed CRC-32 lookup table, built at compile time. Job frames
/// embed whole sub-matrices and every sharded run checksums each frame
/// on both legs, so the checksum must run at table speed, not
/// bit-at-a-time speed.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            j += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — bit-identical to
/// `zlib.crc32`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// The serialized scalar core of a fleet [`ShardPlan`] — everything a
/// remote worker needs to rebuild the plan (bucket handles are
/// host-local; the worker re-picks them from its own manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WirePlan {
    pub n: u32,
    pub d: u32,
    pub shards: u32,
    pub k: u32,
    pub precision: Precision,
    pub kernel: KernelImpl,
    pub cpu_kernel: CpuKernel,
    pub cores: u32,
    pub shard_workers: u32,
    pub oracle_threads: u32,
    pub merge_threads: u32,
}

impl WirePlan {
    /// Capture the wire-transportable core of a live plan.
    pub fn of(plan: &ShardPlan) -> WirePlan {
        WirePlan {
            n: plan.n as u32,
            d: plan.d as u32,
            shards: plan.shards as u32,
            k: plan.k as u32,
            precision: plan.precision,
            kernel: plan.kernel,
            cpu_kernel: plan.cpu_kernel,
            cores: plan.cores as u32,
            shard_workers: plan.shard_workers as u32,
            oracle_threads: plan.oracle_threads as u32,
            merge_threads: plan.merge_threads as u32,
        }
    }

    /// Rebuild a [`ShardPlan`] with empty bucket handles (a remote
    /// worker re-picks buckets for this shape from its own manifest).
    pub fn to_plan(&self) -> ShardPlan {
        ShardPlan {
            n: self.n as usize,
            d: self.d as usize,
            shards: self.shards as usize,
            k: self.k as usize,
            precision: self.precision,
            kernel: self.kernel,
            cpu_kernel: self.cpu_kernel,
            cores: self.cores as usize,
            shard_workers: self.shard_workers as usize,
            oracle_threads: self.oracle_threads as usize,
            merge_threads: self.merge_threads as usize,
            buckets: PlanBuckets::default(),
        }
    }
}

/// One shard's first-stage work order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardJobMsg {
    /// Shard id (position in the partitioner's output).
    pub shard: u32,
    /// Selection budget for this shard (already clamped to its size).
    pub k: u32,
    /// Candidate-batch width a remote worker hands
    /// [`crate::optim::build_optimizer`] (the summarizer fills in its
    /// merge/candidate batch).
    pub batch: u32,
    /// Optimizer registry id ([`crate::optim::ALGORITHMS`]).
    ///
    /// **Remote-rebuild contract**: a worker without the live optimizer
    /// instance reconstructs `build_optimizer(optimizer, batch)` — the
    /// registry configuration at this batch width. Non-registry
    /// parameterizations (a custom `SieveStreaming { epsilon }`, say)
    /// do not survive the wire; local transports always execute with
    /// the live instance, so this only bounds the future socket leg,
    /// where the launcher must restrict fleet runs to registry
    /// optimizers (greedy-family selection is batch-invariant —
    /// `prop_greedy_batch_invariant` — so `batch` only shifts
    /// counters).
    pub optimizer: String,
    /// How the sub-matrix travels: `F32` (lossless, 4 B/scalar) or
    /// `Bf16` (demoted at encode, 2 B/scalar — the edge-link option).
    pub payload: Precision,
    /// Oracle compute precision.
    ///
    /// This and the two kernel knobs below configure the **worker-side
    /// oracle factory**: a remote worker builds its factory from them
    /// before handing jobs to `execute_job` (factory construction is
    /// backend-specific, so it lives outside the executor). Local
    /// transports run the caller's live factory, which already carries
    /// its backend config and ignores these fields.
    pub precision: Precision,
    /// CPU kernel backend for CPU/fallback oracles (see `precision`).
    pub cpu_kernel: CpuKernel,
    /// Preferred accelerator kernel implementation (see `precision`).
    pub kernel: KernelImpl,
    /// Per-oracle kernel-thread override (a planned run's split).
    pub threads: Option<u32>,
    /// Serialized fleet-plan core, when the run is planned.
    pub plan: Option<WirePlan>,
    /// Global ground ids of the sub-matrix rows (`len == data.rows()`).
    pub ground_ids: Vec<u64>,
    /// The shard's sub-matrix.
    pub data: Matrix,
}

/// One shard's first-stage outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardResultMsg {
    /// Shard id (copied from the job).
    pub shard: u32,
    /// Ground rows the shard held.
    pub size: u32,
    /// Selected exemplars as **global** ground ids, in selection order.
    pub indices: Vec<u64>,
    /// f(S) after each selection (shard-local objective).
    pub f_trajectory: Vec<f32>,
    pub f_final: f32,
    pub wall_seconds: f64,
    pub oracle_calls: u64,
    pub oracle_work: u64,
}

// ------------------------------------------------------------ encoding

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn precision_code(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::Bf16 => 1,
    }
}
fn cpu_kernel_code(k: CpuKernel) -> u8 {
    match k {
        CpuKernel::Scalar => 0,
        CpuKernel::Blocked => 1,
    }
}
fn kernel_impl_code(k: KernelImpl) -> u8 {
    match k {
        KernelImpl::Pallas => 0,
        KernelImpl::Jnp => 1,
    }
}

/// Wrap a payload in the versioned header + CRC trailer.
///
/// The v1 length field is u32, capping payloads at 4 GiB. That is far
/// beyond any shard this system ships (a shard's sub-matrix is a
/// fraction of a window that must fit device memory), so an oversized
/// payload is a caller bug — assert loudly here rather than truncate
/// silently and fail as a confusing checksum error at decode.
fn seal_frame(kind: FrameKind, payload: Vec<u8>) -> Vec<u8> {
    assert!(
        payload.len() <= u32::MAX as usize,
        "wire v1 frames cap payloads at u32::MAX bytes, got {}",
        payload.len()
    );
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    frame.extend_from_slice(&WIRE_MAGIC);
    put_u16(&mut frame, WIRE_VERSION);
    frame.push(kind.code());
    frame.push(0); // reserved
    put_u32(&mut frame, payload.len() as u32);
    frame.extend_from_slice(&payload);
    let crc = crc32(&frame);
    put_u32(&mut frame, crc);
    frame
}

/// Encode a job message into one sealed frame.
pub fn encode_job(job: &ShardJobMsg) -> Vec<u8> {
    let mut p = Vec::with_capacity(64 + job.ground_ids.len() * 8 + job.data.data().len() * 4);
    put_u32(&mut p, job.shard);
    put_u32(&mut p, job.k);
    put_u32(&mut p, job.batch);
    put_str(&mut p, &job.optimizer);
    p.push(precision_code(job.payload));
    p.push(precision_code(job.precision));
    p.push(cpu_kernel_code(job.cpu_kernel));
    p.push(kernel_impl_code(job.kernel));
    match job.threads {
        Some(t) => {
            p.push(1);
            put_u32(&mut p, t);
        }
        None => {
            p.push(0);
            put_u32(&mut p, 0);
        }
    }
    match &job.plan {
        Some(w) => {
            p.push(1);
            put_u32(&mut p, w.n);
            put_u32(&mut p, w.d);
            put_u32(&mut p, w.shards);
            put_u32(&mut p, w.k);
            p.push(precision_code(w.precision));
            p.push(kernel_impl_code(w.kernel));
            p.push(cpu_kernel_code(w.cpu_kernel));
            put_u32(&mut p, w.cores);
            put_u32(&mut p, w.shard_workers);
            put_u32(&mut p, w.oracle_threads);
            put_u32(&mut p, w.merge_threads);
        }
        None => p.push(0),
    }
    put_u32(&mut p, job.ground_ids.len() as u32);
    for &id in &job.ground_ids {
        put_u64(&mut p, id);
    }
    put_u32(&mut p, job.data.rows() as u32);
    put_u32(&mut p, job.data.cols() as u32);
    match job.payload {
        Precision::F32 => {
            for &v in job.data.data() {
                put_f32(&mut p, v);
            }
        }
        Precision::Bf16 => {
            for &v in job.data.data() {
                let hi = (bf16_round(v).to_bits() >> 16) as u16;
                put_u16(&mut p, hi);
            }
        }
    }
    seal_frame(FrameKind::Job, p)
}

/// Encode a result message into one sealed frame.
pub fn encode_result(res: &ShardResultMsg) -> Vec<u8> {
    let mut p = Vec::with_capacity(48 + res.indices.len() * 8 + res.f_trajectory.len() * 4);
    put_u32(&mut p, res.shard);
    put_u32(&mut p, res.size);
    put_u32(&mut p, res.indices.len() as u32);
    for &i in &res.indices {
        put_u64(&mut p, i);
    }
    put_u32(&mut p, res.f_trajectory.len() as u32);
    for &f in &res.f_trajectory {
        put_f32(&mut p, f);
    }
    put_f32(&mut p, res.f_final);
    put_f64(&mut p, res.wall_seconds);
    put_u64(&mut p, res.oracle_calls);
    put_u64(&mut p, res.oracle_work);
    seal_frame(FrameKind::Result, p)
}

// ------------------------------------------------------------ decoding

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.i.checked_add(n).ok_or_else(|| WireError::TooShort {
            need: usize::MAX,
            have: self.b.len(),
        })?;
        if end > self.b.len() {
            return Err(WireError::TooShort { need: end, have: self.b.len() });
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self, field: &'static str) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| WireError::Malformed {
            field,
            detail: format!("invalid utf-8: {e}"),
        })
    }

    fn precision(&mut self, field: &'static str) -> Result<Precision, WireError> {
        match self.u8()? {
            0 => Ok(Precision::F32),
            1 => Ok(Precision::Bf16),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown precision code {other}"),
            }),
        }
    }

    fn cpu_kernel(&mut self, field: &'static str) -> Result<CpuKernel, WireError> {
        match self.u8()? {
            0 => Ok(CpuKernel::Scalar),
            1 => Ok(CpuKernel::Blocked),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown cpu kernel code {other}"),
            }),
        }
    }

    fn kernel_impl(&mut self, field: &'static str) -> Result<KernelImpl, WireError> {
        match self.u8()? {
            0 => Ok(KernelImpl::Pallas),
            1 => Ok(KernelImpl::Jnp),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown kernel impl code {other}"),
            }),
        }
    }

    fn flag(&mut self, field: &'static str) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(WireError::Malformed {
                field,
                detail: format!("flag byte must be 0 or 1, got {other}"),
            }),
        }
    }

    /// A declared element count must fit the bytes that remain —
    /// checked before any allocation so a hostile count cannot OOM.
    fn count(&mut self, elem_size: usize, field: &'static str) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(elem_size).ok_or_else(|| WireError::Malformed {
            field,
            detail: format!("count {n} overflows"),
        })?;
        if need > self.remaining() {
            return Err(WireError::TooShort {
                need: self.i + need,
                have: self.b.len(),
            });
        }
        Ok(n)
    }
}

/// Validate the header + checksum of a frame and classify its kind.
pub fn frame_kind(frame: &[u8]) -> Result<FrameKind, WireError> {
    let min = HEADER_LEN + TRAILER_LEN;
    if frame.len() < min {
        return Err(WireError::TooShort { need: min, have: frame.len() });
    }
    let magic: [u8; 4] = frame[0..4].try_into().unwrap();
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic { found: magic });
    }
    let version = u16::from_le_bytes(frame[4..6].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(WireError::UnsupportedVersion { found: version, supported: WIRE_VERSION });
    }
    let kind = match frame[6] {
        1 => FrameKind::Job,
        2 => FrameKind::Result,
        other => return Err(WireError::UnknownKind(other)),
    };
    let declared = u32::from_le_bytes(frame[8..12].try_into().unwrap()) as usize;
    let available = frame.len() - min;
    if declared != available {
        return Err(WireError::LengthMismatch { declared, available });
    }
    let body = &frame[..frame.len() - TRAILER_LEN];
    let stored = u32::from_le_bytes(frame[frame.len() - TRAILER_LEN..].try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        return Err(WireError::ChecksumMismatch { stored, computed });
    }
    Ok(kind)
}

fn open_frame(frame: &[u8], want: FrameKind) -> Result<&[u8], WireError> {
    let kind = frame_kind(frame)?;
    if kind != want {
        return Err(WireError::Malformed {
            field: "kind",
            detail: format!("expected {want:?} frame, got {kind:?}"),
        });
    }
    Ok(&frame[HEADER_LEN..frame.len() - TRAILER_LEN])
}

/// Decode a job frame. Total: corrupted input yields a [`WireError`].
pub fn decode_job(frame: &[u8]) -> Result<ShardJobMsg, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Job)?);
    let shard = r.u32()?;
    let k = r.u32()?;
    let batch = r.u32()?;
    let optimizer = r.str("optimizer")?;
    let payload = r.precision("payload_precision")?;
    let precision = r.precision("precision")?;
    let cpu_kernel = r.cpu_kernel("cpu_kernel")?;
    let kernel = r.kernel_impl("kernel_impl")?;
    let has_threads = r.flag("has_threads")?;
    let threads_raw = r.u32()?;
    let threads = has_threads.then_some(threads_raw);
    let plan = if r.flag("has_plan")? {
        Some(WirePlan {
            n: r.u32()?,
            d: r.u32()?,
            shards: r.u32()?,
            k: r.u32()?,
            precision: r.precision("plan.precision")?,
            kernel: r.kernel_impl("plan.kernel")?,
            cpu_kernel: r.cpu_kernel("plan.cpu_kernel")?,
            cores: r.u32()?,
            shard_workers: r.u32()?,
            oracle_threads: r.u32()?,
            merge_threads: r.u32()?,
        })
    } else {
        None
    };
    let id_count = r.count(8, "ground_ids")?;
    let mut ground_ids = Vec::with_capacity(id_count);
    for _ in 0..id_count {
        ground_ids.push(r.u64()?);
    }
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    if rows != ground_ids.len() {
        return Err(WireError::Malformed {
            field: "rows",
            detail: format!("{rows} rows but {} ground ids", ground_ids.len()),
        });
    }
    let elems = rows.checked_mul(cols).ok_or_else(|| WireError::Malformed {
        field: "rows",
        detail: format!("{rows}x{cols} overflows"),
    })?;
    let elem_size = match payload {
        Precision::F32 => 4,
        Precision::Bf16 => 2,
    };
    let need = elems.checked_mul(elem_size).ok_or_else(|| WireError::Malformed {
        field: "data",
        detail: format!("{elems} elements overflow"),
    })?;
    if need != r.remaining() {
        return Err(WireError::Malformed {
            field: "data",
            detail: format!("expected {need} data bytes, have {}", r.remaining()),
        });
    }
    let mut data = Vec::with_capacity(elems);
    match payload {
        Precision::F32 => {
            for _ in 0..elems {
                data.push(r.f32()?);
            }
        }
        Precision::Bf16 => {
            for _ in 0..elems {
                data.push(f32::from_bits((r.u16()? as u32) << 16));
            }
        }
    }
    Ok(ShardJobMsg {
        shard,
        k,
        batch,
        optimizer,
        payload,
        precision,
        cpu_kernel,
        kernel,
        threads,
        plan,
        ground_ids,
        data: Matrix::from_vec(rows, cols, data),
    })
}

/// Decode a result frame. Total: corrupted input yields a [`WireError`].
pub fn decode_result(frame: &[u8]) -> Result<ShardResultMsg, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Result)?);
    let shard = r.u32()?;
    let size = r.u32()?;
    let idx_count = r.count(8, "indices")?;
    let mut indices = Vec::with_capacity(idx_count);
    for _ in 0..idx_count {
        indices.push(r.u64()?);
    }
    let traj_count = r.count(4, "f_trajectory")?;
    let mut f_trajectory = Vec::with_capacity(traj_count);
    for _ in 0..traj_count {
        f_trajectory.push(r.f32()?);
    }
    let f_final = r.f32()?;
    let wall_seconds = r.f64()?;
    let oracle_calls = r.u64()?;
    let oracle_work = r.u64()?;
    if r.remaining() != 0 {
        return Err(WireError::Malformed {
            field: "payload",
            detail: format!("{} trailing bytes", r.remaining()),
        });
    }
    Ok(ShardResultMsg {
        shard,
        size,
        indices,
        f_trajectory,
        f_final,
        wall_seconds,
        oracle_calls,
        oracle_work,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlanRequest;
    use crate::util::rng::Rng;

    fn job(payload: Precision, with_plan: bool) -> ShardJobMsg {
        let mut rng = Rng::new(7);
        let plan = with_plan.then(|| {
            let mut req = PlanRequest::new(40, 3, 4, 5);
            req.cores = 8;
            WirePlan::of(&ShardPlan::plan(None, &req))
        });
        ShardJobMsg {
            shard: 2,
            k: 5,
            batch: 256,
            optimizer: "greedy".into(),
            payload,
            precision: Precision::F32,
            cpu_kernel: CpuKernel::Blocked,
            kernel: KernelImpl::Jnp,
            threads: Some(3),
            plan,
            ground_ids: (0..10).map(|i| i * 4 + 1).collect(),
            data: Matrix::random_normal(10, 3, &mut rng),
        }
    }

    fn result() -> ShardResultMsg {
        ShardResultMsg {
            shard: 1,
            size: 25,
            indices: vec![17, 3, 88],
            f_trajectory: vec![0.5, 0.9, 1.25],
            f_final: 1.25,
            wall_seconds: 0.031,
            oracle_calls: 12,
            oracle_work: 99_000,
        }
    }

    #[test]
    fn crc32_matches_zlib_check_value() {
        // the standard CRC-32 check: crc32("123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn job_roundtrip_f32_is_lossless() {
        for with_plan in [false, true] {
            let j = job(Precision::F32, with_plan);
            let frame = encode_job(&j);
            assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Job);
            let back = decode_job(&frame).unwrap();
            assert_eq!(back, j);
        }
    }

    #[test]
    fn job_roundtrip_bf16_equals_demoted() {
        let j = job(Precision::Bf16, true);
        let frame = encode_job(&j);
        let back = decode_job(&frame).unwrap();
        // data came back demoted; everything else identical
        let want: Vec<f32> = j.data.data().iter().map(|&v| bf16_round(v)).collect();
        assert_eq!(back.data.data(), &want[..]);
        let mut j_demoted = j.clone();
        j_demoted.data = Matrix::from_vec(10, 3, want);
        assert_eq!(back, j_demoted);
        // re-encoding the decoded message is byte-stable
        assert_eq!(encode_job(&back), frame);
    }

    #[test]
    fn result_roundtrip_is_lossless() {
        let m = result();
        let frame = encode_result(&m);
        assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Result);
        assert_eq!(decode_result(&frame).unwrap(), m);
    }

    #[test]
    fn kind_confusion_is_malformed() {
        let jf = encode_job(&job(Precision::F32, false));
        let rf = encode_result(&result());
        assert!(matches!(decode_result(&jf), Err(WireError::Malformed { field: "kind", .. })));
        assert!(matches!(decode_job(&rf), Err(WireError::Malformed { field: "kind", .. })));
    }

    #[test]
    fn truncation_anywhere_is_a_typed_error_not_a_panic() {
        let frame = encode_job(&job(Precision::F32, true));
        for len in 0..frame.len() {
            let err = decode_job(&frame[..len]).unwrap_err();
            match err {
                WireError::TooShort { .. } | WireError::LengthMismatch { .. } => {}
                other => panic!("truncated to {len}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        // the header fields fail their own checks; everything else the CRC
        let frame = encode_result(&result());
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_result(&bad).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn unknown_version_is_typed() {
        let mut frame = encode_job(&job(Precision::F32, false));
        frame[4] = 9; // version 9
        assert_eq!(
            decode_job(&frame).unwrap_err(),
            WireError::UnsupportedVersion { found: 9, supported: WIRE_VERSION }
        );
    }

    #[test]
    fn hostile_counts_cannot_allocate() {
        // a job frame whose id_count claims 2^31 entries but carries none
        let j = job(Precision::F32, false);
        let mut frame = encode_job(&j);
        // find the id-count field: it sits right after the fixed-size knobs
        // (shard/k/batch = 12, str "greedy" = 4 + 6, 4 enum bytes,
        // has_threads + threads = 5, has_plan = 1) at payload offset 32
        let off = HEADER_LEN + 32;
        assert_eq!(
            u32::from_le_bytes(frame[off..off + 4].try_into().unwrap()),
            j.ground_ids.len() as u32
        );
        frame[off..off + 4].copy_from_slice(&0x8000_0000u32.to_le_bytes());
        // fix the checksum so the count check itself is what trips
        let body_len = frame.len() - TRAILER_LEN;
        let crc = crc32(&frame[..body_len]);
        frame[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode_job(&frame), Err(WireError::TooShort { .. })));
    }

    #[test]
    fn wire_plan_roundtrips_through_shard_plan() {
        let mut req = PlanRequest::new(512, 32, 6, 8);
        req.cores = 12;
        let plan = ShardPlan::plan(None, &req);
        let w = WirePlan::of(&plan);
        let back = w.to_plan();
        assert_eq!(back.n, plan.n);
        assert_eq!(back.shards, plan.shards);
        assert_eq!(back.shard_workers, plan.shard_workers);
        assert_eq!(back.oracle_threads, plan.oracle_threads);
        assert_eq!(back.merge_threads, plan.merge_threads);
        assert_eq!(WirePlan::of(&back), w);
        assert!(back.buckets.gains.is_none());
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0xBAD);
        for _ in 0..500 {
            let len = rng.below(200);
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_job(&bytes);
            let _ = decode_result(&bytes);
            let _ = frame_kind(&bytes);
        }
    }
}
