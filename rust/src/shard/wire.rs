//! Versioned, self-describing binary wire format for remote shard
//! execution — the transport seam's on-the-wire contract.
//!
//! A shard job carries everything a remote coordinator replica needs to
//! reproduce local execution: the shard's sub-matrix rows, the global
//! ground ids they map back to, the optimizer id + budget, and the
//! oracle knobs (precision / kernel / thread split) including the
//! serialized scalar core of a fleet [`ShardPlan`]. A shard result
//! carries the selection mapped back to ground ids, the per-accept
//! f-trajectory and the timing/work counters.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//!   offset  size  field
//!   ------  ----  ----------------------------------------------
//!        0     4  magic  "EBCW"  (45 42 43 57)
//!        4     2  version        (u16: 2 for data kinds, 3 for control kinds)
//!        6     1  kind           (1 = job, 2 = result, 3 = request,
//!                                 4 = hello, 5 = heartbeat, 6 = goodbye)
//!        7     1  reserved       (0)
//!        8     4  payload_len    (u32)
//!       12     N  payload        (kind-specific, see below)
//!     12+N     4  crc32          (IEEE/zlib CRC-32 of bytes [0, 12+N))
//! ```
//!
//! Job payload v2 (layout unchanged from v1):
//!
//! ```text
//!   u32 shard · u32 k · u32 batch · str optimizer
//!   u8 payload_precision · u8 precision · u8 cpu_kernel · u8 kernel_impl
//!   u8 has_threads · u32 threads
//!   u8 has_plan · [u32 n · u32 d · u32 shards · u32 k · u8 precision ·
//!                  u8 kernel_impl · u8 cpu_kernel · u32 cores ·
//!                  u32 shard_workers · u32 oracle_threads · u32 merge_threads]
//!   u32 id_count · id_count × u64 ground ids
//!   u32 rows · u32 cols · rows·cols × (f32 | bf16-as-u16) sub-matrix
//! ```
//!
//! Result payload v2 (layout unchanged from v1):
//!
//! ```text
//!   u32 shard · u32 size
//!   u32 idx_count  · idx_count  × u64 exemplar ground ids (selection order)
//!   u32 traj_count · traj_count × f32 f-trajectory
//!   f32 f_final · f64 wall_seconds · u64 oracle_calls · u64 oracle_work
//! ```
//!
//! Request payload v2 (new in v2 — the serialized form of a full
//! [`crate::api::SummarizeRequest`], the frame a client hands the
//! future TCP listener to start a run):
//!
//! ```text
//!   u32 k · u32 batch · str optimizer (registry id)
//!   u8 precision · u8 cpu_kernel · u32 threads (0 = auto)
//!   u64 seed · u8 with_baseline
//!   u8 has_shard · [u32 partitions · str partitioner · u32 per_shard_k ·
//!                   u32 threads · str transport · u32 replicas ·
//!                   u8 plan · u32 cores]
//!   u8 dataset_kind:
//!     0 inline:    u8 payload · u32 rows · u32 cols ·
//!                  rows·cols × (f32 | bf16-as-u16)
//!     1 synthetic: u32 n · u32 d · u64 seed
//!     2 imm:       u8 part · u8 state · u32 samples · u64 seed
//! ```
//!
//! Control payloads (v3, new with the TCP socket leg — see
//! [`crate::shard::net`]). Data-frame layouts above are **unchanged**:
//! kinds 1–3 still seal at version 2 byte-identically, so every v2
//! golden stays valid and v2-only decoders keep rejecting control
//! frames up front by version:
//!
//! ```text
//!   hello (4):     str id · u32 capacity
//!   heartbeat (5): str id · u64 seq
//!   goodbye (6):   str id · u8 drain · str detail
//! ```
//!
//! `cpu_kernel` bytes carry 0 = scalar, 1 = blocked, 2 = simd (the
//! code set grew with the simd backend; layouts are unchanged and
//! pre-simd decoders reject code 2 as `Malformed`, never misread it).
//!
//! Strings are `u32 len + UTF-8 bytes`. A `bf16` payload ships each
//! value as the upper 16 bits of its [`bf16_round`]-ed f32 (2 bytes per
//! scalar — the edge-link option); decoding widens back losslessly, so
//! `decode(encode(x)) == x` exactly for payloads that are already
//! bf16-representable, and equals `demote_bf16(x)` otherwise.
//!
//! The format is frozen per version: the golden conformance suite
//! (`rust/tests/wire_golden.rs`) pins the exact bytes, so any layout
//! change must bump [`WIRE_VERSION`] consciously. Decoding is total —
//! truncated, bit-flipped or unknown-version frames yield a typed
//! [`WireError`], never a panic.
//!
//! The plan section serializes only the scalar core of a
//! [`ShardPlan`]; pre-picked engine buckets are host-local handles, so
//! a remote worker re-picks them from **its** artifact manifest for the
//! plan's (n, d, P) shape — the local transports instead reuse the live
//! plan handle (see [`crate::shard::transport::ExecCtx`]).

use crate::engine::{KernelImpl, Precision, ShardPlan};
use crate::imm::{Part, ProcessState};
use crate::linalg::gemm::{bf16_round, CpuKernel};
use crate::linalg::Matrix;
use crate::runtime::artifact::PlanBuckets;
use std::fmt;

/// Frame magic: "EBCW".
pub const WIRE_MAGIC: [u8; 4] = *b"EBCW";
/// Current wire format version for **data** frames (job / result /
/// request). v2 added the request frame kind (job/result payload
/// layouts are unchanged from v1, but v1 decoders reject v2 frames by
/// version, so the bump is a conscious break). The socket leg's
/// control frames carry [`WIRE_CONTROL_VERSION`] instead — data-frame
/// layouts (and their goldens) are untouched by that bump.
pub const WIRE_VERSION: u16 = 2;
/// Wire format version for **control** frames (hello / heartbeat /
/// goodbye, new with the TCP socket leg). The decoder enforces the
/// (version, kind) pairing: a v3 job frame or a v2 hello frame is
/// [`WireError::UnsupportedVersion`].
pub const WIRE_CONTROL_VERSION: u16 = 3;
/// Fixed frame header size (magic + version + kind + reserved + len).
pub const HEADER_LEN: usize = 12;
/// Trailing checksum size.
pub const TRAILER_LEN: usize = 4;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Job,
    Result,
    /// A full summarize request (v2) — what a client sends the socket
    /// leg's listener to start a run.
    Request,
    /// A replica announcing itself on connect (v3, control).
    Hello,
    /// A replica liveness ping (v3, control) — feeds
    /// [`crate::coordinator::ReplicaRegistry::expire`].
    Heartbeat,
    /// A replica leaving — graceful drain or a job-level failure
    /// report (v3, control).
    Goodbye,
}

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Job => 1,
            FrameKind::Result => 2,
            FrameKind::Request => 3,
            FrameKind::Hello => 4,
            FrameKind::Heartbeat => 5,
            FrameKind::Goodbye => 6,
        }
    }

    /// The wire version this kind seals at: data kinds are frozen at
    /// [`WIRE_VERSION`], control kinds at [`WIRE_CONTROL_VERSION`].
    pub fn version(self) -> u16 {
        match self {
            FrameKind::Job | FrameKind::Result | FrameKind::Request => WIRE_VERSION,
            FrameKind::Hello | FrameKind::Heartbeat | FrameKind::Goodbye => WIRE_CONTROL_VERSION,
        }
    }
}

/// Typed decode failure. Every variant is reachable from corrupted or
/// foreign input; none of them panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than a field (or the fixed header) needs.
    TooShort { need: usize, have: usize },
    /// First four bytes are not [`WIRE_MAGIC`].
    BadMagic { found: [u8; 4] },
    /// Version field is newer/older than this decoder speaks.
    UnsupportedVersion { found: u16, supported: u16 },
    /// Kind byte is none of the known frame kinds.
    UnknownKind(u8),
    /// Declared payload length disagrees with the frame size.
    LengthMismatch { declared: usize, available: usize },
    /// CRC-32 trailer does not match the received bytes.
    ChecksumMismatch { stored: u32, computed: u32 },
    /// A payload field failed validation (bad enum byte, bad UTF-8,
    /// inconsistent counts, trailing bytes, ...).
    Malformed { field: &'static str, detail: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::TooShort { need, have } => {
                write!(f, "frame too short: need {need} bytes, have {have}")
            }
            WireError::BadMagic { found } => write!(f, "bad magic {found:02x?}"),
            WireError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported wire version {found} (decoder speaks {supported})")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::LengthMismatch { declared, available } => {
                write!(f, "payload length {declared} disagrees with frame ({available} available)")
            }
            WireError::ChecksumMismatch { stored, computed } => {
                write!(f, "checksum mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            WireError::Malformed { field, detail } => write!(f, "malformed {field}: {detail}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Byte-indexed CRC-32 lookup table, built at compile time. Job frames
/// embed whole sub-matrices and every sharded run checksums each frame
/// on both legs, so the checksum must run at table speed, not
/// bit-at-a-time speed.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            j += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — bit-identical to
/// `zlib.crc32`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// The serialized scalar core of a fleet [`ShardPlan`] — everything a
/// remote worker needs to rebuild the plan (bucket handles are
/// host-local; the worker re-picks them from its own manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WirePlan {
    pub n: u32,
    pub d: u32,
    pub shards: u32,
    pub k: u32,
    pub precision: Precision,
    pub kernel: KernelImpl,
    pub cpu_kernel: CpuKernel,
    pub cores: u32,
    pub shard_workers: u32,
    pub oracle_threads: u32,
    pub merge_threads: u32,
}

impl WirePlan {
    /// Capture the wire-transportable core of a live plan.
    pub fn of(plan: &ShardPlan) -> WirePlan {
        WirePlan {
            n: plan.n as u32,
            d: plan.d as u32,
            shards: plan.shards as u32,
            k: plan.k as u32,
            precision: plan.precision,
            kernel: plan.kernel,
            cpu_kernel: plan.cpu_kernel,
            cores: plan.cores as u32,
            shard_workers: plan.shard_workers as u32,
            oracle_threads: plan.oracle_threads as u32,
            merge_threads: plan.merge_threads as u32,
        }
    }

    /// Rebuild a [`ShardPlan`] with empty bucket handles (a remote
    /// worker re-picks buckets for this shape from its own manifest).
    pub fn to_plan(&self) -> ShardPlan {
        ShardPlan {
            n: self.n as usize,
            // prune knobs are local-only (never serialized): a rebuilt
            // plan picks buckets for the full window
            n_eff: self.n as usize,
            d: self.d as usize,
            shards: self.shards as usize,
            k: self.k as usize,
            precision: self.precision,
            kernel: self.kernel,
            cpu_kernel: self.cpu_kernel,
            cores: self.cores as usize,
            shard_workers: self.shard_workers as usize,
            oracle_threads: self.oracle_threads as usize,
            merge_threads: self.merge_threads as usize,
            buckets: PlanBuckets::default(),
        }
    }
}

/// One shard's first-stage work order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardJobMsg {
    /// Shard id (position in the partitioner's output).
    pub shard: u32,
    /// Selection budget for this shard (already clamped to its size).
    pub k: u32,
    /// Candidate-batch width a remote worker hands
    /// [`crate::optim::build_optimizer`] (the summarizer fills in its
    /// merge/candidate batch).
    pub batch: u32,
    /// Optimizer registry id ([`crate::optim::ALGORITHMS`]).
    ///
    /// **Remote-rebuild contract**: a worker without the live optimizer
    /// instance reconstructs `build_optimizer(optimizer, batch)` — the
    /// registry configuration at this batch width. Non-registry
    /// parameterizations (a custom `SieveStreaming { epsilon }`, say)
    /// do not survive the wire; local transports always execute with
    /// the live instance, so this only bounds the future socket leg,
    /// where the launcher must restrict fleet runs to registry
    /// optimizers (greedy-family selection is batch-invariant —
    /// `prop_greedy_batch_invariant` — so `batch` only shifts
    /// counters).
    pub optimizer: String,
    /// How the sub-matrix travels: `F32` (lossless, 4 B/scalar) or
    /// `Bf16` (demoted at encode, 2 B/scalar — the edge-link option).
    pub payload: Precision,
    /// Oracle compute precision.
    ///
    /// This and the two kernel knobs below configure the **worker-side
    /// oracle factory**: a remote worker builds its factory from them
    /// before handing jobs to `execute_job` (factory construction is
    /// backend-specific, so it lives outside the executor). Local
    /// transports run the caller's live factory, which already carries
    /// its backend config and ignores these fields.
    pub precision: Precision,
    /// CPU kernel backend for CPU/fallback oracles (see `precision`).
    pub cpu_kernel: CpuKernel,
    /// Preferred accelerator kernel implementation (see `precision`).
    pub kernel: KernelImpl,
    /// Per-oracle kernel-thread override (a planned run's split).
    pub threads: Option<u32>,
    /// Serialized fleet-plan core, when the run is planned.
    pub plan: Option<WirePlan>,
    /// Global ground ids of the sub-matrix rows (`len == data.rows()`).
    pub ground_ids: Vec<u64>,
    /// The shard's sub-matrix.
    pub data: Matrix,
}

/// One shard's first-stage outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardResultMsg {
    /// Shard id (copied from the job).
    pub shard: u32,
    /// Ground rows the shard held.
    pub size: u32,
    /// Selected exemplars as **global** ground ids, in selection order.
    pub indices: Vec<u64>,
    /// f(S) after each selection (shard-local objective).
    pub f_trajectory: Vec<f32>,
    pub f_final: f32,
    pub wall_seconds: f64,
    pub oracle_calls: u64,
    pub oracle_work: u64,
}

/// Serialized shard configuration of a [`WireRequest`] — mirrors
/// [`crate::api::ShardSpec`] field for field.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireShardSpec {
    /// Shard count P.
    pub partitions: u32,
    /// Partitioner registry id ([`crate::shard::PARTITIONERS`]).
    pub partitioner: String,
    /// Exemplars per shard in stage 1 (0 = final k).
    pub per_shard_k: u32,
    /// Stage-1 worker threads (0 = auto).
    pub threads: u32,
    /// Transport registry id ([`crate::shard::TRANSPORTS`]).
    pub transport: String,
    /// Replica count for replica transports.
    pub replicas: u32,
    /// Whether to pre-plan the run (bucket shape + core split).
    pub plan: bool,
    /// Core budget for planned runs (0 = auto).
    pub cores: u32,
}

/// Serialized dataset reference of a [`WireRequest`] — mirrors
/// [`crate::api::DatasetRef`]. Inline matrices ship at the declared
/// payload precision exactly like job sub-matrices do.
#[derive(Debug, Clone, PartialEq)]
pub enum WireDataset {
    /// The ground matrix itself, shipped in the frame.
    Inline { payload: Precision, data: Matrix },
    /// A standard-normal synthetic matrix the executor generates.
    Synthetic { n: u32, d: u32, seed: u64 },
    /// An injection-molding campaign the executor generates.
    Imm { part: Part, state: ProcessState, samples: u32, seed: u64 },
}

/// The wire form of a full [`crate::api::SummarizeRequest`] (v2,
/// kind 3): everything an executor — today's loopback leg, tomorrow's
/// TCP listener — needs to reproduce a local run. Only **registry**
/// optimizers serialize (the remote-rebuild contract on
/// [`ShardJobMsg::optimizer`] applies to whole requests too), which is
/// why [`crate::api::SummarizeRequest::validate`] rejects non-registry
/// optimizers whenever the transport is not `inproc`.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Summary cardinality.
    pub k: u32,
    /// Candidate-batch width for the batched-greedy family.
    pub batch: u32,
    /// Optimizer registry id ([`crate::optim::ALGORITHMS`]).
    pub optimizer: String,
    /// Oracle compute precision.
    pub precision: Precision,
    /// CPU kernel backend for CPU/fallback oracles.
    pub cpu_kernel: CpuKernel,
    /// Oracle kernel threads (0 = auto).
    pub threads: u32,
    /// Seed for partitioners / synthetic data.
    pub seed: u64,
    /// Run a single-node reference pass for quality accounting.
    pub with_baseline: bool,
    /// Sharding configuration; `None` = single-node run.
    pub shard: Option<WireShardSpec>,
    /// What to summarize.
    pub dataset: WireDataset,
}

/// A replica announcing itself on connect (control frame, kind 4).
/// The capacity feeds the coordinator's
/// [`crate::coordinator::ReplicaRegistry`] weighting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireHello {
    /// Replica-chosen id (informational — the coordinator keys its
    /// registry by endpoint address).
    pub id: String,
    /// Relative shard capacity (assignment weight, ≥ 1).
    pub capacity: u32,
}

/// A replica liveness ping (control frame, kind 5). The coordinator
/// refreshes the sender's registry heartbeat on every one it reads,
/// so a replica that keeps a connection alive never expires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireHeartbeat {
    /// Replica-chosen id (informational).
    pub id: String,
    /// Monotone per-connection sequence number.
    pub seq: u64,
}

/// A replica leaving (control frame, kind 6): `drain == true` is a
/// graceful hand-back (finish nothing new, re-queue elsewhere);
/// `drain == false` reports a deterministic job-level failure in
/// `detail` — the coordinator surfaces it as a typed error instead of
/// retrying it forever.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireGoodbye {
    /// Replica-chosen id (informational).
    pub id: String,
    /// Graceful drain (true) vs deterministic failure report (false).
    pub drain: bool,
    /// Failure description; empty on graceful drains.
    pub detail: String,
}

fn part_code(p: Part) -> u8 {
    match p {
        Part::Cover => 0,
        Part::Plate => 1,
    }
}

fn state_code(s: ProcessState) -> u8 {
    match s {
        ProcessState::StartUp => 0,
        ProcessState::Stable => 1,
        ProcessState::Downtimes => 2,
        ProcessState::Regrind => 3,
        ProcessState::Doe => 4,
    }
}

// ------------------------------------------------------------ encoding

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn precision_code(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::Bf16 => 1,
    }
}
fn cpu_kernel_code(k: CpuKernel) -> u8 {
    // growing the code set (2 = simd, PR 9) leaves every v2 layout
    // untouched — the field was always a free-form u8; old decoders
    // reject unknown codes as Malformed, exactly as designed
    match k {
        CpuKernel::Scalar => 0,
        CpuKernel::Blocked => 1,
        CpuKernel::Simd => 2,
    }
}
fn kernel_impl_code(k: KernelImpl) -> u8 {
    match k {
        KernelImpl::Pallas => 0,
        KernelImpl::Jnp => 1,
    }
}

/// Wrap a payload in the versioned header + CRC trailer.
///
/// The v1 length field is u32, capping payloads at 4 GiB. That is far
/// beyond any shard this system ships (a shard's sub-matrix is a
/// fraction of a window that must fit device memory), so an oversized
/// payload is a caller bug — assert loudly here rather than truncate
/// silently and fail as a confusing checksum error at decode.
fn seal_frame(kind: FrameKind, payload: Vec<u8>) -> Vec<u8> {
    assert!(
        payload.len() <= u32::MAX as usize,
        "wire v1 frames cap payloads at u32::MAX bytes, got {}",
        payload.len()
    );
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    frame.extend_from_slice(&WIRE_MAGIC);
    put_u16(&mut frame, kind.version());
    frame.push(kind.code());
    frame.push(0); // reserved
    put_u32(&mut frame, payload.len() as u32);
    frame.extend_from_slice(&payload);
    let crc = crc32(&frame);
    put_u32(&mut frame, crc);
    frame
}

/// Encode a job message into one sealed frame.
pub fn encode_job(job: &ShardJobMsg) -> Vec<u8> {
    let mut p = Vec::with_capacity(64 + job.ground_ids.len() * 8 + job.data.data().len() * 4);
    put_u32(&mut p, job.shard);
    put_u32(&mut p, job.k);
    put_u32(&mut p, job.batch);
    put_str(&mut p, &job.optimizer);
    p.push(precision_code(job.payload));
    p.push(precision_code(job.precision));
    p.push(cpu_kernel_code(job.cpu_kernel));
    p.push(kernel_impl_code(job.kernel));
    match job.threads {
        Some(t) => {
            p.push(1);
            put_u32(&mut p, t);
        }
        None => {
            p.push(0);
            put_u32(&mut p, 0);
        }
    }
    match &job.plan {
        Some(w) => {
            p.push(1);
            put_u32(&mut p, w.n);
            put_u32(&mut p, w.d);
            put_u32(&mut p, w.shards);
            put_u32(&mut p, w.k);
            p.push(precision_code(w.precision));
            p.push(kernel_impl_code(w.kernel));
            p.push(cpu_kernel_code(w.cpu_kernel));
            put_u32(&mut p, w.cores);
            put_u32(&mut p, w.shard_workers);
            put_u32(&mut p, w.oracle_threads);
            put_u32(&mut p, w.merge_threads);
        }
        None => p.push(0),
    }
    put_u32(&mut p, job.ground_ids.len() as u32);
    for &id in &job.ground_ids {
        put_u64(&mut p, id);
    }
    put_matrix(&mut p, job.payload, &job.data);
    seal_frame(FrameKind::Job, p)
}

/// `u32 rows · u32 cols · rows·cols × (f32 | bf16)` — shared by job and
/// request frames.
fn put_matrix(p: &mut Vec<u8>, payload: Precision, m: &Matrix) {
    put_u32(p, m.rows() as u32);
    put_u32(p, m.cols() as u32);
    match payload {
        Precision::F32 => {
            for &v in m.data() {
                put_f32(p, v);
            }
        }
        Precision::Bf16 => {
            for &v in m.data() {
                let hi = (bf16_round(v).to_bits() >> 16) as u16;
                put_u16(p, hi);
            }
        }
    }
}

/// Encode a result message into one sealed frame.
pub fn encode_result(res: &ShardResultMsg) -> Vec<u8> {
    let mut p = Vec::with_capacity(48 + res.indices.len() * 8 + res.f_trajectory.len() * 4);
    put_u32(&mut p, res.shard);
    put_u32(&mut p, res.size);
    put_u32(&mut p, res.indices.len() as u32);
    for &i in &res.indices {
        put_u64(&mut p, i);
    }
    put_u32(&mut p, res.f_trajectory.len() as u32);
    for &f in &res.f_trajectory {
        put_f32(&mut p, f);
    }
    put_f32(&mut p, res.f_final);
    put_f64(&mut p, res.wall_seconds);
    put_u64(&mut p, res.oracle_calls);
    put_u64(&mut p, res.oracle_work);
    seal_frame(FrameKind::Result, p)
}

/// Encode a request message into one sealed frame.
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut p = Vec::with_capacity(96);
    put_u32(&mut p, req.k);
    put_u32(&mut p, req.batch);
    put_str(&mut p, &req.optimizer);
    p.push(precision_code(req.precision));
    p.push(cpu_kernel_code(req.cpu_kernel));
    put_u32(&mut p, req.threads);
    put_u64(&mut p, req.seed);
    p.push(req.with_baseline as u8);
    match &req.shard {
        Some(s) => {
            p.push(1);
            put_u32(&mut p, s.partitions);
            put_str(&mut p, &s.partitioner);
            put_u32(&mut p, s.per_shard_k);
            put_u32(&mut p, s.threads);
            put_str(&mut p, &s.transport);
            put_u32(&mut p, s.replicas);
            p.push(s.plan as u8);
            put_u32(&mut p, s.cores);
        }
        None => p.push(0),
    }
    match &req.dataset {
        WireDataset::Inline { payload, data } => {
            p.push(0);
            p.push(precision_code(*payload));
            put_matrix(&mut p, *payload, data);
        }
        WireDataset::Synthetic { n, d, seed } => {
            p.push(1);
            put_u32(&mut p, *n);
            put_u32(&mut p, *d);
            put_u64(&mut p, *seed);
        }
        WireDataset::Imm { part, state, samples, seed } => {
            p.push(2);
            p.push(part_code(*part));
            p.push(state_code(*state));
            put_u32(&mut p, *samples);
            put_u64(&mut p, *seed);
        }
    }
    seal_frame(FrameKind::Request, p)
}

/// Encode a hello control frame (v3).
pub fn encode_hello(h: &WireHello) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + h.id.len());
    put_str(&mut p, &h.id);
    put_u32(&mut p, h.capacity);
    seal_frame(FrameKind::Hello, p)
}

/// Encode a heartbeat control frame (v3).
pub fn encode_heartbeat(h: &WireHeartbeat) -> Vec<u8> {
    let mut p = Vec::with_capacity(12 + h.id.len());
    put_str(&mut p, &h.id);
    put_u64(&mut p, h.seq);
    seal_frame(FrameKind::Heartbeat, p)
}

/// Encode a goodbye control frame (v3).
pub fn encode_goodbye(g: &WireGoodbye) -> Vec<u8> {
    let mut p = Vec::with_capacity(9 + g.id.len() + g.detail.len());
    put_str(&mut p, &g.id);
    p.push(g.drain as u8);
    put_str(&mut p, &g.detail);
    seal_frame(FrameKind::Goodbye, p)
}

// ------------------------------------------------------------ decoding

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.i.checked_add(n).ok_or_else(|| WireError::TooShort {
            need: usize::MAX,
            have: self.b.len(),
        })?;
        if end > self.b.len() {
            return Err(WireError::TooShort { need: end, have: self.b.len() });
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self, field: &'static str) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| WireError::Malformed {
            field,
            detail: format!("invalid utf-8: {e}"),
        })
    }

    fn precision(&mut self, field: &'static str) -> Result<Precision, WireError> {
        match self.u8()? {
            0 => Ok(Precision::F32),
            1 => Ok(Precision::Bf16),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown precision code {other}"),
            }),
        }
    }

    fn cpu_kernel(&mut self, field: &'static str) -> Result<CpuKernel, WireError> {
        match self.u8()? {
            0 => Ok(CpuKernel::Scalar),
            1 => Ok(CpuKernel::Blocked),
            2 => Ok(CpuKernel::Simd),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown cpu kernel code {other}"),
            }),
        }
    }

    fn kernel_impl(&mut self, field: &'static str) -> Result<KernelImpl, WireError> {
        match self.u8()? {
            0 => Ok(KernelImpl::Pallas),
            1 => Ok(KernelImpl::Jnp),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown kernel impl code {other}"),
            }),
        }
    }

    fn part(&mut self, field: &'static str) -> Result<Part, WireError> {
        match self.u8()? {
            0 => Ok(Part::Cover),
            1 => Ok(Part::Plate),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown part code {other}"),
            }),
        }
    }

    fn state(&mut self, field: &'static str) -> Result<ProcessState, WireError> {
        match self.u8()? {
            0 => Ok(ProcessState::StartUp),
            1 => Ok(ProcessState::Stable),
            2 => Ok(ProcessState::Downtimes),
            3 => Ok(ProcessState::Regrind),
            4 => Ok(ProcessState::Doe),
            other => Err(WireError::Malformed {
                field,
                detail: format!("unknown process state code {other}"),
            }),
        }
    }

    fn flag(&mut self, field: &'static str) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(WireError::Malformed {
                field,
                detail: format!("flag byte must be 0 or 1, got {other}"),
            }),
        }
    }

    /// A declared element count must fit the bytes that remain —
    /// checked before any allocation so a hostile count cannot OOM.
    fn count(&mut self, elem_size: usize, field: &'static str) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(elem_size).ok_or_else(|| WireError::Malformed {
            field,
            detail: format!("count {n} overflows"),
        })?;
        if need > self.remaining() {
            return Err(WireError::TooShort {
                need: self.i + need,
                have: self.b.len(),
            });
        }
        Ok(n)
    }
}

/// Validate the header + checksum of a frame and classify its kind.
pub fn frame_kind(frame: &[u8]) -> Result<FrameKind, WireError> {
    let min = HEADER_LEN + TRAILER_LEN;
    if frame.len() < min {
        return Err(WireError::TooShort { need: min, have: frame.len() });
    }
    let magic: [u8; 4] = frame[0..4].try_into().unwrap();
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic { found: magic });
    }
    let version = u16::from_le_bytes(frame[4..6].try_into().unwrap());
    // versions this decoder has ever spoken: anything else is rejected
    // before the kind byte is even interpreted (a v9 frame may use kind
    // codes we have never assigned)
    if version != WIRE_VERSION && version != WIRE_CONTROL_VERSION {
        return Err(WireError::UnsupportedVersion { found: version, supported: WIRE_VERSION });
    }
    let kind = match frame[6] {
        1 => FrameKind::Job,
        2 => FrameKind::Result,
        3 => FrameKind::Request,
        4 => FrameKind::Hello,
        5 => FrameKind::Heartbeat,
        6 => FrameKind::Goodbye,
        other => return Err(WireError::UnknownKind(other)),
    };
    // data kinds are sealed at v2, control kinds at v3 — a mismatched
    // pairing (v3 job, v2 hello) is a version error, keeping every v2
    // data layout byte-frozen across the control-frame addition
    if version != kind.version() {
        return Err(WireError::UnsupportedVersion { found: version, supported: kind.version() });
    }
    let declared = u32::from_le_bytes(frame[8..12].try_into().unwrap()) as usize;
    let available = frame.len() - min;
    if declared != available {
        return Err(WireError::LengthMismatch { declared, available });
    }
    let body = &frame[..frame.len() - TRAILER_LEN];
    let stored = u32::from_le_bytes(frame[frame.len() - TRAILER_LEN..].try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        return Err(WireError::ChecksumMismatch { stored, computed });
    }
    Ok(kind)
}

fn open_frame(frame: &[u8], want: FrameKind) -> Result<&[u8], WireError> {
    let kind = frame_kind(frame)?;
    if kind != want {
        return Err(WireError::Malformed {
            field: "kind",
            detail: format!("expected {want:?} frame, got {kind:?}"),
        });
    }
    Ok(&frame[HEADER_LEN..frame.len() - TRAILER_LEN])
}

/// Decode a job frame. Total: corrupted input yields a [`WireError`].
pub fn decode_job(frame: &[u8]) -> Result<ShardJobMsg, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Job)?);
    let shard = r.u32()?;
    let k = r.u32()?;
    let batch = r.u32()?;
    let optimizer = r.str("optimizer")?;
    let payload = r.precision("payload_precision")?;
    let precision = r.precision("precision")?;
    let cpu_kernel = r.cpu_kernel("cpu_kernel")?;
    let kernel = r.kernel_impl("kernel_impl")?;
    let has_threads = r.flag("has_threads")?;
    let threads_raw = r.u32()?;
    let threads = has_threads.then_some(threads_raw);
    let plan = if r.flag("has_plan")? {
        Some(WirePlan {
            n: r.u32()?,
            d: r.u32()?,
            shards: r.u32()?,
            k: r.u32()?,
            precision: r.precision("plan.precision")?,
            kernel: r.kernel_impl("plan.kernel")?,
            cpu_kernel: r.cpu_kernel("plan.cpu_kernel")?,
            cores: r.u32()?,
            shard_workers: r.u32()?,
            oracle_threads: r.u32()?,
            merge_threads: r.u32()?,
        })
    } else {
        None
    };
    let id_count = r.count(8, "ground_ids")?;
    let mut ground_ids = Vec::with_capacity(id_count);
    for _ in 0..id_count {
        ground_ids.push(r.u64()?);
    }
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    if rows != ground_ids.len() {
        return Err(WireError::Malformed {
            field: "rows",
            detail: format!("{rows} rows but {} ground ids", ground_ids.len()),
        });
    }
    let elems = rows.checked_mul(cols).ok_or_else(|| WireError::Malformed {
        field: "rows",
        detail: format!("{rows}x{cols} overflows"),
    })?;
    let elem_size = match payload {
        Precision::F32 => 4,
        Precision::Bf16 => 2,
    };
    let need = elems.checked_mul(elem_size).ok_or_else(|| WireError::Malformed {
        field: "data",
        detail: format!("{elems} elements overflow"),
    })?;
    if need != r.remaining() {
        return Err(WireError::Malformed {
            field: "data",
            detail: format!("expected {need} data bytes, have {}", r.remaining()),
        });
    }
    let mut data = Vec::with_capacity(elems);
    match payload {
        Precision::F32 => {
            for _ in 0..elems {
                data.push(r.f32()?);
            }
        }
        Precision::Bf16 => {
            for _ in 0..elems {
                data.push(f32::from_bits((r.u16()? as u32) << 16));
            }
        }
    }
    Ok(ShardJobMsg {
        shard,
        k,
        batch,
        optimizer,
        payload,
        precision,
        cpu_kernel,
        kernel,
        threads,
        plan,
        ground_ids,
        data: Matrix::from_vec(rows, cols, data),
    })
}

/// Decode a result frame. Total: corrupted input yields a [`WireError`].
pub fn decode_result(frame: &[u8]) -> Result<ShardResultMsg, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Result)?);
    let shard = r.u32()?;
    let size = r.u32()?;
    let idx_count = r.count(8, "indices")?;
    let mut indices = Vec::with_capacity(idx_count);
    for _ in 0..idx_count {
        indices.push(r.u64()?);
    }
    let traj_count = r.count(4, "f_trajectory")?;
    let mut f_trajectory = Vec::with_capacity(traj_count);
    for _ in 0..traj_count {
        f_trajectory.push(r.f32()?);
    }
    let f_final = r.f32()?;
    let wall_seconds = r.f64()?;
    let oracle_calls = r.u64()?;
    let oracle_work = r.u64()?;
    if r.remaining() != 0 {
        return Err(WireError::Malformed {
            field: "payload",
            detail: format!("{} trailing bytes", r.remaining()),
        });
    }
    Ok(ShardResultMsg {
        shard,
        size,
        indices,
        f_trajectory,
        f_final,
        wall_seconds,
        oracle_calls,
        oracle_work,
    })
}

/// Decode a request frame. Total: corrupted input yields a
/// [`WireError`]. Decoding is purely syntactic — semantic checks
/// (registry membership, k ≤ n, ...) belong to
/// [`crate::api::SummarizeRequest::validate`].
pub fn decode_request(frame: &[u8]) -> Result<WireRequest, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Request)?);
    let k = r.u32()?;
    let batch = r.u32()?;
    let optimizer = r.str("optimizer")?;
    let precision = r.precision("precision")?;
    let cpu_kernel = r.cpu_kernel("cpu_kernel")?;
    let threads = r.u32()?;
    let seed = r.u64()?;
    let with_baseline = r.flag("with_baseline")?;
    let shard = if r.flag("has_shard")? {
        Some(WireShardSpec {
            partitions: r.u32()?,
            partitioner: r.str("shard.partitioner")?,
            per_shard_k: r.u32()?,
            threads: r.u32()?,
            transport: r.str("shard.transport")?,
            replicas: r.u32()?,
            plan: r.flag("shard.plan")?,
            cores: r.u32()?,
        })
    } else {
        None
    };
    let dataset = match r.u8()? {
        0 => {
            let payload = r.precision("dataset.payload")?;
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let elems = rows.checked_mul(cols).ok_or_else(|| WireError::Malformed {
                field: "dataset.rows",
                detail: format!("{rows}x{cols} overflows"),
            })?;
            let elem_size = match payload {
                Precision::F32 => 4,
                Precision::Bf16 => 2,
            };
            let need = elems.checked_mul(elem_size).ok_or_else(|| WireError::Malformed {
                field: "dataset.data",
                detail: format!("{elems} elements overflow"),
            })?;
            if need != r.remaining() {
                return Err(WireError::Malformed {
                    field: "dataset.data",
                    detail: format!("expected {need} data bytes, have {}", r.remaining()),
                });
            }
            let mut data = Vec::with_capacity(elems);
            match payload {
                Precision::F32 => {
                    for _ in 0..elems {
                        data.push(r.f32()?);
                    }
                }
                Precision::Bf16 => {
                    for _ in 0..elems {
                        data.push(f32::from_bits((r.u16()? as u32) << 16));
                    }
                }
            }
            WireDataset::Inline { payload, data: Matrix::from_vec(rows, cols, data) }
        }
        1 => WireDataset::Synthetic { n: r.u32()?, d: r.u32()?, seed: r.u64()? },
        2 => WireDataset::Imm {
            part: r.part("dataset.part")?,
            state: r.state("dataset.state")?,
            samples: r.u32()?,
            seed: r.u64()?,
        },
        other => {
            return Err(WireError::Malformed {
                field: "dataset_kind",
                detail: format!("unknown dataset kind {other}"),
            })
        }
    };
    if r.remaining() != 0 {
        return Err(WireError::Malformed {
            field: "payload",
            detail: format!("{} trailing bytes", r.remaining()),
        });
    }
    Ok(WireRequest {
        k,
        batch,
        optimizer,
        precision,
        cpu_kernel,
        threads,
        seed,
        with_baseline,
        shard,
        dataset,
    })
}

fn end_of_payload(r: &Reader<'_>) -> Result<(), WireError> {
    if r.remaining() != 0 {
        return Err(WireError::Malformed {
            field: "payload",
            detail: format!("{} trailing bytes", r.remaining()),
        });
    }
    Ok(())
}

/// Decode a hello control frame. Total: corrupted input yields a
/// [`WireError`].
pub fn decode_hello(frame: &[u8]) -> Result<WireHello, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Hello)?);
    let id = r.str("hello.id")?;
    let capacity = r.u32()?;
    end_of_payload(&r)?;
    Ok(WireHello { id, capacity })
}

/// Decode a heartbeat control frame. Total: corrupted input yields a
/// [`WireError`].
pub fn decode_heartbeat(frame: &[u8]) -> Result<WireHeartbeat, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Heartbeat)?);
    let id = r.str("heartbeat.id")?;
    let seq = r.u64()?;
    end_of_payload(&r)?;
    Ok(WireHeartbeat { id, seq })
}

/// Decode a goodbye control frame. Total: corrupted input yields a
/// [`WireError`].
pub fn decode_goodbye(frame: &[u8]) -> Result<WireGoodbye, WireError> {
    let mut r = Reader::new(open_frame(frame, FrameKind::Goodbye)?);
    let id = r.str("goodbye.id")?;
    let drain = r.flag("goodbye.drain")?;
    let detail = r.str("goodbye.detail")?;
    end_of_payload(&r)?;
    Ok(WireGoodbye { id, drain, detail })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlanRequest;
    use crate::util::rng::Rng;

    fn job(payload: Precision, with_plan: bool) -> ShardJobMsg {
        let mut rng = Rng::new(7);
        let plan = with_plan.then(|| {
            let mut req = PlanRequest::new(40, 3, 4, 5);
            req.cores = 8;
            WirePlan::of(&ShardPlan::plan(None, &req))
        });
        ShardJobMsg {
            shard: 2,
            k: 5,
            batch: 256,
            optimizer: "greedy".into(),
            payload,
            precision: Precision::F32,
            cpu_kernel: CpuKernel::Blocked,
            kernel: KernelImpl::Jnp,
            threads: Some(3),
            plan,
            ground_ids: (0..10).map(|i| i * 4 + 1).collect(),
            data: Matrix::random_normal(10, 3, &mut rng),
        }
    }

    fn result() -> ShardResultMsg {
        ShardResultMsg {
            shard: 1,
            size: 25,
            indices: vec![17, 3, 88],
            f_trajectory: vec![0.5, 0.9, 1.25],
            f_final: 1.25,
            wall_seconds: 0.031,
            oracle_calls: 12,
            oracle_work: 99_000,
        }
    }

    #[test]
    fn crc32_matches_zlib_check_value() {
        // the standard CRC-32 check: crc32("123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn job_roundtrip_f32_is_lossless() {
        for with_plan in [false, true] {
            let j = job(Precision::F32, with_plan);
            let frame = encode_job(&j);
            assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Job);
            let back = decode_job(&frame).unwrap();
            assert_eq!(back, j);
        }
    }

    #[test]
    fn job_roundtrip_bf16_equals_demoted() {
        let j = job(Precision::Bf16, true);
        let frame = encode_job(&j);
        let back = decode_job(&frame).unwrap();
        // data came back demoted; everything else identical
        let want: Vec<f32> = j.data.data().iter().map(|&v| bf16_round(v)).collect();
        assert_eq!(back.data.data(), &want[..]);
        let mut j_demoted = j.clone();
        j_demoted.data = Matrix::from_vec(10, 3, want);
        assert_eq!(back, j_demoted);
        // re-encoding the decoded message is byte-stable
        assert_eq!(encode_job(&back), frame);
    }

    #[test]
    fn simd_cpu_kernel_code_roundtrips_everywhere_it_appears() {
        // job knob + plan section + request knob all carry code 2
        let mut j = job(Precision::F32, true);
        j.cpu_kernel = CpuKernel::Simd;
        if let Some(plan) = &mut j.plan {
            plan.cpu_kernel = CpuKernel::Simd;
        }
        let back = decode_job(&encode_job(&j)).unwrap();
        assert_eq!(back.cpu_kernel, CpuKernel::Simd);
        assert_eq!(back.plan.unwrap().cpu_kernel, CpuKernel::Simd);

        let mut req = request(WireDataset::Synthetic { n: 10, d: 2, seed: 1 });
        req.cpu_kernel = CpuKernel::Simd;
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);

        // a pre-simd decoder's behaviour: code 3 is still Malformed
        let mut frame = encode_job(&j);
        // cpu_kernel byte sits after shard/k/batch (12) + str "greedy"
        // (10) + payload/precision (2) at payload offset 24
        let off = HEADER_LEN + 24;
        assert_eq!(frame[off], 2);
        frame[off] = 3;
        reseal(&mut frame);
        assert!(matches!(
            decode_job(&frame),
            Err(WireError::Malformed { field: "cpu_kernel", .. })
        ));
    }

    #[test]
    fn result_roundtrip_is_lossless() {
        let m = result();
        let frame = encode_result(&m);
        assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Result);
        assert_eq!(decode_result(&frame).unwrap(), m);
    }

    #[test]
    fn kind_confusion_is_malformed() {
        let jf = encode_job(&job(Precision::F32, false));
        let rf = encode_result(&result());
        assert!(matches!(decode_result(&jf), Err(WireError::Malformed { field: "kind", .. })));
        assert!(matches!(decode_job(&rf), Err(WireError::Malformed { field: "kind", .. })));
    }

    #[test]
    fn truncation_anywhere_is_a_typed_error_not_a_panic() {
        let frame = encode_job(&job(Precision::F32, true));
        for len in 0..frame.len() {
            let err = decode_job(&frame[..len]).unwrap_err();
            match err {
                WireError::TooShort { .. } | WireError::LengthMismatch { .. } => {}
                other => panic!("truncated to {len}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        // the header fields fail their own checks; everything else the CRC
        let frame = encode_result(&result());
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_result(&bad).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn unknown_version_is_typed() {
        let mut frame = encode_job(&job(Precision::F32, false));
        frame[4] = 9; // version 9
        assert_eq!(
            decode_job(&frame).unwrap_err(),
            WireError::UnsupportedVersion { found: 9, supported: WIRE_VERSION }
        );
    }

    #[test]
    fn hostile_counts_cannot_allocate() {
        // a job frame whose id_count claims 2^31 entries but carries none
        let j = job(Precision::F32, false);
        let mut frame = encode_job(&j);
        // find the id-count field: it sits right after the fixed-size knobs
        // (shard/k/batch = 12, str "greedy" = 4 + 6, 4 enum bytes,
        // has_threads + threads = 5, has_plan = 1) at payload offset 32
        let off = HEADER_LEN + 32;
        assert_eq!(
            u32::from_le_bytes(frame[off..off + 4].try_into().unwrap()),
            j.ground_ids.len() as u32
        );
        frame[off..off + 4].copy_from_slice(&0x8000_0000u32.to_le_bytes());
        // fix the checksum so the count check itself is what trips
        let body_len = frame.len() - TRAILER_LEN;
        let crc = crc32(&frame[..body_len]);
        frame[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode_job(&frame), Err(WireError::TooShort { .. })));
    }

    #[test]
    fn wire_plan_roundtrips_through_shard_plan() {
        let mut req = PlanRequest::new(512, 32, 6, 8);
        req.cores = 12;
        let plan = ShardPlan::plan(None, &req);
        let w = WirePlan::of(&plan);
        let back = w.to_plan();
        assert_eq!(back.n, plan.n);
        assert_eq!(back.shards, plan.shards);
        assert_eq!(back.shard_workers, plan.shard_workers);
        assert_eq!(back.oracle_threads, plan.oracle_threads);
        assert_eq!(back.merge_threads, plan.merge_threads);
        assert_eq!(WirePlan::of(&back), w);
        assert!(back.buckets.gains.is_none());
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0xBAD);
        for _ in 0..500 {
            let len = rng.below(200);
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_job(&bytes);
            let _ = decode_result(&bytes);
            let _ = decode_request(&bytes);
            let _ = decode_hello(&bytes);
            let _ = decode_heartbeat(&bytes);
            let _ = decode_goodbye(&bytes);
            let _ = frame_kind(&bytes);
        }
    }

    fn reseal(frame: &mut [u8]) {
        let body_len = frame.len() - TRAILER_LEN;
        let crc = crc32(&frame[..body_len]);
        frame[body_len..].copy_from_slice(&crc.to_le_bytes());
    }

    #[test]
    fn control_roundtrips_are_lossless() {
        let h = WireHello { id: "replica-7".into(), capacity: 4 };
        let frame = encode_hello(&h);
        assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Hello);
        assert_eq!(decode_hello(&frame).unwrap(), h);

        let b = WireHeartbeat { id: "replica-7".into(), seq: u64::MAX - 1 };
        let frame = encode_heartbeat(&b);
        assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Heartbeat);
        assert_eq!(decode_heartbeat(&frame).unwrap(), b);

        for drain in [false, true] {
            let g = WireGoodbye {
                id: "replica-7".into(),
                drain,
                detail: if drain { String::new() } else { "oracle: unknown optimizer".into() },
            };
            let frame = encode_goodbye(&g);
            assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Goodbye);
            assert_eq!(decode_goodbye(&frame).unwrap(), g);
        }
    }

    #[test]
    fn control_frames_seal_at_the_control_version() {
        // data kinds stay at v2 byte-for-byte; control kinds seal at v3
        let data = encode_result(&result());
        assert_eq!(u16::from_le_bytes([data[4], data[5]]), WIRE_VERSION);
        let ctrl = encode_heartbeat(&WireHeartbeat { id: "r".into(), seq: 0 });
        assert_eq!(u16::from_le_bytes([ctrl[4], ctrl[5]]), WIRE_CONTROL_VERSION);
    }

    #[test]
    fn cross_version_pairing_is_rejected() {
        // a control kind claiming the data version (and vice versa) is a
        // typed version error naming the version that kind actually wants,
        // even with a fixed-up checksum
        let mut ctrl = encode_hello(&WireHello { id: "r".into(), capacity: 1 });
        ctrl[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
        reseal(&mut ctrl);
        assert_eq!(
            decode_hello(&ctrl).unwrap_err(),
            WireError::UnsupportedVersion {
                found: WIRE_VERSION,
                supported: WIRE_CONTROL_VERSION
            }
        );
        let mut data = encode_result(&result());
        data[4..6].copy_from_slice(&WIRE_CONTROL_VERSION.to_le_bytes());
        reseal(&mut data);
        assert_eq!(
            decode_result(&data).unwrap_err(),
            WireError::UnsupportedVersion {
                found: WIRE_CONTROL_VERSION,
                supported: WIRE_VERSION
            }
        );
    }

    #[test]
    fn control_kind_confusion_is_malformed() {
        let hello = encode_hello(&WireHello { id: "r".into(), capacity: 1 });
        let beat = encode_heartbeat(&WireHeartbeat { id: "r".into(), seq: 3 });
        assert!(matches!(
            decode_heartbeat(&hello),
            Err(WireError::Malformed { field: "kind", .. })
        ));
        assert!(matches!(decode_goodbye(&beat), Err(WireError::Malformed { field: "kind", .. })));
        // and control/data confusion in both directions
        let jf = encode_job(&job(Precision::F32, false));
        assert!(matches!(decode_hello(&jf), Err(WireError::Malformed { field: "kind", .. })));
        assert!(matches!(decode_job(&hello), Err(WireError::Malformed { field: "kind", .. })));
    }

    #[test]
    fn control_truncation_and_bit_flips_are_typed() {
        let frame = encode_goodbye(&WireGoodbye {
            id: "replica-3".into(),
            drain: false,
            detail: "connection reset mid-job".into(),
        });
        for len in 0..frame.len() {
            match decode_goodbye(&frame[..len]) {
                Err(WireError::TooShort { .. }) | Err(WireError::LengthMismatch { .. }) => {}
                other => panic!("truncated to {len}: {other:?}"),
            }
        }
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_goodbye(&bad).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn control_trailing_bytes_are_malformed() {
        // a resealed hello payload with one stray byte after the fields
        let mut p = Vec::new();
        put_str(&mut p, "r1");
        put_u32(&mut p, 4);
        p.push(0);
        let frame = seal_frame(FrameKind::Hello, p);
        assert!(matches!(
            decode_hello(&frame),
            Err(WireError::Malformed { field: "payload", .. })
        ));
    }

    fn request(dataset: WireDataset) -> WireRequest {
        WireRequest {
            k: 5,
            batch: 512,
            optimizer: "greedy".into(),
            precision: Precision::F32,
            cpu_kernel: CpuKernel::Blocked,
            threads: 2,
            seed: 0xEBC,
            with_baseline: true,
            shard: Some(WireShardSpec {
                partitions: 4,
                partitioner: "locality".into(),
                per_shard_k: 0,
                threads: 0,
                transport: "loopback".into(),
                replicas: 3,
                plan: true,
                cores: 8,
            }),
            dataset,
        }
    }

    #[test]
    fn request_roundtrip_every_dataset_kind() {
        use crate::imm::{Part, ProcessState};
        let mut rng = Rng::new(11);
        let datasets = [
            WireDataset::Inline {
                payload: Precision::F32,
                data: Matrix::random_normal(6, 3, &mut rng),
            },
            WireDataset::Synthetic { n: 500, d: 32, seed: 7 },
            WireDataset::Imm {
                part: Part::Plate,
                state: ProcessState::Regrind,
                samples: 256,
                seed: 9,
            },
        ];
        for dataset in datasets {
            let mut req = request(dataset);
            let frame = encode_request(&req);
            assert_eq!(frame_kind(&frame).unwrap(), FrameKind::Request);
            assert_eq!(decode_request(&frame).unwrap(), req);
            // single-node requests round-trip too
            req.shard = None;
            let frame = encode_request(&req);
            assert_eq!(decode_request(&frame).unwrap(), req);
        }
    }

    #[test]
    fn request_bf16_inline_dataset_equals_demoted() {
        let mut rng = Rng::new(13);
        let m = Matrix::random_normal(4, 3, &mut rng);
        let req = request(WireDataset::Inline {
            payload: Precision::Bf16,
            data: m.clone(),
        });
        let frame = encode_request(&req);
        let back = decode_request(&frame).unwrap();
        let want: Vec<f32> = m.data().iter().map(|&v| bf16_round(v)).collect();
        match &back.dataset {
            WireDataset::Inline { payload: Precision::Bf16, data } => {
                assert_eq!(data.data(), &want[..]);
            }
            other => panic!("{other:?}"),
        }
        // demotion is idempotent: the re-encode is byte-stable
        assert_eq!(encode_request(&back), frame);
    }

    #[test]
    fn request_kind_confusion_and_truncation_are_typed() {
        let rf = encode_request(&request(WireDataset::Synthetic { n: 10, d: 2, seed: 1 }));
        assert!(matches!(decode_job(&rf), Err(WireError::Malformed { field: "kind", .. })));
        assert!(matches!(decode_result(&rf), Err(WireError::Malformed { field: "kind", .. })));
        for len in 0..rf.len() {
            match decode_request(&rf[..len]) {
                Err(WireError::TooShort { .. }) | Err(WireError::LengthMismatch { .. }) => {}
                other => panic!("truncated to {len}: {other:?}"),
            }
        }
    }
}
