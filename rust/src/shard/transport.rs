//! The shard transport seam: how first-stage shard jobs reach their
//! executors.
//!
//! Every transport speaks the [`crate::shard::wire`] format on **both**
//! legs — jobs are encoded and re-decoded before execution, results are
//! encoded and re-decoded before they return — so the wire contract is
//! exercised on every sharded run, not just on remote ones, and a
//! remote implementation cannot drift from the local semantics without
//! a test catching it.
//!
//! Three implementations:
//!
//! * [`InProcessTransport`] — the threadpool path: jobs fan out over
//!   [`par_map`] workers in this process. The default.
//! * [`LoopbackReplicaTransport`] — the replica path: jobs are dealt
//!   across registered worker replicas
//!   ([`crate::coordinator::replica::ReplicaRegistry`]) by capacity;
//!   a replica failing mid-run gets its unfinished shards re-queued to
//!   the survivors (counted as `shard_retries`), and a drained replica
//!   receives no new shards. Replicas execute in-process here — the
//!   registry/assignment/retry machinery is exactly what the socket
//!   transport reuses, with the loopback call replaced by a connection.
//! * [`crate::shard::net::TcpReplicaTransport`] — the socket path: the
//!   same registry machinery over real TCP connections to
//!   [`crate::shard::net::ReplicaServer`] processes, with deadlines,
//!   jittered-backoff retries and optional deterministic fault
//!   injection ([`crate::shard::fault`]).
//!
//! Execution itself ([`execute_job`]) is a pure function of the decoded
//! job: build the oracle through the factory seam, run the optimizer,
//! map the selection back to ground ids. Local transports pass the live
//! optimizer and plan through [`ExecCtx`]; a true remote worker
//! reconstructs both from the job alone ([`ExecCtx::remote`] — the
//! registry optimizer by id, the plan from its serialized scalar core).

use crate::engine::{OracleSpec, ShardPlan};
use crate::obs;
use crate::optim::{build_optimizer, Optimizer};
use crate::shard::summarizer::ShardOracleFactory;
use crate::shard::wire::{
    decode_job, decode_result, encode_job, encode_result, ShardJobMsg, ShardResultMsg, WireError,
};
use crate::util::threadpool::par_map;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

fn wire_encode_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::WIRE_ENCODE_SECONDS, "wire frame encode latency (seconds)")
    })
}

fn wire_decode_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::WIRE_DECODE_SECONDS, "wire frame decode latency (seconds)")
    })
}

pub use crate::coordinator::replica::{Replica, ReplicaRegistry, ReplicaState};

/// Transport names accepted by [`build_transport`] (and therefore by
/// `shard.transport` in the config schema and the CLI flag).
pub const TRANSPORTS: &[&str] = &["inproc", "loopback", "tcp"];

/// Why a transport could not complete a job set.
#[derive(Debug)]
pub enum TransportError {
    /// A frame failed to decode (corruption on a real link; a bug in a
    /// loopback one).
    Wire(WireError),
    /// The job names an optimizer the executor's registry lacks.
    UnknownOptimizer(String),
    /// No assignable replica remains while shards are still unassigned.
    NoReplicas { unassigned: usize },
    /// A remote replica reported a deterministic job failure (goodbye
    /// frame with `drain = false`) — retrying elsewhere cannot help.
    Replica { id: String, detail: String },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Wire(e) => write!(f, "wire error: {e}"),
            TransportError::UnknownOptimizer(name) => {
                write!(f, "job optimizer '{name}' is not in the registry")
            }
            TransportError::NoReplicas { unassigned } => {
                write!(f, "no assignable replica left ({unassigned} shard(s) unassigned)")
            }
            TransportError::Replica { id, detail } => {
                write!(f, "replica '{id}' failed the job: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

/// Cumulative transport counters (monotone; read via
/// [`ShardTransport::stats`], diffed per run by the summarizer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TransportSnapshot {
    /// Bytes that crossed the wire (job + result frames, both legs).
    pub wire_bytes: u64,
    /// Shards re-queued after a replica failure.
    pub shard_retries: u64,
}

impl TransportSnapshot {
    /// Counter deltas since `earlier`.
    pub fn since(&self, earlier: TransportSnapshot) -> TransportSnapshot {
        TransportSnapshot {
            wire_bytes: self.wire_bytes.saturating_sub(earlier.wire_bytes),
            shard_retries: self.shard_retries.saturating_sub(earlier.shard_retries),
        }
    }
}

#[derive(Default)]
pub(crate) struct TransportStats {
    wire_bytes: AtomicU64,
    shard_retries: AtomicU64,
}

impl TransportStats {
    pub(crate) fn add_bytes(&self, n: usize) {
        self.wire_bytes.fetch_add(n as u64, Ordering::Relaxed);
    }
    pub(crate) fn add_retries(&self, n: usize) {
        self.shard_retries.fetch_add(n as u64, Ordering::Relaxed);
    }
    pub(crate) fn snapshot(&self) -> TransportSnapshot {
        TransportSnapshot {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            shard_retries: self.shard_retries.load(Ordering::Relaxed),
        }
    }
}

/// Host-side execution context a transport hands [`execute_job`].
pub struct ExecCtx<'a> {
    /// Oracle constructor seam (same as the summarizer's).
    pub factory: &'a ShardOracleFactory,
    /// Live optimizer instance; `None` makes the executor rebuild it
    /// from the registry via the job's `optimizer`/`batch` fields — the
    /// remote-worker path.
    pub optimizer: Option<&'a dyn Optimizer>,
    /// Live fleet-plan handle (with engine buckets); `None` makes the
    /// executor rebuild the bucket-less plan from the job's serialized
    /// core — the remote-worker path.
    pub plan: Option<Arc<ShardPlan>>,
    /// Worker width for transports that fan out on the local pool.
    pub workers: usize,
    /// Span handle of the dispatching stage, captured at construction
    /// (0 = not inside a traced request). Worker threads have no
    /// implicit current span, so per-shard `transport.job` spans parent
    /// here explicitly — see [`crate::obs`].
    pub span: u64,
}

impl<'a> ExecCtx<'a> {
    /// Context for local transports: live optimizer + live plan.
    pub fn local(
        factory: &'a ShardOracleFactory,
        optimizer: &'a dyn Optimizer,
        plan: Option<Arc<ShardPlan>>,
        workers: usize,
    ) -> ExecCtx<'a> {
        ExecCtx {
            factory,
            optimizer: Some(optimizer),
            plan,
            workers,
            span: obs::current_span(),
        }
    }

    /// Context a remote worker would run with: everything except the
    /// oracle factory reconstructed from the job itself. Execution
    /// matches the local path for registry-configured optimizers (see
    /// the remote-rebuild contract on [`ShardJobMsg::optimizer`]); the
    /// plan is rebuilt bucket-less from its serialized core, with
    /// buckets re-picked from the worker's own manifest.
    pub fn remote(factory: &'a ShardOracleFactory, workers: usize) -> ExecCtx<'a> {
        ExecCtx { factory, optimizer: None, plan: None, workers, span: obs::current_span() }
    }
}

/// Lazily builds shard jobs for a transport run.
///
/// Transports call [`JobSource::job`] at **dispatch time** — one job
/// frame (and its sub-matrix payload) only exists while its shard is in
/// flight, and [`JobSource::complete`] marks it released. A re-queued
/// shard (replica failure) simply rebuilds its job from the source, so
/// nothing needs to hold payloads for the whole stage: peak payload
/// residency is bounded by the transport's concurrency, not by the
/// shard count (the ROADMAP "host-side twin" memory item).
///
/// `job(i)` must be deterministic in `i` — a rebuild after a replica
/// failure must produce the identical job.
pub trait JobSource: Sync {
    /// Number of jobs.
    fn len(&self) -> usize;

    /// True when there is nothing to run.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build (or rebuild) the `i`-th job.
    fn job(&self, i: usize) -> ShardJobMsg;

    /// The `i`-th job's payload has been released (executed or failed).
    fn complete(&self, _i: usize) {}
}

/// Pre-materialized jobs (tests, callers that already hold frames).
impl JobSource for Vec<ShardJobMsg> {
    fn len(&self) -> usize {
        self.as_slice().len()
    }

    fn job(&self, i: usize) -> ShardJobMsg {
        self[i].clone()
    }
}

/// Run one decoded shard job to completion: build the oracle for the
/// sub-matrix, run the optimizer at the job's budget, map the selection
/// back to global ground ids. Deterministic in the job for any
/// deterministic optimizer — which replica executes it cannot change
/// the outcome.
pub fn execute_job(job: ShardJobMsg, ctx: &ExecCtx) -> Result<ShardResultMsg, TransportError> {
    let plan = ctx
        .plan
        .clone()
        .or_else(|| job.plan.as_ref().map(|w| Arc::new(w.to_plan())));
    let spec = OracleSpec { threads: job.threads.map(|t| t as usize), plan };
    let built;
    let optimizer: &dyn Optimizer = match ctx.optimizer {
        Some(o) => o,
        None => {
            built = build_optimizer(&job.optimizer, (job.batch as usize).max(1))
                .ok_or_else(|| TransportError::UnknownOptimizer(job.optimizer.clone()))?;
            built.as_ref()
        }
    };
    let ShardJobMsg { shard, k, ground_ids, data, .. } = job;
    let size = data.rows();
    let mut oracle = (ctx.factory)(Arc::new(data), &spec);
    let res = optimizer.run(oracle.as_mut(), (k as usize).min(size));
    Ok(ShardResultMsg {
        shard,
        size: size as u32,
        // decode_job guarantees ground_ids.len() == rows, and any
        // optimizer selection is a set of row indices < rows
        indices: res.indices.iter().map(|&i| ground_ids[i]).collect(),
        f_trajectory: res.f_trajectory,
        f_final: res.f_final,
        wall_seconds: res.wall_seconds,
        oracle_calls: res.oracle_calls as u64,
        oracle_work: res.oracle_work,
    })
}

/// Build → encode → decode → execute → encode → decode: the full
/// double wire round trip every transport runs per shard. The job is
/// built here (at dispatch) and every intermediate copy is dropped as
/// soon as the next leg owns the data, so a shard's payload lives only
/// while that shard executes.
fn run_one(
    jobs: &dyn JobSource,
    i: usize,
    ctx: &ExecCtx,
    stats: &TransportStats,
) -> Result<ShardResultMsg, TransportError> {
    // explicit-parent span: this usually runs on a pool worker with no
    // implicit current span (no-op when the dispatch wasn't traced)
    let _span = obs::span_under("transport.job", ctx.span);
    let out: Result<ShardResultMsg, TransportError> = (|| {
        let job = jobs.job(i);
        let job_frame = {
            let _s = obs::span("wire.encode");
            wire_encode_hist().time(|| encode_job(&job))
        };
        drop(job);
        stats.add_bytes(job_frame.len());
        let decoded = {
            let _s = obs::span("wire.decode");
            wire_decode_hist().time(|| decode_job(&job_frame))
        }?;
        drop(job_frame);
        let result = execute_job(decoded, ctx)?;
        let result_frame = {
            let _s = obs::span("wire.encode");
            wire_encode_hist().time(|| encode_result(&result))
        };
        stats.add_bytes(result_frame.len());
        let returned = {
            let _s = obs::span("wire.decode");
            wire_decode_hist().time(|| decode_result(&result_frame))
        }?;
        Ok(returned)
    })();
    jobs.complete(i);
    out
}

/// How shard jobs reach their executors. Implementations must return
/// one result per job, in job order, route every job through the wire
/// encode/decode round trip, and build jobs lazily through the
/// [`JobSource`] (never materialize the whole job set).
pub trait ShardTransport: Send + Sync {
    fn name(&self) -> &'static str;

    /// Execute all jobs; `results[i]` answers `jobs.job(i)`.
    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError>;

    /// Cumulative counters since construction.
    fn stats(&self) -> TransportSnapshot;

    /// Replicas currently accepting shards (0 for replica-less
    /// transports).
    fn replica_count(&self) -> usize {
        0
    }
}

impl<T: ShardTransport> ShardTransport for Arc<T> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError> {
        (**self).run_jobs(jobs, ctx)
    }
    fn stats(&self) -> TransportSnapshot {
        (**self).stats()
    }
    fn replica_count(&self) -> usize {
        (**self).replica_count()
    }
}

/// Build a transport by registry name: `inproc` | `loopback` | `tcp`
/// (the loopback variant starts with `replicas` unit-capacity replicas;
/// the tcp variant gets default [`NetOptions`](crate::shard::net::NetOptions)
/// with no endpoints — use [`build_transport_with`] to point it at a
/// fleet). `None` for unknown names.
pub fn build_transport(name: &str, replicas: usize) -> Option<Box<dyn ShardTransport>> {
    build_transport_with(name, replicas, &crate::shard::net::NetOptions::default())
}

/// [`build_transport`] with explicit network options: `tcp` connects to
/// `net.addrs` under `net`'s deadlines/backoff, and a nonzero
/// `net.chaos` seed wraps the built transport in deterministic fault
/// injection (`tcp` corrupts its client-side streams, `inproc` swaps in
/// the frame-mangling [`FaultyTransport`](crate::shard::fault::FaultyTransport)).
pub fn build_transport_with(
    name: &str,
    replicas: usize,
    net: &crate::shard::net::NetOptions,
) -> Option<Box<dyn ShardTransport>> {
    match name {
        "inproc" if net.chaos != 0 => Some(Box::new(crate::shard::fault::FaultyTransport::new(
            crate::shard::fault::ChaosConfig::from_seed(net.chaos),
        ))),
        "inproc" => Some(Box::new(InProcessTransport::default())),
        "loopback" => Some(Box::new(LoopbackReplicaTransport::with_replicas(replicas.max(1), 1))),
        "tcp" => Some(Box::new(crate::shard::net::TcpReplicaTransport::new(net.clone()))),
        _ => None,
    }
}

// ----------------------------------------------------------- in-process

/// Today's threadpool path, routed through the wire format: jobs fan
/// out over `ctx.workers` pool workers in this process.
#[derive(Default)]
pub struct InProcessTransport {
    stats: TransportStats,
}

impl ShardTransport for InProcessTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError> {
        // dispatch indices, not jobs: each worker builds its shard's
        // payload right before executing it and drops it right after,
        // so at most `workers` payloads are alive at once
        let idx: Vec<usize> = (0..jobs.len()).collect();
        par_map(&idx, ctx.workers.max(1), |&i| run_one(jobs, i, ctx, &self.stats))
            .into_iter()
            .collect()
    }

    fn stats(&self) -> TransportSnapshot {
        self.stats.snapshot()
    }
}

// ------------------------------------------------------------- loopback

/// One replica's work order for one scheduling round.
struct RoundAssignment {
    id: String,
    /// Jobs this replica completes before its injected failure (if
    /// any) trips; the rest of its assignment fails and is re-queued.
    allowed: u64,
    job_idx: Vec<usize>,
}

/// Replica-registry-backed transport: shards are dealt across
/// registered replicas by capacity and executed loopback (in this
/// process). Failure semantics are real — a replica dying mid-round
/// loses its unfinished shards to a re-queue on the survivors — only
/// the link is simulated.
pub struct LoopbackReplicaTransport {
    registry: Mutex<ReplicaRegistry>,
    stats: TransportStats,
}

impl Default for LoopbackReplicaTransport {
    fn default() -> Self {
        LoopbackReplicaTransport::new()
    }
}

impl LoopbackReplicaTransport {
    /// An empty fleet — register replicas before running jobs.
    pub fn new() -> LoopbackReplicaTransport {
        LoopbackReplicaTransport {
            registry: Mutex::new(ReplicaRegistry::new()),
            stats: TransportStats::default(),
        }
    }

    /// `n` replicas named `replica-0..n-1`, each with `capacity`.
    pub fn with_replicas(n: usize, capacity: usize) -> LoopbackReplicaTransport {
        let t = LoopbackReplicaTransport::new();
        {
            let mut reg = t.registry.lock().unwrap();
            for i in 0..n.max(1) {
                reg.register(&format!("replica-{i}"), capacity);
            }
        }
        t
    }

    /// Run `f` under the registry lock — register/heartbeat/drain/kill
    /// and inspection all go through here.
    pub fn with_registry<T>(&self, f: impl FnOnce(&mut ReplicaRegistry) -> T) -> T {
        f(&mut self.registry.lock().unwrap())
    }

    pub fn register(&self, id: &str, capacity: usize) {
        self.with_registry(|r| r.register(id, capacity));
    }

    pub fn heartbeat(&self, id: &str) -> bool {
        self.with_registry(|r| r.heartbeat(id))
    }

    pub fn drain(&self, id: &str) -> bool {
        self.with_registry(|r| r.drain(id))
    }

    pub fn kill(&self, id: &str) -> bool {
        self.with_registry(|r| r.kill(id))
    }

    /// Failure injection: `id` dies after completing `jobs` more shards.
    pub fn fail_after(&self, id: &str, jobs: u64) -> bool {
        self.with_registry(|r| match r.get_mut(id) {
            Some(rep) => {
                rep.fail_after = Some(jobs);
                true
            }
            None => false,
        })
    }
}

impl ShardTransport for LoopbackReplicaTransport {
    fn name(&self) -> &'static str {
        "loopback"
    }

    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError> {
        let mut results: Vec<Option<ShardResultMsg>> = (0..jobs.len()).map(|_| None).collect();
        let mut pending: Vec<usize> = (0..jobs.len()).collect();
        while !pending.is_empty() {
            // deal the pending shards across assignable replicas
            let round: Vec<RoundAssignment> = self.with_registry(|reg| {
                reg.tick();
                reg.assign(&pending)
                    .into_iter()
                    .map(|(id, job_idx)| {
                        let allowed = reg
                            .get(&id)
                            .and_then(|r| r.fail_after)
                            .unwrap_or(u64::MAX);
                        RoundAssignment { id, allowed, job_idx }
                    })
                    .collect()
            });
            if round.is_empty() {
                return Err(TransportError::NoReplicas { unassigned: pending.len() });
            }
            // all replicas of the round run concurrently, each working
            // its own assignment sequentially; partial progress and a
            // possible job-level error travel back side by side so the
            // registry bookkeeping below never gets skipped
            type RoundOutcome = (Vec<(usize, ShardResultMsg)>, Option<TransportError>);
            let outcomes: Vec<RoundOutcome> = par_map(&round, round.len(), |a| {
                let mut done = Vec::with_capacity(a.job_idx.len());
                for (nth, &ji) in a.job_idx.iter().enumerate() {
                    if (nth as u64) >= a.allowed {
                        break; // the replica died; the rest re-queues
                    }
                    match run_one(jobs, ji, ctx, &self.stats) {
                        Ok(res) => done.push((ji, res)),
                        // a job-level error (bad frame, unknown
                        // optimizer) is deterministic — retrying it on
                        // another replica cannot help
                        Err(e) => return (done, Some(e)),
                    }
                }
                (done, None)
            });
            // (replica id, shards completed, died mid-assignment)
            let mut completed_per_replica: Vec<(String, u64, bool)> = Vec::new();
            let mut next_pending: Vec<usize> = Vec::new();
            let mut round_error: Option<TransportError> = None;
            for (a, (done, err)) in round.iter().zip(outcomes) {
                // a replica that hit a job error is healthy — only an
                // exhausted failure budget counts as death
                let died = err.is_none() && done.len() < a.job_idx.len();
                completed_per_replica.push((a.id.clone(), done.len() as u64, died));
                if died {
                    next_pending.extend_from_slice(&a.job_idx[done.len()..]);
                }
                for (ji, res) in done {
                    results[ji] = Some(res);
                }
                if round_error.is_none() {
                    round_error = err;
                }
            }
            // book-keep: completed counts, injected deaths become real
            self.with_registry(|reg| {
                for (id, completed, died) in &completed_per_replica {
                    if let Some(rep) = reg.get_mut(id) {
                        rep.jobs_done += *completed;
                        if let Some(left) = rep.fail_after.as_mut() {
                            *left = left.saturating_sub(*completed);
                        }
                    }
                    if *died {
                        reg.kill(id);
                    } else {
                        reg.heartbeat(id);
                    }
                }
            });
            if let Some(e) = round_error {
                return Err(e); // bookkeeping applied; the error is final
            }
            next_pending.sort_unstable();
            self.stats.add_retries(next_pending.len());
            pending = next_pending;
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("loop exits only when every job has a result"))
            .collect())
    }

    fn stats(&self) -> TransportSnapshot {
        self.stats.snapshot()
    }

    fn replica_count(&self) -> usize {
        self.with_registry(|r| r.alive())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Precision;
    use crate::linalg::gemm::CpuKernel;
    use crate::linalg::{Matrix, SharedMatrix};
    use crate::optim::Greedy;
    use crate::runtime::artifact::KernelImpl;
    use crate::submodular::{CpuOracle, Oracle};
    use crate::util::rng::Rng;

    fn factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    }

    /// Equality modulo `wall_seconds` (timing differs between runs).
    fn same_outcome(a: &[ShardResultMsg], b: &[ShardResultMsg]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.shard == y.shard
                    && x.size == y.size
                    && x.indices == y.indices
                    && x.f_trajectory.iter().map(|f| f.to_bits()).eq(
                        y.f_trajectory.iter().map(|f| f.to_bits()),
                    )
                    && x.f_final.to_bits() == y.f_final.to_bits()
                    && x.oracle_calls == y.oracle_calls
                    && x.oracle_work == y.oracle_work
            })
    }

    fn jobs(n_jobs: usize, rows: usize, seed: u64) -> Vec<ShardJobMsg> {
        let mut rng = Rng::new(seed);
        (0..n_jobs)
            .map(|s| ShardJobMsg {
                shard: s as u32,
                k: 3,
                batch: 64,
                optimizer: "greedy".into(),
                payload: Precision::F32,
                precision: Precision::F32,
                cpu_kernel: CpuKernel::Scalar,
                kernel: KernelImpl::Jnp,
                threads: None,
                plan: None,
                ground_ids: (0..rows as u64).map(|i| i + 100 * s as u64).collect(),
                data: Matrix::random_normal(rows, 4, &mut rng),
            })
            .collect()
    }

    #[test]
    fn inproc_executes_all_jobs_in_order_and_counts_bytes() {
        let t = InProcessTransport::default();
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let js = jobs(5, 12, 3);
        let out = t.run_jobs(&js, &ctx).unwrap();
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.shard, i as u32);
            assert_eq!(r.size, 12);
            assert_eq!(r.indices.len(), 3);
            // indices mapped into this shard's ground-id space
            for &g in &r.indices {
                assert!((100 * i as u64..100 * i as u64 + 12).contains(&g), "{g}");
            }
        }
        let s = t.stats();
        assert!(s.wire_bytes > 0);
        assert_eq!(s.shard_retries, 0);
        assert_eq!(t.replica_count(), 0);
    }

    #[test]
    fn remote_ctx_rebuilds_optimizer_and_matches_local() {
        let t = InProcessTransport::default();
        let f = factory();
        let greedy = Greedy { batch: 64 };
        let js = jobs(3, 15, 9);
        let local = t.run_jobs(&js, &ExecCtx::local(&f, &greedy, None, 1)).unwrap();
        let remote = t.run_jobs(&js, &ExecCtx::remote(&f, 1)).unwrap();
        assert!(same_outcome(&local, &remote));
        // unknown optimizer ids are a typed error
        let mut bad = jobs(1, 5, 1);
        bad[0].optimizer = "psychic".into();
        match t.run_jobs(&bad, &ExecCtx::remote(&f, 1)) {
            Err(TransportError::UnknownOptimizer(name)) => assert_eq!(name, "psychic"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn loopback_matches_inproc_exactly() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let js = jobs(7, 10, 11);
        let inproc = InProcessTransport::default().run_jobs(&js, &ctx).unwrap();
        for replicas in [1usize, 2, 5] {
            let lb = LoopbackReplicaTransport::with_replicas(replicas, 2);
            assert_eq!(lb.replica_count(), replicas);
            let out = lb.run_jobs(&js, &ctx).unwrap();
            assert!(same_outcome(&out, &inproc), "replicas={replicas}");
            assert_eq!(lb.stats().shard_retries, 0);
        }
    }

    #[test]
    fn replica_death_requeues_to_survivors() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let js = jobs(6, 8, 21);
        let healthy = LoopbackReplicaTransport::with_replicas(2, 1);
        let want = healthy.run_jobs(&js, &ctx).unwrap();

        let chaotic = LoopbackReplicaTransport::with_replicas(2, 1);
        chaotic.fail_after("replica-0", 1); // dies after its first shard
        let got = chaotic.run_jobs(&js, &ctx).unwrap();
        assert!(
            same_outcome(&got, &want),
            "selection must not depend on which replica ran a shard"
        );
        let s = chaotic.stats();
        assert!(s.shard_retries >= 2, "retries {}", s.shard_retries);
        // the dead replica is really dead; the survivor did the rest
        chaotic.with_registry(|reg| {
            assert_eq!(reg.get("replica-0").unwrap().state, ReplicaState::Dead);
            assert_eq!(reg.get("replica-0").unwrap().jobs_done, 1);
            assert_eq!(reg.get("replica-1").unwrap().jobs_done, 5);
        });
        assert_eq!(chaotic.replica_count(), 1);
    }

    #[test]
    fn job_level_error_keeps_replicas_alive_and_books_progress() {
        let f = factory();
        let mut js = jobs(4, 6, 55);
        js[3].optimizer = "psychic".into(); // deterministic poison job
        let t = LoopbackReplicaTransport::with_replicas(2, 1);
        // ExecCtx::remote forces the registry rebuild, so job 3 errors
        match t.run_jobs(&js, &ExecCtx::remote(&f, 2)) {
            Err(TransportError::UnknownOptimizer(name)) => assert_eq!(name, "psychic"),
            other => panic!("{other:?}"),
        }
        t.with_registry(|reg| {
            // a job-level error is not a replica death...
            assert_eq!(reg.alive(), 2);
            // ...and the work replicas completed that round is recorded
            // (deal: replica-0 ← jobs 0,2; replica-1 ← jobs 1, then 3 errors)
            assert_eq!(reg.get("replica-0").unwrap().jobs_done, 2);
            assert_eq!(reg.get("replica-1").unwrap().jobs_done, 1);
        });
        assert_eq!(t.stats().shard_retries, 0, "poison jobs are not retried");
    }

    #[test]
    fn all_replicas_dead_is_a_typed_error() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 1);
        let js = jobs(3, 6, 5);
        let t = LoopbackReplicaTransport::with_replicas(1, 1);
        t.kill("replica-0");
        match t.run_jobs(&js, &ctx) {
            Err(TransportError::NoReplicas { unassigned: 3 }) => {}
            other => panic!("{other:?}"),
        }
        // empty job sets succeed trivially even with no replicas
        assert_eq!(t.run_jobs(&Vec::new(), &ctx).unwrap(), vec![]);
    }

    #[test]
    fn drained_replica_receives_no_new_shards() {
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let js = jobs(6, 8, 33);
        let t = LoopbackReplicaTransport::with_replicas(3, 1);
        t.run_jobs(&js, &ctx).unwrap();
        let before = t.with_registry(|reg| reg.get("replica-1").unwrap().jobs_done);
        assert!(before > 0);
        assert!(t.drain("replica-1"));
        t.run_jobs(&js, &ctx).unwrap();
        t.with_registry(|reg| {
            assert_eq!(reg.get("replica-1").unwrap().jobs_done, before);
            assert_eq!(reg.get("replica-1").unwrap().state, ReplicaState::Draining);
        });
        assert_eq!(t.replica_count(), 2);
    }

    #[test]
    fn payloads_are_built_per_dispatch_and_bounded_by_workers() {
        use std::sync::atomic::AtomicUsize;
        struct Tracked {
            inner: Vec<ShardJobMsg>,
            alive: AtomicUsize,
            peak: AtomicUsize,
            builds: AtomicUsize,
        }
        impl JobSource for Tracked {
            fn len(&self) -> usize {
                self.inner.as_slice().len()
            }
            fn job(&self, i: usize) -> ShardJobMsg {
                self.builds.fetch_add(1, Ordering::SeqCst);
                let alive = self.alive.fetch_add(1, Ordering::SeqCst) + 1;
                self.peak.fetch_max(alive, Ordering::SeqCst);
                self.inner[i].clone()
            }
            fn complete(&self, _i: usize) {
                self.alive.fetch_sub(1, Ordering::SeqCst);
            }
        }
        let src = Tracked {
            inner: jobs(6, 8, 77),
            alive: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            builds: AtomicUsize::new(0),
        };
        let f = factory();
        let greedy = Greedy::default();
        let ctx = ExecCtx::local(&f, &greedy, None, 2);
        let t = InProcessTransport::default();
        let out = t.run_jobs(&src, &ctx).unwrap();
        assert_eq!(out.len(), 6);
        // every job was built exactly once, at dispatch time...
        assert_eq!(src.builds.load(Ordering::SeqCst), 6);
        // ...and never more payloads alive than concurrent workers
        let peak = src.peak.load(Ordering::SeqCst);
        assert!(peak <= 2, "peak {peak} payloads held with 2 workers");
        assert_eq!(src.alive.load(Ordering::SeqCst), 0, "payload leaked");
    }

    #[test]
    fn build_transport_registry() {
        assert_eq!(build_transport("inproc", 0).unwrap().name(), "inproc");
        let lb = build_transport("loopback", 3).unwrap();
        assert_eq!(lb.name(), "loopback");
        assert_eq!(lb.replica_count(), 3);
        // tcp builds with no endpoints (fails at run time, not build time)
        let tcp = build_transport("tcp", 0).unwrap();
        assert_eq!(tcp.name(), "tcp");
        assert_eq!(tcp.replica_count(), 0);
        assert!(build_transport("carrier-pigeon", 1).is_none());
        for name in TRANSPORTS {
            assert!(build_transport(name, 1).is_some(), "{name}");
        }
        // a nonzero chaos seed swaps inproc for the frame mangler
        let net = crate::shard::net::NetOptions { chaos: 0xC4A05, ..Default::default() };
        assert_eq!(build_transport_with("inproc", 0, &net).unwrap().name(), "inproc+chaos");
    }
}
