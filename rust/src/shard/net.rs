//! The socket leg: a TCP replica server and the coordinator-side
//! transport that drives a fleet of them.
//!
//! # Protocol
//!
//! Every message is one length-framed [`crate::shard::wire`] frame.
//! On accept, the replica introduces itself with a `hello` frame
//! (id + capacity) followed by `heartbeat` seq 0. The coordinator then
//! writes `job` frames one at a time; for each job the replica answers
//! a fresh `heartbeat` (seq = jobs completed on this connection) and
//! the `result` frame. A deterministic job failure (unknown optimizer,
//! bad frame contents) is answered with `goodbye(drain = false,
//! detail)` and the connection closes — the coordinator turns that into
//! a final [`TransportError::Replica`], because retrying a
//! deterministic failure elsewhere cannot help. The coordinator closes
//! a finished connection with `goodbye(drain = true)`.
//!
//! # Failure semantics
//!
//! | failure | classification | coordinator behaviour |
//! |---|---|---|
//! | connect refused / timed out | transient | jittered backoff, reconnect, up to `retries` attempts |
//! | read/write deadline hit | transient (`ebc_net_timeouts`) | drop connection, backoff, retry |
//! | corrupt / truncated / oversized frame | transient | drop connection, backoff, retry |
//! | duplicate or stale result frame | transient | drop connection, backoff, retry |
//! | retry budget exhausted | replica death | kill in the registry, re-queue its shards to survivors (`shard_retries`) |
//! | `goodbye(drain = true)` | graceful drain | no new shards; unfinished shards re-queue |
//! | `goodbye(drain = false)` | deterministic job failure | final typed [`TransportError::Replica`] |
//! | every replica dead | fleet loss | typed [`TransportError::NoReplicas`] (the summarizer degrades to in-process and flags it) |
//!
//! Every socket operation is deadline-bounded
//! ([`NetOptions::connect_timeout_ms`] / [`NetOptions::io_timeout_ms`])
//! and every read is length-capped *before* allocating
//! ([`read_frame`]), so a hostile peer can neither hang the
//! coordinator nor make it allocate unbounded memory.
//!
//! # Chaos
//!
//! A nonzero [`NetOptions::chaos`] seed wraps each client-side stream
//! in a [`ChaosStream`] (per-connection forked seed), injecting
//! bit-flips, truncations, delays, duplicate frames and mid-frame
//! disconnects. The replica sees corrupt bytes and drops the
//! connection; the coordinator's retry machinery recovers — the chaos
//! soak test asserts that the final exemplars are identical to the
//! in-process path or that the error is typed, never a panic or hang.

use crate::engine::OracleSpec;
use crate::obs;
use crate::shard::fault::{ChaosConfig, ChaosStream};
use crate::shard::summarizer::ShardOracleFactory;
use crate::shard::transport::{
    execute_job, ExecCtx, JobSource, ReplicaRegistry, ShardTransport, TransportError,
    TransportSnapshot, TransportStats,
};
use crate::shard::wire::{
    decode_goodbye, decode_heartbeat, decode_hello, decode_job, decode_result, encode_goodbye,
    encode_heartbeat, encode_hello, encode_job, encode_result, frame_kind, FrameKind,
    ShardResultMsg, WireError, WireGoodbye, WireHeartbeat, WireHello, HEADER_LEN, TRAILER_LEN,
};
use crate::submodular::Oracle;
use crate::util::rng::Rng;
use crate::util::threadpool::par_map;
use crate::linalg::SharedMatrix;
use std::fmt;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

fn net_connects() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::counter(obs::NET_CONNECTS, "TCP connections established to replicas"))
}

fn net_timeouts() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| {
        obs::counter(obs::NET_TIMEOUTS, "socket operations that hit their deadline")
    })
}

fn net_retries() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| {
        obs::counter(obs::NET_RETRIES, "job attempts retried after transient network failures")
    })
}

fn net_bytes() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::counter(obs::NET_BYTES, "bytes across replica sockets (both legs)"))
}

fn net_heartbeat_lag() -> &'static obs::Gauge {
    static G: OnceLock<obs::Gauge> = OnceLock::new();
    G.get_or_init(|| {
        obs::gauge(obs::NET_HEARTBEAT_LAG, "ticks since the freshest live replica heartbeat")
    })
}

/// Knobs for the socket leg, threaded from `[shard]` config through
/// [`crate::api::ShardSpec`] down to the transport. Additive and
/// local-only: these never cross the wire (a remote replica has its own
/// config).
#[derive(Debug, Clone, PartialEq)]
pub struct NetOptions {
    /// Replica endpoints (`host:port`). Empty means the tcp transport
    /// has no fleet and every run fails with
    /// [`TransportError::NoReplicas`].
    pub addrs: Vec<String>,
    /// TCP connect deadline per attempt (milliseconds).
    pub connect_timeout_ms: u64,
    /// Read/write deadline per socket operation (milliseconds). Must
    /// cover one shard's execution time on the replica.
    pub io_timeout_ms: u64,
    /// Transient-failure retries per replica assignment before the
    /// replica is declared dead and its shards re-queue.
    pub retries: u32,
    /// Base backoff between retries (milliseconds); attempt `a` sleeps
    /// `backoff_ms * 2^a`, jittered uniformly in [0.5, 1.5).
    pub backoff_ms: u64,
    /// Largest frame accepted off the wire (MiB) — checked against the
    /// declared length *before* allocating, so hostile lengths cannot
    /// balloon memory.
    pub max_frame_mb: u32,
    /// Heartbeat age (scheduler rounds) past which a silent replica is
    /// expired via [`ReplicaRegistry::expire`].
    pub heartbeat_max_age: u64,
    /// Fault-injection seed (0 = off). See [`crate::shard::fault`].
    pub chaos: u64,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            addrs: Vec::new(),
            connect_timeout_ms: 1000,
            io_timeout_ms: 5000,
            retries: 2,
            backoff_ms: 50,
            max_frame_mb: 64,
            heartbeat_max_age: 3,
            chaos: 0,
        }
    }
}

impl NetOptions {
    /// The frame cap in bytes.
    pub fn max_frame_len(&self) -> usize {
        (self.max_frame_mb as usize).max(1) * 1024 * 1024
    }
}

/// What can go wrong on the socket leg (one level above
/// [`WireError`]: transport framing and I/O).
#[derive(Debug)]
pub enum NetError {
    /// Socket-level failure (includes deadline hits).
    Io(io::Error),
    /// The bytes arrived but are not a valid frame.
    Wire(WireError),
    /// The frame header declares a length beyond the configured cap —
    /// rejected before any allocation.
    FrameTooLarge { declared: u64, cap: u64 },
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "io: {e}"),
            NetError::Wire(e) => write!(f, "wire: {e}"),
            NetError::FrameTooLarge { declared, cap } => {
                write!(f, "frame declares {declared} bytes, cap is {cap}")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> NetError {
        NetError::Io(e)
    }
}

impl From<WireError> for NetError {
    fn from(e: WireError) -> NetError {
        NetError::Wire(e)
    }
}

/// Read one length-framed wire frame. The header is read first and its
/// declared payload length validated against `max_frame_len` **before**
/// the payload buffer is allocated — a hostile length is a typed
/// [`NetError::FrameTooLarge`], not an allocation.
pub fn read_frame(r: &mut impl Read, max_frame_len: usize) -> Result<Vec<u8>, NetError> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    // payload length lives at header bytes 8..12 (see the wire layout)
    let payload_len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
    let total = HEADER_LEN + payload_len + TRAILER_LEN;
    if total > max_frame_len {
        return Err(NetError::FrameTooLarge { declared: total as u64, cap: max_frame_len as u64 });
    }
    let mut frame = vec![0u8; total];
    frame[..HEADER_LEN].copy_from_slice(&header);
    r.read_exact(&mut frame[HEADER_LEN..])?;
    Ok(frame)
}

/// Write one frame and flush it (frames are written whole, so a chaos
/// duplicate-write duplicates a complete frame).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

/// A boxed bidirectional stream (plain [`TcpStream`] or a
/// chaos-wrapped one).
trait NetStream: Read + Write + Send {}
impl<T: Read + Write + Send> NetStream for T {}

// ------------------------------------------------------------- replica

/// The replica side of the socket leg: a TCP listener that executes job
/// frames through [`ExecCtx::remote`] — exactly the reconstruction path
/// a loopback replica proves — and answers heartbeat + result frames.
/// Stood up by the `serve-replica` CLI subcommand.
pub struct ReplicaServer {
    listener: TcpListener,
    id: String,
    capacity: u32,
    workers: usize,
    max_frame_len: usize,
    io_timeout: Duration,
}

impl ReplicaServer {
    /// Bind `addr` (use port 0 for an ephemeral test port). `id` is the
    /// name sent in hello/heartbeat frames; `capacity` is the replica's
    /// relative share of the shard deal; `workers` is the local oracle
    /// thread width.
    pub fn bind(
        addr: &str,
        id: &str,
        capacity: u32,
        workers: usize,
        opts: &NetOptions,
    ) -> io::Result<ReplicaServer> {
        let listener = TcpListener::bind(addr)?;
        // nonblocking accept so `serve` can poll its stop flag
        listener.set_nonblocking(true)?;
        Ok(ReplicaServer {
            listener,
            id: id.to_string(),
            capacity: capacity.max(1),
            workers: workers.max(1),
            max_frame_len: opts.max_frame_len(),
            io_timeout: Duration::from_millis(opts.io_timeout_ms.max(1)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept and serve connections until `stop` is set; returns the
    /// number of jobs executed. Each connection runs on its own scoped
    /// thread; corrupt frames or deadline hits drop that connection
    /// (the coordinator's retry machinery owns recovery).
    pub fn serve(&self, factory: &ShardOracleFactory, stop: &AtomicBool) -> io::Result<u64> {
        let served = AtomicU64::new(0);
        let accept_result: io::Result<()> = std::thread::scope(|s| {
            while !stop.load(Ordering::Relaxed) {
                match self.listener.accept() {
                    Ok((stream, peer)) => {
                        let served = &served;
                        s.spawn(move || {
                            if let Err(e) = self.handle(stream, factory, served, stop) {
                                log::warn!(
                                    "replica {}: connection from {peer} dropped: {e}",
                                    self.id
                                );
                            }
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(())
        });
        accept_result?;
        Ok(served.load(Ordering::Relaxed))
    }

    fn handle(
        &self,
        stream: TcpStream,
        factory: &ShardOracleFactory,
        served: &AtomicU64,
        stop: &AtomicBool,
    ) -> Result<(), NetError> {
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(self.io_timeout))?;
        stream.set_write_timeout(Some(self.io_timeout))?;
        let mut stream = stream;
        let mut seq: u64 = 0;
        write_frame(
            &mut stream,
            &encode_hello(&WireHello { id: self.id.clone(), capacity: self.capacity }),
        )?;
        write_frame(&mut stream, &encode_heartbeat(&WireHeartbeat { id: self.id.clone(), seq }))?;
        while !stop.load(Ordering::Relaxed) {
            let frame = match read_frame(&mut stream, self.max_frame_len) {
                Ok(f) => f,
                // the coordinator closing the connection is a clean end
                Err(NetError::Io(e)) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
                Err(e) => return Err(e),
            };
            match frame_kind(&frame)? {
                FrameKind::Job => {
                    let job = decode_job(&frame)?;
                    drop(frame);
                    match execute_job(job, &ExecCtx::remote(factory, self.workers)) {
                        Ok(result) => {
                            seq += 1;
                            served.fetch_add(1, Ordering::Relaxed);
                            write_frame(
                                &mut stream,
                                &encode_heartbeat(&WireHeartbeat { id: self.id.clone(), seq }),
                            )?;
                            write_frame(&mut stream, &encode_result(&result))?;
                        }
                        Err(e) => {
                            // deterministic job failure: tell the
                            // coordinator why, then close — retrying on
                            // another replica cannot help
                            let bye = encode_goodbye(&WireGoodbye {
                                id: self.id.clone(),
                                drain: false,
                                detail: e.to_string(),
                            });
                            let _ = write_frame(&mut stream, &bye);
                            return Ok(());
                        }
                    }
                }
                FrameKind::Goodbye => return Ok(()),
                other => {
                    return Err(NetError::Wire(WireError::Malformed {
                        field: "kind",
                        detail: format!("unexpected {other:?} frame on a replica connection"),
                    }))
                }
            }
        }
        Ok(())
    }
}

/// A running [`ReplicaServer`] on a background thread (tests, examples,
/// benches). Stopping — explicitly or on drop — signals the serve loop
/// and joins it.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<io::Result<u64>>>,
}

impl ServerHandle {
    /// The server's `host:port` (ephemeral ports resolved).
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Signal stop, join, and return the number of jobs the server
    /// executed (0 if the serve loop itself failed).
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        match self.join.take().map(|j| j.join()) {
            Some(Ok(Ok(n))) => n,
            _ => 0,
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind and serve a replica on a background thread. `factory` must be
/// owned (`Send + 'static`) because it moves to the server thread.
pub fn spawn_replica<F>(
    addr: &str,
    id: &str,
    capacity: u32,
    workers: usize,
    opts: &NetOptions,
    factory: F,
) -> io::Result<ServerHandle>
where
    F: Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Send + Sync + 'static,
{
    let server = ReplicaServer::bind(addr, id, capacity, workers, opts)?;
    let sock = server.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = stop.clone();
    let join = std::thread::Builder::new()
        .name(format!("replica-{id}"))
        .spawn(move || server.serve(&factory, &thread_stop))?;
    Ok(ServerHandle { addr: sock, stop, join: Some(join) })
}

// --------------------------------------------------------- coordinator

/// How one job attempt on one connection ended.
enum JobFailure {
    /// Network trouble — worth a backoff and a reconnect.
    Transient(String),
    /// The replica announced a graceful drain; its remaining shards
    /// re-queue elsewhere.
    Drained,
    /// Deterministic failure — final for the whole run.
    Fatal(TransportError),
}

/// One live coordinator→replica connection (hello already consumed).
struct Connection {
    stream: Box<dyn NetStream>,
}

/// The coordinator side of the socket leg: [`ShardTransport`] over real
/// TCP connections to [`ReplicaServer`] fleets, reusing the
/// [`ReplicaRegistry`] deal/retry machinery the loopback transport
/// proved. See the module docs for the protocol and the failure
/// semantics table.
pub struct TcpReplicaTransport {
    opts: NetOptions,
    registry: Mutex<ReplicaRegistry>,
    stats: TransportStats,
    /// Backoff jitter stream (seeded so chaos runs reproduce).
    rng: Mutex<Rng>,
    /// Connections opened — also forks the per-connection chaos seed.
    connects: AtomicU64,
}

impl TcpReplicaTransport {
    /// One registry entry per endpoint in `opts.addrs` (the endpoint
    /// string is the registry id; the replica's hello refines its
    /// capacity on first contact).
    pub fn new(opts: NetOptions) -> TcpReplicaTransport {
        let mut registry = ReplicaRegistry::new();
        for addr in &opts.addrs {
            registry.register(addr, 1);
        }
        let seed = 0xEBC0_0000 ^ opts.chaos;
        TcpReplicaTransport {
            opts,
            registry: Mutex::new(registry),
            stats: TransportStats::default(),
            rng: Mutex::new(Rng::new(seed)),
            connects: AtomicU64::new(0),
        }
    }

    /// Run `f` under the registry lock (inspection, manual
    /// register/drain/kill).
    pub fn with_registry<T>(&self, f: impl FnOnce(&mut ReplicaRegistry) -> T) -> T {
        f(&mut self.registry.lock().unwrap())
    }

    fn max_frame_len(&self) -> usize {
        self.opts.max_frame_len()
    }

    fn count_bytes(&self, n: usize) {
        self.stats.add_bytes(n);
        net_bytes().add(n as u64);
    }

    /// Sleep `backoff_ms * 2^attempt`, jittered uniformly in [0.5, 1.5).
    fn backoff(&self, attempt: u32) {
        let base = self.opts.backoff_ms.max(1);
        let exp = base.saturating_mul(1u64 << attempt.min(10));
        let jitter = 0.5 + self.rng.lock().unwrap().f64();
        std::thread::sleep(Duration::from_millis(((exp as f64) * jitter) as u64));
    }

    fn transient_io(&self, op: &str, addr: &str, e: io::Error) -> JobFailure {
        if matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock) {
            net_timeouts().inc();
        }
        JobFailure::Transient(format!("{op} {addr}: {e}"))
    }

    fn transient_net(&self, op: &str, addr: &str, e: NetError) -> JobFailure {
        match e {
            NetError::Io(e) => self.transient_io(op, addr, e),
            other => JobFailure::Transient(format!("{op} {addr}: {other}")),
        }
    }

    /// Open a deadline-bounded connection and consume the replica's
    /// hello (its heartbeat(0) stays buffered for the job read loop).
    fn connect(&self, addr: &str, ctx: &ExecCtx) -> Result<Connection, NetError> {
        let _span = obs::span_under("net.connect", ctx.span);
        let timeout = Duration::from_millis(self.opts.connect_timeout_ms.max(1));
        let mut last: Option<io::Error> = None;
        let mut stream: Option<TcpStream> = None;
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let s = stream.ok_or_else(|| {
            NetError::Io(last.unwrap_or_else(|| {
                io::Error::new(
                    io::ErrorKind::AddrNotAvailable,
                    format!("{addr}: resolves to no socket address"),
                )
            }))
        })?;
        s.set_nodelay(true).ok();
        let io_timeout = Duration::from_millis(self.opts.io_timeout_ms.max(1));
        s.set_read_timeout(Some(io_timeout))?;
        s.set_write_timeout(Some(io_timeout))?;
        let nth = self.connects.fetch_add(1, Ordering::Relaxed);
        net_connects().inc();
        let mut leg: Box<dyn NetStream> = if self.opts.chaos != 0 {
            // fork the chaos seed per connection so retries see fresh
            // (but still reproducible) fault schedules
            let seed = self.opts.chaos ^ nth.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            Box::new(ChaosStream::new(s, ChaosConfig::from_seed(seed)))
        } else {
            Box::new(s)
        };
        let frame = read_frame(&mut leg, self.max_frame_len())?;
        self.count_bytes(frame.len());
        let hello = decode_hello(&frame)?;
        self.with_registry(|r| {
            if let Some(rep) = r.get_mut(addr) {
                rep.capacity = (hello.capacity as usize).max(1);
            }
            r.heartbeat(addr);
        });
        Ok(Connection { stream: leg })
    }

    /// Send one job and read frames until its result (heartbeats and
    /// goodbyes interleave).
    fn run_job_on(
        &self,
        c: &mut Connection,
        addr: &str,
        jobs: &dyn JobSource,
        ji: usize,
        ctx: &ExecCtx,
    ) -> Result<ShardResultMsg, JobFailure> {
        let _span = obs::span_under("net.job", ctx.span);
        let job = jobs.job(ji);
        let shard = job.shard;
        let frame = {
            let _s = obs::span("wire.encode");
            encode_job(&job)
        };
        drop(job);
        jobs.complete(ji);
        self.count_bytes(frame.len());
        write_frame(&mut c.stream, &frame).map_err(|e| self.transient_io("write", addr, e))?;
        drop(frame);
        loop {
            let reply = read_frame(&mut c.stream, self.max_frame_len())
                .map_err(|e| self.transient_net("read", addr, e))?;
            self.count_bytes(reply.len());
            let decoded = {
                let _s = obs::span("wire.decode");
                frame_kind(&reply).and_then(|kind| match kind {
                    FrameKind::Heartbeat => decode_heartbeat(&reply).map(Frame::Heartbeat),
                    FrameKind::Result => decode_result(&reply).map(Frame::Result),
                    FrameKind::Goodbye => decode_goodbye(&reply).map(Frame::Goodbye),
                    other => Err(WireError::Malformed {
                        field: "kind",
                        detail: format!("unexpected {other:?} frame on a coordinator connection"),
                    }),
                })
            };
            match decoded.map_err(|e| JobFailure::Transient(format!("read {addr}: {e}")))? {
                Frame::Heartbeat(_hb) => {
                    self.with_registry(|r| r.heartbeat(addr));
                }
                Frame::Result(res) => {
                    if res.shard != shard {
                        // a duplicated or stale frame desynced the
                        // stream — reconnect and retransmit
                        return Err(JobFailure::Transient(format!(
                            "{addr}: result for shard {} while waiting on shard {shard} \
                             (duplicate or stale frame)",
                            res.shard
                        )));
                    }
                    return Ok(res);
                }
                Frame::Goodbye(g) => {
                    if g.drain {
                        return Err(JobFailure::Drained);
                    }
                    return Err(JobFailure::Fatal(TransportError::Replica {
                        id: g.id,
                        detail: g.detail,
                    }));
                }
            }
        }
    }

    /// Work one replica's assignment for the round, reconnecting with
    /// backoff across transient failures. Returns (completed, re-queued
    /// shard indices, fatal error).
    fn run_assignment(
        &self,
        addr: &str,
        job_idx: &[usize],
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> (Vec<(usize, ShardResultMsg)>, Vec<usize>, Option<TransportError>) {
        let mut done: Vec<(usize, ShardResultMsg)> = Vec::with_capacity(job_idx.len());
        let mut conn: Option<Connection> = None;
        let mut attempt: u32 = 0;
        let mut i = 0;
        while i < job_idx.len() {
            let step = (|| -> Result<ShardResultMsg, JobFailure> {
                if conn.is_none() {
                    let c = self.connect(addr, ctx).map_err(|e| {
                        if let NetError::Io(ioe) = &e {
                            if matches!(
                                ioe.kind(),
                                io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock
                            ) {
                                net_timeouts().inc();
                            }
                        }
                        JobFailure::Transient(format!("connect {addr}: {e}"))
                    })?;
                    conn = Some(c);
                }
                self.run_job_on(conn.as_mut().unwrap(), addr, jobs, job_idx[i], ctx)
            })();
            match step {
                Ok(res) => {
                    done.push((job_idx[i], res));
                    i += 1;
                    attempt = 0;
                }
                Err(JobFailure::Transient(why)) => {
                    conn = None; // the stream is suspect — drop it
                    net_retries().inc();
                    attempt += 1;
                    if attempt > self.opts.retries {
                        log::warn!(
                            "tcp transport: replica {addr} exhausted {attempt} attempt(s) \
                             ({why}); killing it and re-queuing {} shard(s)",
                            job_idx.len() - i
                        );
                        self.with_registry(|r| r.kill(addr));
                        return (done, job_idx[i..].to_vec(), None);
                    }
                    log::debug!("tcp transport: transient failure on {addr} ({why}); retrying");
                    self.backoff(attempt);
                }
                Err(JobFailure::Drained) => {
                    conn = None;
                    log::info!("tcp transport: replica {addr} draining; re-queuing its shards");
                    self.with_registry(|r| r.drain(addr));
                    return (done, job_idx[i..].to_vec(), None);
                }
                Err(JobFailure::Fatal(e)) => {
                    return (done, Vec::new(), Some(e));
                }
            }
        }
        // graceful close: tell the replica we are done with it
        if let Some(mut c) = conn.take() {
            let bye = encode_goodbye(&WireGoodbye {
                id: "coordinator".into(),
                drain: true,
                detail: String::new(),
            });
            self.count_bytes(bye.len());
            let _ = write_frame(&mut c.stream, &bye);
        }
        (done, Vec::new(), None)
    }
}

/// A decoded coordinator-side reply frame.
enum Frame {
    Heartbeat(WireHeartbeat),
    Result(ShardResultMsg),
    Goodbye(WireGoodbye),
}

impl ShardTransport for TcpReplicaTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn run_jobs(
        &self,
        jobs: &dyn JobSource,
        ctx: &ExecCtx,
    ) -> Result<Vec<ShardResultMsg>, TransportError> {
        let mut results: Vec<Option<ShardResultMsg>> = (0..jobs.len()).map(|_| None).collect();
        let mut pending: Vec<usize> = (0..jobs.len()).collect();
        while !pending.is_empty() {
            let round = self.with_registry(|reg| {
                reg.tick();
                for id in reg.expire(self.opts.heartbeat_max_age) {
                    log::warn!("tcp transport: replica {id} missed heartbeats and expired");
                }
                reg.assign(&pending)
            });
            if round.is_empty() {
                return Err(TransportError::NoReplicas { unassigned: pending.len() });
            }
            // all replicas of the round run concurrently, each working
            // its own assignment sequentially over one connection
            let outcomes = par_map(&round, round.len(), |(addr, job_idx)| {
                self.run_assignment(addr, job_idx, jobs, ctx)
            });
            let mut next_pending: Vec<usize> = Vec::new();
            let mut round_error: Option<TransportError> = None;
            for ((addr, _), (done, requeued, err)) in round.iter().zip(outcomes) {
                self.with_registry(|reg| {
                    if let Some(rep) = reg.get_mut(addr) {
                        rep.jobs_done += done.len() as u64;
                    }
                });
                for (ji, res) in done {
                    results[ji] = Some(res);
                }
                next_pending.extend(requeued);
                if round_error.is_none() {
                    round_error = err;
                }
            }
            // heartbeat lag over the replicas still in the deal
            let lag = self.with_registry(|reg| {
                let clock = reg.clock();
                reg.iter()
                    .filter(|r| r.assignable())
                    .map(|r| clock.saturating_sub(r.last_heartbeat))
                    .min()
            });
            if let Some(lag) = lag {
                net_heartbeat_lag().set(lag as i64);
            }
            if let Some(e) = round_error {
                return Err(e); // deterministic failure: final
            }
            next_pending.sort_unstable();
            self.stats.add_retries(next_pending.len());
            pending = next_pending;
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("loop exits only when every job has a result"))
            .collect())
    }

    fn stats(&self) -> TransportSnapshot {
        self.stats.snapshot()
    }

    fn replica_count(&self) -> usize {
        self.with_registry(|r| r.alive())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::wire::WIRE_MAGIC;
    use std::io::Cursor;

    fn result_msg() -> ShardResultMsg {
        ShardResultMsg {
            shard: 3,
            size: 10,
            indices: vec![1, 2],
            f_trajectory: vec![0.1, 0.2],
            f_final: 0.2,
            wall_seconds: 0.0,
            oracle_calls: 2,
            oracle_work: 20,
        }
    }

    #[test]
    fn frame_roundtrips_through_a_stream() {
        let frame = encode_result(&result_msg());
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        write_frame(&mut buf, &frame).unwrap();
        let mut r = Cursor::new(buf);
        for _ in 0..2 {
            let got = read_frame(&mut r, 1 << 20).unwrap();
            assert_eq!(got, frame);
            assert_eq!(decode_result(&got).unwrap(), result_msg());
        }
        // stream exhausted: the next header read is UnexpectedEof
        match read_frame(&mut r, 1 << 20) {
            Err(NetError::Io(e)) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hostile_length_is_rejected_before_allocation() {
        // a header declaring a u32::MAX payload over a tiny cap
        let mut header = Vec::new();
        header.extend_from_slice(&WIRE_MAGIC);
        header.extend_from_slice(&2u16.to_le_bytes());
        header.push(1); // kind: job
        header.push(0); // reserved
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = Cursor::new(header);
        match read_frame(&mut r, 1 << 20) {
            Err(NetError::FrameTooLarge { declared, cap }) => {
                assert!(declared > cap);
                assert_eq!(cap, 1 << 20);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn truncated_stream_is_a_typed_io_error() {
        let frame = encode_result(&result_msg());
        for cut in [1, HEADER_LEN - 1, HEADER_LEN + 3, frame.len() - 1] {
            let mut r = Cursor::new(frame[..cut].to_vec());
            match read_frame(&mut r, 1 << 20) {
                Err(NetError::Io(e)) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof),
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
    }

    #[test]
    fn net_options_defaults_are_sane() {
        let o = NetOptions::default();
        assert!(o.addrs.is_empty());
        assert_eq!(o.max_frame_len(), 64 * 1024 * 1024);
        assert_eq!(o.chaos, 0);
        assert!(o.retries > 0 && o.io_timeout_ms > 0 && o.connect_timeout_ms > 0);
    }

    #[test]
    fn tcp_transport_without_endpoints_is_a_typed_error() {
        let t = TcpReplicaTransport::new(NetOptions::default());
        assert_eq!(t.name(), "tcp");
        assert_eq!(t.replica_count(), 0);
        let f = |m: SharedMatrix, _spec: &OracleSpec| {
            Box::new(crate::submodular::CpuOracle::new_shared(m)) as Box<dyn Oracle>
        };
        let ctx = ExecCtx::remote(&f, 1);
        // empty job sets succeed trivially
        assert!(t.run_jobs(&Vec::new(), &ctx).unwrap().is_empty());
        // anything else has nowhere to go
        let jobs = vec![crate::shard::wire::ShardJobMsg {
            shard: 0,
            k: 1,
            batch: 8,
            optimizer: "greedy".into(),
            payload: crate::engine::Precision::F32,
            precision: crate::engine::Precision::F32,
            cpu_kernel: crate::linalg::gemm::CpuKernel::Scalar,
            kernel: crate::runtime::artifact::KernelImpl::Jnp,
            threads: None,
            plan: None,
            ground_ids: vec![0, 1, 2],
            data: crate::linalg::Matrix::random_normal(3, 2, &mut Rng::new(1)),
        }];
        match t.run_jobs(&jobs, &ctx) {
            Err(TransportError::NoReplicas { unassigned: 1 }) => {}
            other => panic!("{other:?}"),
        }
    }
}
