//! The sharded two-stage summarizer (partition → per-shard optimize →
//! greedy merge) — see the module docs in [`crate::shard`].

use crate::engine::{KernelImpl, OracleSpec, Precision, ShardPlan};
use crate::linalg::gemm::CpuKernel;
use crate::linalg::SharedMatrix;
use crate::obs;
use crate::optim::{Optimizer, SummaryResult};
use crate::prune::{merge_tree, prune_rows, HierarchyConfig, MergeLeaf, PruneConfig, PruneOptions, PrunedGround};
use crate::shard::merge::greedy_merge;
use crate::shard::partition::Partitioner;
use crate::shard::transport::{ExecCtx, InProcessTransport, JobSource, ShardTransport};
use crate::shard::wire::{ShardJobMsg, ShardResultMsg, WirePlan};
use crate::submodular::Oracle;
use crate::util::threadpool::default_threads;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

fn merge_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::MERGE_SECONDS, "stage-2 greedy-merge latency per sharded run (seconds)")
    })
}

/// Oracle constructor seam shared with the coordinator: `Sync` so the
/// per-shard stage can call it from pool workers concurrently. The
/// ground set travels as a [`SharedMatrix`] (the merge and baseline
/// oracles alias one allocation) and the [`OracleSpec`] carries the
/// per-oracle plan handle + thread width of a planned fleet run.
pub type ShardOracleFactory = dyn Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync;

/// Outcome of one shard's first-stage run.
#[derive(Debug, Clone)]
pub struct ShardRun {
    /// Shard id (position in the partitioner's output).
    pub shard: usize,
    /// Ground rows assigned to this shard.
    pub size: usize,
    /// First-stage result with indices mapped back to the **global**
    /// ground set. `f_final` is relative to the shard's own ground set.
    pub result: SummaryResult,
}

impl ShardRun {
    /// Lift a wire result message into the in-process representation.
    fn from_msg(msg: &ShardResultMsg) -> ShardRun {
        ShardRun {
            shard: msg.shard as usize,
            size: msg.size as usize,
            result: SummaryResult {
                indices: msg.indices.iter().map(|&i| i as usize).collect(),
                f_trajectory: msg.f_trajectory.clone(),
                f_final: msg.f_final,
                wall_seconds: msg.wall_seconds,
                oracle_calls: msg.oracle_calls as usize,
                oracle_work: msg.oracle_work,
            },
        }
    }
}

/// Outcome of a sharded summarization.
#[derive(Debug, Clone)]
pub struct ShardedResult {
    /// Second-stage (merge) result over the full ground set: global
    /// indices, f measured against the complete dataset.
    pub merged: SummaryResult,
    /// Per-shard first-stage results (empty shards are skipped).
    pub per_shard: Vec<ShardRun>,
    /// Non-empty shards actually run.
    pub shards_used: usize,
    /// Partitioner that produced the split.
    pub partitioner: &'static str,
    pub partition_seconds: f64,
    /// Wall-clock of the parallel first stage (all shards).
    pub shard_seconds: f64,
    /// Wall-clock of the merge stage.
    pub merge_seconds: f64,
    /// Single-node reference run, when requested via
    /// [`ShardedSummarizer::summarize_with_baseline`].
    pub baseline: Option<SummaryResult>,
    /// Transport the first stage ran over.
    pub transport: &'static str,
    /// Wire bytes this run moved (job + result frames, both legs).
    pub wire_bytes: u64,
    /// Shards re-queued after replica failures during this run.
    pub shard_retries: u64,
    /// Most job payloads (gathered sub-matrices) alive at once during
    /// stage 1 — bounded by the transport's concurrency, not by the
    /// shard count, because jobs are built per dispatch.
    pub peak_jobs_held: usize,
    /// The configured transport failed outright (e.g. every TCP
    /// replica dead) and stage 1 re-ran on the in-process fallback.
    /// The answer is still correct — but the fleet did not produce it.
    pub degraded: bool,
    /// Ground rows sieved away before stage 1 (0 = pruning off).
    pub pruned_n: usize,
    /// Wall-clock of the coordinator-side prune stage.
    pub prune_seconds: f64,
    /// Merge-tree depth (1 = the flat single merge).
    pub merge_depth: usize,
    /// Most ground rows any single merge node scored — equals the full
    /// ground size on the flat path, and is ≤ `max_merge_n` whenever
    /// that cap is set.
    pub max_merge_scored: usize,
}

impl ShardedResult {
    pub fn total_seconds(&self) -> f64 {
        self.partition_seconds + self.shard_seconds + self.merge_seconds
    }

    /// merged f / single-node f — the two-stage quality ratio
    /// (`None` without a baseline; 1.0 when the baseline is degenerate).
    pub fn quality_ratio(&self) -> Option<f64> {
        self.baseline.as_ref().map(|b| {
            if b.f_final <= 0.0 {
                1.0
            } else {
                self.merged.f_final as f64 / b.f_final as f64
            }
        })
    }
}

/// Two-stage sharded summarization à la GreeDi / Mitrovic et al. 2018:
/// stage 1 runs `optimizer` on each shard's sub-dataset (concurrently,
/// on scoped pool workers); stage 2 greedily re-selects `k` exemplars
/// from the union of shard picks, scored against the full ground set.
pub struct ShardedSummarizer<'a> {
    pub partitioner: &'a dyn Partitioner,
    pub optimizer: &'a dyn Optimizer,
    /// Number of shards P (>= 1).
    pub shards: usize,
    /// Worker threads for the per-shard stage; 0 = `default_threads()`.
    /// Ignored when a [`Self::plan`] is set — the plan's worker split
    /// wins.
    pub threads: usize,
    /// Exemplars each shard contributes; 0 = same as the final k.
    pub per_shard_k: usize,
    /// Candidate-batch size for the merge stage (and the greedy
    /// baseline); matches `Greedy::batch` semantics.
    pub merge_batch: usize,
    /// Fleet execution plan: pins the P-worker × T-thread CPU split
    /// (P·T ≤ cores instead of P oversubscribed `default_threads()`
    /// oracles) and, for engine oracles, the shared bucket/executable
    /// set. `None` = legacy unplanned behavior.
    pub plan: Option<Arc<ShardPlan>>,
    /// First-stage transport. `None` = a run-local
    /// [`InProcessTransport`]; either way every shard round-trips
    /// through the [`crate::shard::wire`] encode/decode — there is no
    /// direct-call path.
    pub transport: Option<&'a dyn ShardTransport>,
    /// Pruned-submodularity-graph + merge-tree knobs
    /// ([`PruneOptions::default`] = everything off, legacy flat path).
    /// Pruning happens coordinator-side: jobs ship only the surviving
    /// core rows, so every transport works unchanged and nothing
    /// prune-related ever crosses the frozen wire format.
    pub prune: PruneOptions,
    /// Optimizer for the merge stage(s); `None` (or greedy) keeps the
    /// exact candidate-greedy merge. A non-greedy choice runs over a
    /// candidate-pool oracle weighted by prune charges and forces the
    /// merge-tree path.
    pub merge_optimizer: Option<&'a dyn Optimizer>,
}

impl<'a> ShardedSummarizer<'a> {
    pub fn new(
        partitioner: &'a dyn Partitioner,
        optimizer: &'a dyn Optimizer,
        shards: usize,
    ) -> ShardedSummarizer<'a> {
        ShardedSummarizer {
            partitioner,
            optimizer,
            shards: shards.max(1),
            threads: 0,
            per_shard_k: 0,
            merge_batch: 1024,
            plan: None,
            transport: None,
            prune: PruneOptions::default(),
            merge_optimizer: None,
        }
    }

    /// Configure a summarizer from a validated
    /// [`crate::api::SummarizeRequest`] — the api façade's entry path.
    /// Shard count, stage-1 workers, per-shard k and the
    /// merge/candidate batch come from the request; the
    /// partitioner/optimizer (and any plan/transport handles) stay
    /// caller-owned borrows.
    ///
    /// # Panics
    /// If the request carries no [`crate::api::ShardSpec`] — single-node
    /// requests never reach the sharded pipeline
    /// (see [`crate::api::execute`]).
    pub fn from_request(
        req: &crate::api::SummarizeRequest,
        partitioner: &'a dyn Partitioner,
        optimizer: &'a dyn Optimizer,
    ) -> ShardedSummarizer<'a> {
        let spec = req.shard.as_ref().expect("from_request needs a sharded request");
        let mut s = ShardedSummarizer::new(partitioner, optimizer, spec.partitions);
        s.threads = spec.threads;
        s.per_shard_k = spec.per_shard_k;
        s.merge_batch = req.batch.max(1);
        s.prune = PruneOptions {
            rate: spec.prune,
            fanout: spec.fanout,
            max_merge_n: spec.max_merge_n,
            seed: req.seed,
            kernel: req.cpu_kernel,
            precision: req.precision,
        };
        s
    }

    /// Run the two-stage pipeline. `factory` builds the evaluation
    /// oracle for each shard's sub-matrix and for the merge stage — the
    /// same seam the coordinator uses, so shards run on the CPU baseline
    /// or the XLA engine unchanged.
    pub fn summarize(
        &self,
        data: &SharedMatrix,
        factory: &ShardOracleFactory,
        k: usize,
    ) -> ShardedResult {
        self.run(data, factory, k, false)
    }

    /// Same, plus a single-node reference run of the same optimizer on
    /// the full dataset for quality-ratio accounting.
    pub fn summarize_with_baseline(
        &self,
        data: &SharedMatrix,
        factory: &ShardOracleFactory,
        k: usize,
    ) -> ShardedResult {
        self.run(data, factory, k, true)
    }

    fn run(
        &self,
        data: &SharedMatrix,
        factory: &ShardOracleFactory,
        k: usize,
        with_baseline: bool,
    ) -> ShardedResult {
        let p = self.shards.max(1);

        let t0 = Instant::now();
        let parts = {
            let _span = obs::span("shard.partition");
            self.partitioner.partition(data, p)
        };
        debug_assert!(
            crate::shard::partition::validate_partition(&parts, data.rows(), p).is_ok()
        );
        // skip empty shards but remember original shard ids
        let jobs: Vec<(usize, Vec<usize>)> = parts
            .into_iter()
            .enumerate()
            .filter(|(_, part)| !part.is_empty())
            .collect();
        let partition_seconds = t0.elapsed().as_secs_f64();

        // ---- stage 0: coordinator-side sieve prune per shard ----------
        // Each shard's ground is sieved down to an O((1-rate)·m) core
        // before any job is built: jobs then ship only the surviving
        // rows, so pruning works over every transport with zero wire
        // changes. Cores (with their charge weights) are kept for the
        // merge tree; the legacy flat path never allocates them.
        let use_tree = self.merge_optimizer.map_or(false, |o| o.name() != "greedy")
            || self.prune.hierarchical(jobs.len());
        let tp = Instant::now();
        let cores: Option<Vec<PrunedGround>> = use_tree.then(|| {
            jobs.iter()
                .map(|(sid, part)| {
                    if !self.prune.enabled() {
                        return PrunedGround::identity(part);
                    }
                    let cfg = PruneConfig::new(
                        self.prune.rate,
                        self.prune.seed
                            ^ (*sid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    prune_rows(data, part, self.prune.kernel, default_threads(), &cfg).0
                })
                .collect()
        });
        let pruned_n: usize =
            cores.as_ref().map_or(0, |cs| cs.iter().map(|c| c.dropped()).sum());
        let prune_seconds =
            if self.prune.enabled() { tp.elapsed().as_secs_f64() } else { 0.0 };
        // pruned stage-1 jobs carry the core's global ids in place of
        // the full shard ground
        let jobs: Vec<(usize, Vec<usize>)> = match &cores {
            Some(cs) if self.prune.enabled() => jobs
                .into_iter()
                .zip(cs)
                .map(|((sid, _), core)| (sid, core.ids.clone()))
                .collect(),
            _ => jobs,
        };
        // stage-1 results come back keyed by shard id; this maps them
        // to their cores after `jobs` moves into the job source
        let sids: Vec<usize> = jobs.iter().map(|(sid, _)| *sid).collect();

        // ---- stage 1: per-shard optimization through the transport ---
        // a plan pins the worker × kernel-thread split; unplanned runs
        // keep the legacy `threads` semantics (each oracle at factory
        // defaults). Every shard travels as a wire-format job frame and
        // comes back as a result frame — the in-process transport runs
        // the same encode/decode round trip a remote replica would.
        let t1 = Instant::now();
        let shard_k = if self.per_shard_k == 0 { k } else { self.per_shard_k };
        let (threads, shard_spec) = match &self.plan {
            Some(plan) => (plan.shard_workers, OracleSpec::for_shard(plan)),
            None => {
                let t = if self.threads == 0 { default_threads() } else { self.threads };
                (t, OracleSpec::unplanned())
            }
        };
        // jobs are NOT materialized up front: the source gathers each
        // shard's sub-matrix at dispatch time and a re-queued shard
        // rebuilds its payload, so peak payload residency is bounded by
        // the transport's concurrency instead of holding a full extra
        // ground-matrix copy for the whole stage (`peak_jobs_held`
        // reports the observed bound).
        let (precision, cpu_kernel, kernel) = match &self.plan {
            Some(p) => (p.precision, p.cpu_kernel, p.kernel),
            None => (Precision::F32, CpuKernel::Blocked, KernelImpl::Jnp),
        };
        let source = StageJobs {
            parts: jobs,
            data,
            shard_k,
            batch: self.merge_batch,
            optimizer: self.optimizer.name().to_string(),
            threads: shard_spec.threads,
            plan: self.plan.clone(),
            precision,
            cpu_kernel,
            kernel,
            alive: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        };
        // opened before the ExecCtx so worker threads parent their
        // transport.job spans under this stage (the ctx captures the
        // constructing thread's current span)
        let stage1_span = obs::span("shard.stage1");
        let ctx = ExecCtx::local(factory, self.optimizer, shard_spec.plan.clone(), threads);
        let local = InProcessTransport::default();
        // `transport` aliases `local` when no external transport is set
        let external = self.transport.is_some();
        let transport: &dyn ShardTransport = self.transport.unwrap_or(&local);
        let stats_before = transport.stats();
        let mut transport_name = transport.name();
        let mut fell_back = false;
        let results: Vec<ShardResultMsg> = match transport.run_jobs(&source, &ctx) {
            Ok(r) => r,
            Err(e) => {
                // a dead replica fleet must not kill the query: degrade
                // to the in-process transport (still wire-routed)
                log::error!(
                    "shard transport '{}' failed ({e}); re-running on the in-process transport",
                    transport.name()
                );
                fell_back = true;
                transport_name = local.name();
                local
                    .run_jobs(&source, &ctx)
                    .unwrap_or_else(|e| panic!("in-process shard transport failed: {e}"))
            }
        };
        let mut stats = transport.stats().since(stats_before);
        // when `transport` IS `local`, its counters already cover every
        // attempt — only an external transport's fallback adds traffic
        if fell_back && external {
            let extra = local.stats();
            stats.wire_bytes += extra.wire_bytes;
            stats.shard_retries += extra.shard_retries;
        }
        drop(stage1_span);
        let per_shard: Vec<ShardRun> = results.iter().map(ShardRun::from_msg).collect();
        let shard_seconds = t1.elapsed().as_secs_f64();

        // ---- stage 2: merge over the union of shard picks ------------
        // merge + baseline alias the full dataset through the shared
        // handle — no ground-matrix copies. With every prune/tree knob
        // off, the legacy flat greedy merge runs verbatim (bit-identical
        // to prior releases); otherwise the shards-of-shards tree takes
        // over, carrying each core's charge weights into node scoring.
        let t2 = Instant::now();
        let merge_spec = match &self.plan {
            Some(plan) => OracleSpec::for_merge(plan),
            None => OracleSpec::unplanned(),
        };
        let (merged, merge_depth, max_merge_scored) = match &cores {
            Some(cores) => {
                let leaves: Vec<MergeLeaf> = per_shard
                    .iter()
                    .map(|s| {
                        let ci = sids
                            .binary_search(&s.shard)
                            .expect("stage-1 result for an unknown shard");
                        MergeLeaf {
                            ground: cores[ci].clone(),
                            selected: s.result.indices.clone(),
                        }
                    })
                    .collect();
                let hcfg = HierarchyConfig {
                    fanout: self.prune.fanout,
                    max_merge_n: self.prune.max_merge_n,
                    seed: self.prune.seed,
                    kernel: self.prune.kernel,
                    precision: self.prune.precision,
                    threads: merge_spec.threads.unwrap_or_else(default_threads),
                    batch: self.merge_batch,
                };
                let mo = self.merge_optimizer.filter(|o| o.name() != "greedy");
                let out = {
                    let _span = obs::span("shard.merge");
                    merge_hist().time(|| merge_tree(data, leaves, k, &hcfg, mo))
                };
                (out.result, out.depth, out.max_scored_n)
            }
            None => {
                let mut union: Vec<usize> = per_shard
                    .iter()
                    .flat_map(|s| s.result.indices.iter().copied())
                    .collect();
                union.sort_unstable();
                union.dedup();
                let mut merge_oracle = factory(Arc::clone(data), &merge_spec);
                let merged = {
                    let _span = obs::span("shard.merge");
                    merge_hist().time(|| {
                        greedy_merge(merge_oracle.as_mut(), &union, k, self.merge_batch)
                    })
                };
                (merged, 1, data.rows())
            }
        };
        let merge_seconds = t2.elapsed().as_secs_f64();

        let baseline = with_baseline.then(|| {
            let _span = obs::span("shard.baseline");
            let mut oracle = factory(Arc::clone(data), &merge_spec);
            self.optimizer.run(oracle.as_mut(), k)
        });

        ShardedResult {
            merged,
            shards_used: per_shard.len(),
            per_shard,
            partitioner: self.partitioner.name(),
            partition_seconds,
            shard_seconds,
            merge_seconds,
            baseline,
            transport: transport_name,
            wire_bytes: stats.wire_bytes,
            shard_retries: stats.shard_retries,
            peak_jobs_held: source.peak.load(Ordering::SeqCst),
            degraded: fell_back,
            pruned_n,
            prune_seconds,
            merge_depth,
            max_merge_scored,
        }
    }
}

/// Stage-1 job source: builds each shard's wire job — the gathered
/// sub-matrix, its global ground ids, the optimizer id + budget, and
/// the oracle knobs (from the plan when the run is planned, engine
/// defaults otherwise; local factories carry their own backend config,
/// the knobs matter to true remote workers) — **at dispatch time**, so
/// only in-flight shards hold payloads and a re-queued shard rebuilds
/// its job deterministically.
struct StageJobs<'a> {
    /// Non-empty shards as (original shard id, ground rows).
    parts: Vec<(usize, Vec<usize>)>,
    data: &'a SharedMatrix,
    shard_k: usize,
    batch: usize,
    optimizer: String,
    /// Per-oracle kernel-thread override of a planned run.
    threads: Option<usize>,
    plan: Option<Arc<ShardPlan>>,
    precision: Precision,
    cpu_kernel: CpuKernel,
    kernel: KernelImpl,
    alive: AtomicUsize,
    peak: AtomicUsize,
}

impl JobSource for StageJobs<'_> {
    fn len(&self) -> usize {
        self.parts.len()
    }

    fn job(&self, i: usize) -> ShardJobMsg {
        let alive = self.alive.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(alive, Ordering::SeqCst);
        let (shard, part) = &self.parts[i];
        ShardJobMsg {
            shard: *shard as u32,
            k: self.shard_k.min(part.len()) as u32,
            batch: self.batch.max(1) as u32,
            optimizer: self.optimizer.clone(),
            payload: Precision::F32,
            precision: self.precision,
            cpu_kernel: self.cpu_kernel,
            kernel: self.kernel,
            threads: self.threads.map(|t| t as u32),
            plan: self.plan.as_ref().map(|p| WirePlan::of(p)),
            ground_ids: part.iter().map(|&r| r as u64).collect(),
            data: self.data.gather(part),
        }
    }

    fn complete(&self, _i: usize) {
        self.alive.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlanRequest;
    use crate::linalg::Matrix;
    use crate::optim::{build_optimizer, exhaustive_best, Greedy, ALGORITHMS};
    use crate::shard::partition::{build_partitioner, PARTITIONERS};
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    fn cpu_factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    }

    fn data(n: usize, d: usize, seed: u64) -> SharedMatrix {
        let mut rng = Rng::new(seed);
        Arc::new(Matrix::random_normal(n, d, &mut rng))
    }

    #[test]
    fn single_shard_reproduces_greedy_bit_for_bit() {
        let v = data(60, 5, 42);
        let greedy = Greedy { batch: 1024 };
        let single = greedy.run(&mut CpuOracle::new_shared(Arc::clone(&v)), 7);
        for name in PARTITIONERS {
            let part = build_partitioner(name, 9).unwrap();
            let s = ShardedSummarizer::new(part.as_ref(), &greedy, 1);
            let res = s.summarize(&v, &cpu_factory(), 7);
            assert_eq!(res.merged.indices, single.indices, "{name}");
            assert_eq!(
                res.merged.f_final.to_bits(),
                single.f_final.to_bits(),
                "{name}: {} vs {}",
                res.merged.f_final,
                single.f_final
            );
            assert_eq!(res.shards_used, 1);
        }
    }

    #[test]
    fn runs_every_registered_optimizer_per_shard() {
        let v = data(48, 4, 7);
        let part = build_partitioner("round_robin", 0).unwrap();
        for name in ALGORITHMS {
            let opt = build_optimizer(name, 64).unwrap();
            let s = ShardedSummarizer::new(part.as_ref(), opt.as_ref(), 4);
            let res = s.summarize(&v, &cpu_factory(), 4);
            assert_eq!(res.shards_used, 4, "{name}");
            assert!(res.merged.k() <= 4, "{name}");
            assert!(res.merged.f_final >= 0.0, "{name}");
            // merged picks come from the union of shard picks
            let union: Vec<usize> = res
                .per_shard
                .iter()
                .flat_map(|s| s.result.indices.iter().copied())
                .collect();
            assert!(
                res.merged.indices.iter().all(|i| union.contains(i)),
                "{name}: {:?} not in {union:?}",
                res.merged.indices
            );
        }
    }

    #[test]
    fn merged_quality_close_to_single_node_greedy() {
        let v = data(120, 6, 11);
        let greedy = Greedy::default();
        for shards in [2usize, 4, 8] {
            let part = build_partitioner("round_robin", 0).unwrap();
            let s = ShardedSummarizer::new(part.as_ref(), &greedy, shards);
            let res = s.summarize_with_baseline(&v, &cpu_factory(), 6);
            let ratio = res.quality_ratio().unwrap();
            assert!(ratio >= 0.8, "P={shards}: quality ratio {ratio}");
            assert!(ratio <= 1.0 + 1e-6, "P={shards}: ratio {ratio} > 1?");
        }
    }

    #[test]
    fn within_constant_factor_of_exhaustive_on_tiny_instance() {
        let v = data(12, 3, 3);
        let (_, opt) = exhaustive_best(&mut CpuOracle::new_shared(Arc::clone(&v)), 3);
        let greedy = Greedy::default();
        for name in PARTITIONERS {
            for shards in [1usize, 2, 4] {
                let part = build_partitioner(name, 5).unwrap();
                let s = ShardedSummarizer::new(part.as_ref(), &greedy, shards);
                let res = s.summarize(&v, &cpu_factory(), 3);
                assert!(
                    res.merged.f_final >= 0.3 * opt,
                    "{name}/P={shards}: {} < 0.3 * {opt}",
                    res.merged.f_final
                );
            }
        }
    }

    #[test]
    fn every_run_reports_wire_traffic_and_transport() {
        use crate::shard::transport::LoopbackReplicaTransport;
        let v = data(30, 4, 5);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        let s = ShardedSummarizer::new(part.as_ref(), &greedy, 3);
        // default transport: in-process, but still wire-routed
        let res = s.summarize(&v, &cpu_factory(), 3);
        assert_eq!(res.transport, "inproc");
        assert!(res.wire_bytes > 0, "no bytes crossed the wire");
        assert_eq!(res.shard_retries, 0);
        assert!(!res.degraded);
        // explicit loopback transport selects identically
        let lb = LoopbackReplicaTransport::with_replicas(2, 1);
        let mut s2 = ShardedSummarizer::new(part.as_ref(), &greedy, 3);
        s2.transport = Some(&lb);
        let res2 = s2.summarize(&v, &cpu_factory(), 3);
        assert_eq!(res2.transport, "loopback");
        assert_eq!(res2.merged.indices, res.merged.indices);
        assert_eq!(res2.merged.f_final.to_bits(), res.merged.f_final.to_bits());
        assert_eq!(res2.wire_bytes, res.wire_bytes, "same jobs, same frames");
    }

    #[test]
    fn stage1_streams_payloads_peak_bounded_by_workers() {
        // 8 shards over 2 stage-1 workers: at most 2 job payloads may
        // be alive at once (the pre-streaming code held all 8 for the
        // whole stage)
        let v = data(64, 4, 29);
        let part = build_partitioner("round_robin", 0).unwrap();
        let greedy = Greedy::default();
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 8);
        s.threads = 2;
        let res = s.summarize(&v, &cpu_factory(), 4);
        assert_eq!(res.shards_used, 8);
        assert!(res.peak_jobs_held >= 1, "peak never recorded");
        assert!(
            res.peak_jobs_held <= 2,
            "peak {} payloads held with 2 workers",
            res.peak_jobs_held
        );
    }

    #[test]
    fn more_shards_than_rows_skips_empty_shards() {
        let v = data(3, 2, 8);
        let part = build_partitioner("round_robin", 0).unwrap();
        let greedy = Greedy::default();
        let s = ShardedSummarizer::new(part.as_ref(), &greedy, 8);
        let res = s.summarize(&v, &cpu_factory(), 2);
        assert_eq!(res.shards_used, 3);
        assert!(res.merged.k() <= 2);
    }

    #[test]
    fn per_shard_indices_are_global_and_disjoint() {
        let v = data(40, 4, 13);
        let part = build_partitioner("hash", 3).unwrap();
        let greedy = Greedy::default();
        let s = ShardedSummarizer::new(part.as_ref(), &greedy, 4);
        let res = s.summarize(&v, &cpu_factory(), 3);
        let mut all: Vec<usize> = res
            .per_shard
            .iter()
            .flat_map(|s| s.result.indices.iter().copied())
            .collect();
        assert!(all.iter().all(|&i| i < 40));
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), before, "shard picks overlap");
    }

    #[test]
    fn explicit_per_shard_k_widens_the_union() {
        let v = data(60, 4, 17);
        let part = build_partitioner("round_robin", 0).unwrap();
        let greedy = Greedy::default();
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 3);
        s.per_shard_k = 5;
        let res = s.summarize(&v, &cpu_factory(), 2);
        let union: usize = res.per_shard.iter().map(|s| s.result.k()).sum();
        assert!(union > 6, "expected ~15 first-stage picks, got {union}");
        assert!(res.merged.k() <= 2);
    }

    #[test]
    fn planned_run_selects_identical_exemplars_and_threads_specs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let v = data(80, 5, 23);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        for shards in [1usize, 3, 5] {
            let unplanned = ShardedSummarizer::new(part.as_ref(), &greedy, shards)
                .summarize(&v, &cpu_factory(), 6);

            let mut req = PlanRequest::new(v.rows(), v.cols(), shards, 6);
            req.cores = 4;
            let plan = Arc::new(ShardPlan::plan(None, &req));
            let shard_builds = AtomicUsize::new(0);
            let planned_factory = |m: SharedMatrix, spec: &OracleSpec| {
                // the planner's split reaches every oracle build
                let t = spec.threads.expect("planned spec carries threads");
                if t == plan.oracle_threads {
                    shard_builds.fetch_add(1, Ordering::SeqCst);
                } else {
                    assert_eq!(t, plan.merge_threads);
                }
                assert!(spec.plan.is_some());
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            };
            let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, shards);
            s.plan = Some(Arc::clone(&plan));
            let planned = s.summarize(&v, &planned_factory, 6);

            assert_eq!(planned.merged.indices, unplanned.merged.indices, "P={shards}");
            assert_eq!(
                planned.merged.f_final.to_bits(),
                unplanned.merged.f_final.to_bits(),
                "P={shards}"
            );
            if plan.oracle_threads != plan.merge_threads {
                assert_eq!(shard_builds.load(Ordering::SeqCst), shards.min(v.rows()));
            }
        }
    }

    fn blocked_factory() -> impl Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Sync {
        |m: SharedMatrix, _spec: &OracleSpec| {
            Box::new(CpuOracle::with_kernel_shared(m, CpuKernel::Blocked, Precision::F32, 0))
                as Box<dyn Oracle>
        }
    }

    #[test]
    fn forced_tree_with_identity_grounds_matches_flat_bitwise() {
        // max_merge_n = n forces the merge-tree path while leaving the
        // cap a no-op: one root over identity grounds with unit weights
        // must reproduce the flat merge exactly (same kernel, same
        // threads, all-ones weighted eval is bit-identical)
        let v = data(72, 5, 31);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        let flat =
            ShardedSummarizer::new(part.as_ref(), &greedy, 4).summarize(&v, &blocked_factory(), 6);
        assert_eq!(flat.merge_depth, 1);
        assert_eq!(flat.pruned_n, 0);
        assert_eq!(flat.max_merge_scored, 72);
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 4);
        s.prune.max_merge_n = 72;
        let tree = s.summarize(&v, &blocked_factory(), 6);
        assert_eq!(tree.merge_depth, 1);
        assert_eq!(tree.max_merge_scored, 72, "root must score the full union");
        assert_eq!(tree.merged.indices, flat.merged.indices);
        assert_eq!(tree.merged.f_final.to_bits(), flat.merged.f_final.to_bits());
    }

    #[test]
    fn pruning_reports_dropped_rows_and_keeps_quality() {
        let v = data(160, 5, 37);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 4);
        s.prune.rate = 0.5;
        let res = s.summarize_with_baseline(&v, &cpu_factory(), 6);
        assert!(res.pruned_n > 0, "nothing pruned at rate 0.5");
        assert!(res.pruned_n < 160);
        assert!(res.prune_seconds >= 0.0);
        assert!(!res.merged.indices.is_empty());
        assert!(res.merged.indices.iter().all(|&i| i < 160));
        let ratio = res.quality_ratio().unwrap();
        assert!(ratio >= 0.5, "pruned quality collapsed: {ratio}");
    }

    #[test]
    fn merge_cap_and_fanout_respected_end_to_end() {
        let v = data(90, 4, 41);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 6);
        s.prune.rate = 0.25;
        s.prune.fanout = 2;
        s.prune.max_merge_n = 30;
        let res = s.summarize(&v, &cpu_factory(), 4);
        assert!(res.pruned_n > 0);
        assert!(res.merge_depth >= 2, "fanout 2 over 6 shards must build a tree");
        assert!(res.max_merge_scored <= 30, "cap violated: {}", res.max_merge_scored);
        assert!(!res.merged.indices.is_empty());
        assert!(res.merged.k() <= 4);
        assert!(res.merged.indices.iter().all(|&i| i < 90));
    }

    #[test]
    fn non_greedy_merge_optimizer_selects_from_the_union() {
        let v = data(60, 4, 43);
        let greedy = Greedy::default();
        let part = build_partitioner("round_robin", 0).unwrap();
        let opt = build_optimizer("stochastic_greedy", 64).unwrap();
        let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, 3);
        s.merge_optimizer = Some(opt.as_ref());
        let res = s.summarize(&v, &cpu_factory(), 4);
        assert_eq!(res.merge_depth, 1);
        assert_eq!(res.pruned_n, 0);
        let union: Vec<usize> = res
            .per_shard
            .iter()
            .flat_map(|s| s.result.indices.iter().copied())
            .collect();
        assert!(
            res.merged.indices.iter().all(|i| union.contains(i)),
            "{:?} not in {union:?}",
            res.merged.indices
        );
        // a merge optimizer literally named "greedy" keeps the flat path
        let gm = build_optimizer("greedy", 64).unwrap();
        let mut s2 = ShardedSummarizer::new(part.as_ref(), &greedy, 3);
        s2.merge_optimizer = Some(gm.as_ref());
        let res2 = s2.summarize(&v, &cpu_factory(), 4);
        assert_eq!(res2.merge_depth, 1);
        let flat =
            ShardedSummarizer::new(part.as_ref(), &greedy, 3).summarize(&v, &cpu_factory(), 4);
        assert_eq!(res2.merged.indices, flat.merged.indices);
        assert_eq!(res2.merged.f_final.to_bits(), flat.merged.f_final.to_bits());
    }
}
