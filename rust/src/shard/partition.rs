//! Ground-set partitioning strategies for the sharded two-stage
//! summarizer.
//!
//! Contract (checked by the property tests): `partition(data, p)`
//! returns exactly `p` index lists, each **strictly ascending**, whose
//! disjoint union is `0..data.rows()`. Ascending order matters: with
//! `p = 1` every strategy must yield the identity list so the sharded
//! pipeline reproduces the single-node optimizer bit for bit.

use crate::linalg::Matrix;
use crate::reduce::{RandomProjection, Reducer};

/// A strategy assigning every ground row to one of `shards` parts.
pub trait Partitioner: Sync {
    fn name(&self) -> &'static str;
    /// Split `0..data.rows()` into `shards` ascending index lists.
    fn partition(&self, data: &Matrix, shards: usize) -> Vec<Vec<usize>>;
}

/// Names accepted by [`build_partitioner`].
pub const PARTITIONERS: &[&str] = &["round_robin", "hash", "locality"];

/// Construct a partitioner by name (the registry the config schema and
/// the CLI validate against). `seed` drives the hash mix / projection.
pub fn build_partitioner(name: &str, seed: u64) -> Option<Box<dyn Partitioner>> {
    Some(match name {
        "round_robin" => Box::new(RoundRobinPartitioner),
        "hash" => Box::new(HashPartitioner { seed }),
        "locality" => Box::new(LocalityPartitioner { seed }),
        _ => return None,
    })
}

/// Row `i` goes to shard `i % p` — perfectly balanced, order-dependent.
pub struct RoundRobinPartitioner;

impl Partitioner for RoundRobinPartitioner {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn partition(&self, data: &Matrix, shards: usize) -> Vec<Vec<usize>> {
        let p = shards.max(1);
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); p];
        for i in 0..data.rows() {
            parts[i % p].push(i);
        }
        parts
    }
}

/// Content-addressed assignment: FNV-1a over the row's f32 bit
/// patterns. Identical vectors land on the same shard regardless of
/// arrival order — the stable choice when the same stream is re-sharded
/// by independent coordinator replicas.
pub struct HashPartitioner {
    pub seed: u64,
}

/// FNV-1a over the row bits, seed-mixed.
fn row_hash(row: &[f32], seed: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &x in row {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    // final avalanche (splitmix-style) so low bits are usable for modulo
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^ (h >> 33)
}

impl Partitioner for HashPartitioner {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn partition(&self, data: &Matrix, shards: usize) -> Vec<Vec<usize>> {
        let p = shards.max(1);
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); p];
        for i in 0..data.rows() {
            let h = row_hash(data.row(i), self.seed);
            parts[(h % p as u64) as usize].push(i);
        }
        parts
    }
}

/// Locality-aware assignment: rows are ordered along a 1-D sparse
/// random projection ([`RandomProjection`], the JL transform of
/// `reduce`) and cut into `p` contiguous equal-size chunks, so nearby
/// vectors tend to share a shard — per-shard greedy then sees coherent
/// neighborhoods, which is where the two-stage merge loses the least
/// quality. Each chunk is re-sorted ascending (see module contract).
pub struct LocalityPartitioner {
    pub seed: u64,
}

impl LocalityPartitioner {
    /// The 1-D projection value of every row (exposed so tests can
    /// verify shard contiguity along the projection axis).
    pub fn scores(&self, data: &Matrix) -> Vec<f32> {
        let rp = RandomProjection::new(data.cols(), 1, self.seed);
        (0..data.rows())
            .map(|i| rp.transform_row(data.row(i))[0])
            .collect()
    }
}

impl Partitioner for LocalityPartitioner {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn partition(&self, data: &Matrix, shards: usize) -> Vec<Vec<usize>> {
        let p = shards.max(1);
        let n = data.rows();
        if n == 0 {
            return vec![Vec::new(); p];
        }
        let scores = self.scores(data);
        let mut order: Vec<usize> = (0..n).collect();
        // total_cmp: NaN scores (bad sensor frames) must not produce an
        // intransitive comparator, which sort_by panics on
        order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
        let chunk = n.div_ceil(p);
        let mut parts: Vec<Vec<usize>> = Vec::with_capacity(p);
        for s in 0..p {
            let lo = (s * chunk).min(n);
            let hi = ((s + 1) * chunk).min(n);
            let mut part: Vec<usize> = order[lo..hi].to_vec();
            part.sort_unstable();
            parts.push(part);
        }
        parts
    }
}

/// Check the partition contract; returns an error string on violation
/// (used by the shard property tests and debug assertions).
pub fn validate_partition(parts: &[Vec<usize>], n: usize, shards: usize) -> Result<(), String> {
    if parts.len() != shards.max(1) {
        return Err(format!("expected {} parts, got {}", shards.max(1), parts.len()));
    }
    let mut seen = vec![false; n];
    for (s, part) in parts.iter().enumerate() {
        for w in part.windows(2) {
            if w[1] <= w[0] {
                return Err(format!("shard {s} not strictly ascending: {w:?}"));
            }
        }
        for &i in part {
            if i >= n {
                return Err(format!("shard {s}: index {i} out of range (n={n})"));
            }
            if seen[i] {
                return Err(format!("index {i} assigned twice"));
            }
            seen[i] = true;
        }
    }
    if let Some(miss) = seen.iter().position(|&b| !b) {
        return Err(format!("index {miss} unassigned"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::random_normal(n, d, &mut rng)
    }

    #[test]
    fn all_partitioners_cover_the_ground_set() {
        let m = data(53, 6, 1);
        for name in PARTITIONERS {
            let p = build_partitioner(name, 9).unwrap();
            for shards in [1usize, 2, 3, 8, 60] {
                let parts = p.partition(&m, shards);
                validate_partition(&parts, 53, shards)
                    .unwrap_or_else(|e| panic!("{name}/p={shards}: {e}"));
            }
        }
    }

    #[test]
    fn single_shard_is_identity_for_every_strategy() {
        let m = data(17, 4, 2);
        let identity: Vec<usize> = (0..17).collect();
        for name in PARTITIONERS {
            let p = build_partitioner(name, 5).unwrap();
            let parts = p.partition(&m, 1);
            assert_eq!(parts.len(), 1, "{name}");
            assert_eq!(parts[0], identity, "{name}");
        }
    }

    #[test]
    fn round_robin_balanced() {
        let m = data(41, 3, 3);
        let parts = RoundRobinPartitioner.partition(&m, 4);
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 41);
        assert!(sizes.iter().all(|&s| (10..=11).contains(&s)), "{sizes:?}");
    }

    #[test]
    fn hash_is_content_addressed() {
        // the same vectors in a different row order shard identically
        let a = data(20, 5, 4);
        let perm: Vec<usize> = (0..20).rev().collect();
        let b = a.gather(&perm);
        let p = HashPartitioner { seed: 11 };
        let pa = p.partition(&a, 4);
        let pb = p.partition(&b, 4);
        for s in 0..4 {
            let mut rows_a: Vec<Vec<u32>> = pa[s]
                .iter()
                .map(|&i| a.row(i).iter().map(|x| x.to_bits()).collect())
                .collect();
            let mut rows_b: Vec<Vec<u32>> = pb[s]
                .iter()
                .map(|&i| b.row(i).iter().map(|x| x.to_bits()).collect())
                .collect();
            rows_a.sort();
            rows_b.sort();
            assert_eq!(rows_a, rows_b, "shard {s} differs under permutation");
        }
    }

    #[test]
    fn hash_seed_changes_assignment() {
        let m = data(64, 4, 5);
        let a = HashPartitioner { seed: 1 }.partition(&m, 4);
        let b = HashPartitioner { seed: 2 }.partition(&m, 4);
        assert_ne!(a, b);
    }

    #[test]
    fn locality_shards_are_contiguous_along_the_projection() {
        let m = data(60, 6, 6);
        let p = LocalityPartitioner { seed: 3 };
        let scores = p.scores(&m);
        let parts = p.partition(&m, 4);
        validate_partition(&parts, 60, 4).unwrap();
        // consecutive shards occupy non-overlapping score ranges
        for w in parts.windows(2) {
            let hi = w[0].iter().map(|&i| scores[i]).fold(f32::NEG_INFINITY, f32::max);
            let lo = w[1].iter().map(|&i| scores[i]).fold(f32::INFINITY, f32::min);
            assert!(hi <= lo, "shard ranges overlap: {hi} > {lo}");
        }
    }

    #[test]
    fn locality_chunks_balanced() {
        // ceil(101/4) = 26 -> sizes 26, 26, 26, 23
        let m = data(101, 8, 7);
        let parts = LocalityPartitioner { seed: 1 }.partition(&m, 4);
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 101);
        assert!(sizes.iter().all(|&s| (23..=26).contains(&s)), "{sizes:?}");
    }

    #[test]
    fn build_partitioner_rejects_unknown() {
        assert!(build_partitioner("magic", 0).is_none());
    }

    #[test]
    fn validate_partition_catches_violations() {
        assert!(validate_partition(&[vec![0, 1]], 3, 1).is_err()); // missing 2
        assert!(validate_partition(&[vec![0, 0, 1, 2]], 3, 1).is_err()); // not ascending
        assert!(validate_partition(&[vec![0, 1], vec![1, 2]], 3, 2).is_err()); // duplicate
        assert!(validate_partition(&[vec![0, 3]], 3, 1).is_err()); // out of range
        assert!(validate_partition(&[vec![0, 1, 2]], 3, 2).is_err()); // wrong count
        assert!(validate_partition(&[vec![0, 2], vec![1]], 3, 2).is_ok());
    }
}
