//! Second-stage merge of the two-stage summarizer: batched greedy over
//! a restricted candidate pool (the union of shard exemplars), with the
//! objective still evaluated against the **full** ground set, so merged
//! f-values are directly comparable to a single-node run.
//!
//! The selection loop is [`crate::optim::greedy::greedy_over_candidates`]
//! — the exact code path [`crate::optim::Greedy`] runs on the whole
//! ground set — so with the candidate pool equal to a greedy run's own
//! selection (the P = 1 case) the merge reproduces that run's indices,
//! trajectory and f-value bit for bit *by construction*, not by keeping
//! two loops in sync.

pub use crate::optim::greedy::greedy_over_candidates as greedy_merge;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::{Greedy, Optimizer};
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn full_candidate_pool_matches_plain_greedy_exactly() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(40, 5, &mut rng);
        let g = Greedy { batch: 16 }.run(&mut CpuOracle::new(v.clone()), 6);
        let all: Vec<usize> = (0..40).collect();
        let m = greedy_merge(&mut CpuOracle::new(v), &all, 6, 16);
        assert_eq!(g.indices, m.indices);
        assert_eq!(
            g.f_trajectory.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            m.f_trajectory.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn restricted_pool_only_selects_candidates() {
        let mut rng = Rng::new(2);
        let v = Matrix::random_normal(30, 4, &mut rng);
        let pool = vec![1usize, 7, 12, 19, 22, 28];
        let m = greedy_merge(&mut CpuOracle::new(v), &pool, 4, 8);
        assert_eq!(m.k(), 4);
        assert!(m.indices.iter().all(|i| pool.contains(i)), "{:?}", m.indices);
        let mut dedup = m.indices.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), m.indices.len());
    }

    #[test]
    fn k_exceeding_pool_selects_at_most_pool() {
        let mut rng = Rng::new(3);
        let v = Matrix::random_normal(20, 3, &mut rng);
        let pool = vec![2usize, 9, 15];
        let m = greedy_merge(&mut CpuOracle::new(v), &pool, 10, 8);
        assert!(m.k() <= 3);
    }

    #[test]
    fn empty_pool_yields_empty_summary() {
        let mut rng = Rng::new(4);
        let v = Matrix::random_normal(10, 3, &mut rng);
        let m = greedy_merge(&mut CpuOracle::new(v), &[], 3, 8);
        assert!(m.indices.is_empty());
        assert_eq!(m.f_final, 0.0);
    }

    #[test]
    fn trajectory_monotone() {
        let mut rng = Rng::new(5);
        let v = Matrix::random_normal(50, 4, &mut rng);
        let pool: Vec<usize> = (0..50).step_by(3).collect();
        let m = greedy_merge(&mut CpuOracle::new(v), &pool, 8, 4);
        for w in m.f_trajectory.windows(2) {
            assert!(w[1] >= w[0] - 1e-5, "{:?}", m.f_trajectory);
        }
    }
}
