//! Hierarchical spans + the bounded in-memory flight recorder.
//!
//! A span is opened as a guard and recorded into the ring buffer when
//! the guard drops (children therefore appear before their parents in
//! ring order). Per-thread scoping is implicit: opening a span makes it
//! the calling thread's *current* span, so nested instrumentation
//! points parent themselves automatically; crossing a thread boundary
//! is explicit via [`FlightRecorder::child_of`] with a captured parent
//! handle.
//!
//! Child spans are recorded **only when they have a parent** — an
//! active current span on the thread or an explicit non-zero handle.
//! Roots are opened at request entry points; everything outside a
//! request records nothing and costs one thread-local read.

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A completed span, as held by the flight recorder.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    /// Non-zero span handle, unique within the recorder.
    pub id: u64,
    /// Parent handle (0 for roots).
    pub parent: u64,
    /// Static instrumentation-point name (e.g. `api.execute`).
    pub name: &'static str,
    /// Start offset from the recorder's epoch, nanoseconds.
    pub start_ns: u64,
    /// Wall-clock duration, nanoseconds.
    pub dur_ns: u64,
}

thread_local! {
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

/// Bounded ring buffer of completed spans (oldest evicted first).
pub struct FlightRecorder {
    epoch: Instant,
    next_id: AtomicU64,
    enabled: AtomicBool,
    capacity: usize,
    evicted: AtomicU64,
    ring: Mutex<VecDeque<SpanRecord>>,
}

impl FlightRecorder {
    /// Recorder holding at most `capacity` completed spans.
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            enabled: AtomicBool::new(true),
            capacity: capacity.max(1),
            evicted: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Toggle recording (open guards still restore scoping correctly).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// The calling thread's current span handle (0 outside any span).
    pub fn current() -> u64 {
        CURRENT.get()
    }

    /// Open a root span (parent 0). Records whenever the recorder is
    /// enabled — roots belong at request entry points only.
    pub fn root(&self, name: &'static str) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard::noop(self);
        }
        self.open(name, 0)
    }

    /// Open a child under the calling thread's current span. No-op
    /// when the thread is outside any span.
    pub fn child(&self, name: &'static str) -> SpanGuard<'_> {
        self.child_of(name, Self::current())
    }

    /// Open a child under an explicit parent handle (the cross-thread
    /// form). No-op when `parent` is 0.
    pub fn child_of(&self, name: &'static str, parent: u64) -> SpanGuard<'_> {
        if parent == 0 || !self.enabled() {
            return SpanGuard::noop(self);
        }
        self.open(name, parent)
    }

    fn open(&self, name: &'static str, parent: u64) -> SpanGuard<'_> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let prev = CURRENT.replace(id);
        SpanGuard { recorder: self, id, parent, prev, name, start: Instant::now() }
    }

    fn record(&self, rec: SpanRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(rec);
    }

    /// Completed spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Spans evicted so far (ring overflow).
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Drop all completed spans (eviction counter is kept).
    pub fn clear(&self) {
        self.ring.lock().unwrap().clear();
    }

    /// Extract the tree rooted at `root`: the root's record plus every
    /// recorded descendant, sorted by start time. Call after the root
    /// guard has dropped — children complete before their parents, so
    /// the tree is whole by then. Foreign spans interleaved in the ring
    /// (other threads, other requests) are excluded by ancestry.
    pub fn trace(&self, root: u64) -> Vec<SpanRecord> {
        let snap = self.snapshot();
        let parents: BTreeMap<u64, u64> = snap.iter().map(|r| (r.id, r.parent)).collect();
        let mut out: Vec<SpanRecord> = snap
            .into_iter()
            .filter(|r| {
                let mut cur = r.id;
                loop {
                    if cur == root {
                        return true;
                    }
                    match parents.get(&cur) {
                        Some(&p) if p != 0 => cur = p,
                        _ => return false,
                    }
                }
            })
            .collect();
        out.sort_by_key(|r| (r.start_ns, r.id));
        out
    }
}

/// RAII span handle: scopes the thread's current span while alive and
/// records a [`SpanRecord`] on drop. A no-op guard (disabled recorder
/// or parentless child) has `id() == 0` and records nothing.
pub struct SpanGuard<'a> {
    recorder: &'a FlightRecorder,
    id: u64,
    parent: u64,
    prev: u64,
    name: &'static str,
    start: Instant,
}

impl SpanGuard<'_> {
    fn noop(recorder: &FlightRecorder) -> SpanGuard<'_> {
        SpanGuard { recorder, id: 0, parent: 0, prev: 0, name: "", start: Instant::now() }
    }

    /// This span's handle — pass to [`FlightRecorder::child_of`] to
    /// parent work on another thread, or to [`FlightRecorder::trace`]
    /// after the guard drops. 0 for no-op guards.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        CURRENT.set(self.prev);
        self.recorder.record(SpanRecord {
            id: self.id,
            parent: self.parent,
            name: self.name,
            start_ns: self.start.duration_since(self.recorder.epoch).as_nanos() as u64,
            dur_ns: self.start.elapsed().as_nanos() as u64,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesting_parents_and_scoping() {
        let fr = FlightRecorder::new(64);
        assert_eq!(FlightRecorder::current(), 0);
        let (root_id, child_id, grandchild_id);
        {
            let root = fr.root("t.root");
            root_id = root.id();
            assert_ne!(root_id, 0);
            assert_eq!(FlightRecorder::current(), root_id);
            {
                let child = fr.child("t.child");
                child_id = child.id();
                assert_eq!(FlightRecorder::current(), child_id);
                {
                    let g = fr.child("t.grandchild");
                    grandchild_id = g.id();
                }
                assert_eq!(FlightRecorder::current(), child_id, "scope restored after drop");
            }
            assert_eq!(FlightRecorder::current(), root_id);
        }
        assert_eq!(FlightRecorder::current(), 0);

        let trace = fr.trace(root_id);
        assert_eq!(trace.len(), 3);
        assert_eq!(trace[0].name, "t.root");
        assert_eq!(trace[0].parent, 0);
        let child = trace.iter().find(|r| r.id == child_id).unwrap();
        assert_eq!(child.parent, root_id);
        let g = trace.iter().find(|r| r.id == grandchild_id).unwrap();
        assert_eq!(g.parent, child_id);
        // children are contained in the parent window
        assert!(child.start_ns >= trace[0].start_ns);
        assert!(child.dur_ns <= trace[0].dur_ns);
    }

    #[test]
    fn trace_excludes_foreign_roots() {
        let fr = FlightRecorder::new(64);
        let a_id;
        {
            let a = fr.root("t.a");
            a_id = a.id();
            let _inner = fr.child("t.a.inner");
        }
        {
            let _b = fr.root("t.b");
            let _inner = fr.child("t.b.inner");
        }
        let trace = fr.trace(a_id);
        assert_eq!(trace.len(), 2);
        assert!(trace.iter().all(|r| r.name.starts_with("t.a")));
    }

    #[test]
    fn child_without_parent_is_noop() {
        let fr = FlightRecorder::new(8);
        {
            let g = fr.child("t.orphan");
            assert_eq!(g.id(), 0);
        }
        assert!(fr.snapshot().is_empty());
        {
            let g = fr.child_of("t.explicit-orphan", 0);
            assert_eq!(g.id(), 0);
        }
        assert!(fr.snapshot().is_empty());
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let fr = FlightRecorder::new(8);
        fr.set_enabled(false);
        {
            let g = fr.root("t.off");
            assert_eq!(g.id(), 0);
        }
        assert!(fr.snapshot().is_empty());
        fr.set_enabled(true);
        {
            let _g = fr.root("t.on");
        }
        assert_eq!(fr.snapshot().len(), 1);
    }

    #[test]
    fn ring_evicts_oldest() {
        let fr = FlightRecorder::new(4);
        let mut ids = Vec::new();
        for _ in 0..6 {
            let g = fr.root("t.evict");
            ids.push(g.id());
        }
        let snap = fr.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(fr.evicted(), 2);
        let kept: Vec<u64> = snap.iter().map(|r| r.id).collect();
        assert_eq!(kept, ids[2..].to_vec(), "oldest two evicted, order preserved");
        fr.clear();
        assert!(fr.snapshot().is_empty());
        assert_eq!(fr.evicted(), 2);
    }

    #[test]
    fn cross_thread_parenting_via_explicit_handle() {
        use std::sync::Arc;
        let fr = Arc::new(FlightRecorder::new(64));
        let root_id;
        {
            let root = fr.root("t.xthread.root");
            root_id = root.id();
            let fr2 = Arc::clone(&fr);
            std::thread::spawn(move || {
                // worker thread: no implicit current span
                assert_eq!(FlightRecorder::current(), 0);
                let child = fr2.child_of("t.xthread.worker", root_id);
                assert_ne!(child.id(), 0);
                let _nested = fr2.child("t.xthread.nested");
            })
            .join()
            .unwrap();
        }
        let trace = fr.trace(root_id);
        assert_eq!(trace.len(), 3);
        let worker = trace.iter().find(|r| r.name == "t.xthread.worker").unwrap();
        assert_eq!(worker.parent, root_id);
        let nested = trace.iter().find(|r| r.name == "t.xthread.nested").unwrap();
        assert_eq!(nested.parent, worker.id);
    }
}
