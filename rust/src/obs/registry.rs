//! Typed metrics registry: counters, gauges, float counters and
//! log-bucketed latency histograms.
//!
//! Handles are `Arc`-backed and lock-free on the hot path (relaxed
//! atomics); the registry itself is only locked to register or
//! snapshot. Histogram snapshots are mergeable across shard workers
//! (identical bucket layout → element-wise sum), which is how the
//! fleet aggregates per-replica latency distributions.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic `u64` counter handle (clone = same underlying cell).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Monotonic `f64` counter handle (seconds totals etc.), implemented
/// as bit-CAS over an `AtomicU64` — std has no `AtomicF64`.
#[derive(Clone, Debug, Default)]
pub struct FCounter(Arc<AtomicU64>);

impl FCounter {
    /// Add `v` (CAS loop; contention here is negligible).
    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Settable `i64` gauge handle (queue depths, replica counts).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    /// Add `delta` (may be negative).
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-spaced ascending upper bounds: `first * growth^i` for
/// `i in 0..buckets`. The default latency layout (`first` 1 µs,
/// `growth` 2, 40 buckets) spans ~1 µs to ~9 min.
pub fn log_bounds(first: f64, growth: f64, buckets: usize) -> Vec<f64> {
    assert!(first > 0.0 && growth > 1.0 && buckets > 0);
    let mut out = Vec::with_capacity(buckets);
    let mut b = first;
    for _ in 0..buckets {
        out.push(b);
        b *= growth;
    }
    out
}

/// Default latency bucket layout used by [`Registry::new`].
pub fn default_latency_bounds(buckets: usize) -> Vec<f64> {
    log_bounds(1e-6, 2.0, buckets.max(1))
}

struct HistCore {
    bounds: Vec<f64>,
    /// One cell per bound + a final overflow cell.
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    /// `f64` bits of the running sum (see [`FCounter`]).
    sum: AtomicU64,
}

/// Log-bucketed histogram handle (clone = same underlying cells).
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistCore>,
}

impl Histogram {
    /// Build with explicit ascending bucket upper bounds.
    pub fn with_bounds(bounds: Vec<f64>) -> Histogram {
        assert!(!bounds.is_empty());
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            core: Arc::new(HistCore {
                bounds,
                counts,
                count: AtomicU64::new(0),
                sum: AtomicU64::new(0),
            }),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let i = self.core.bounds.partition_point(|&b| b < v);
        self.core.counts[i].fetch_add(1, Ordering::Relaxed);
        self.core.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.core.sum.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.core.sum.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Time a closure and record its wall-clock seconds.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(t0.elapsed().as_secs_f64());
        out
    }

    /// Point-in-time copy of the cells.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.core.bounds.clone(),
            counts: self.core.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.core.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.core.sum.load(Ordering::Relaxed)),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Histogram(count={}, sum={}, buckets={})", s.count, s.sum, s.bounds.len())
    }
}

/// Immutable histogram state: mergeable, quantile-queryable.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Ascending bucket upper bounds.
    pub bounds: Vec<f64>,
    /// Per-bucket counts; last cell counts observations above every bound.
    pub counts: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of observations.
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Mean observation (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimated quantile `q in [0, 1]` by linear interpolation inside
    /// the covering bucket. Empty histograms report 0.0; observations in
    /// the overflow bucket report the top bound (no upper edge to
    /// interpolate toward).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c as f64;
            if next >= target {
                let Some(&hi) = self.bounds.get(i) else {
                    // overflow bucket: clamp to the top finite bound
                    return *self.bounds.last().expect("bounds non-empty");
                };
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let frac = ((target - cum) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            cum = next;
        }
        *self.bounds.last().expect("bounds non-empty")
    }

    /// Median shorthand.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    /// 90th-percentile shorthand.
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }
    /// 99th-percentile shorthand.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold another snapshot in (shard-worker aggregation).
    ///
    /// # Panics
    /// When the bucket layouts differ — merging histograms with
    /// different bounds is a programming error, not a runtime state.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        assert_eq!(self.bounds, other.bounds, "histogram bucket layouts differ");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

/// One registered metric at snapshot time.
#[derive(Debug, Clone)]
pub struct MetricSnapshot {
    /// Registered family name (e.g. `ebc_gains_seconds`).
    pub name: String,
    /// Registered help text.
    pub help: String,
    /// Kind + value.
    pub value: MetricValue,
}

/// Snapshot value of one metric family.
#[derive(Debug, Clone)]
pub enum MetricValue {
    /// Monotonic integer counter.
    Counter(u64),
    /// Monotonic float counter.
    FCounter(f64),
    /// Point-in-time gauge.
    Gauge(i64),
    /// Latency distribution.
    Histogram(HistogramSnapshot),
}

/// Ordered (by name) collection of metric snapshots.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    /// The families, ascending by name.
    pub metrics: Vec<MetricSnapshot>,
}

impl RegistrySnapshot {
    /// Look a family up by name.
    pub fn get(&self, name: &str) -> Option<&MetricSnapshot> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// Histogram family accessor (None when absent or not a histogram).
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        match self.get(name)?.value {
            MetricValue::Histogram(ref h) => Some(h),
            _ => None,
        }
    }
}

enum Metric {
    Counter(Counter),
    FCounter(FCounter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A named family of metric handles. Registration is get-or-create:
/// asking twice for the same name returns the same underlying cells.
pub struct Registry {
    hist_bounds: Vec<f64>,
    inner: Mutex<BTreeMap<String, (String, Metric)>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// Registry with the default 40-bucket latency layout.
    pub fn new() -> Registry {
        Registry::with_buckets(40)
    }

    /// Registry whose histograms get `buckets` log-spaced latency
    /// buckets (1 µs first bound, ×2 growth).
    pub fn with_buckets(buckets: usize) -> Registry {
        Registry {
            hist_bounds: default_latency_bounds(buckets),
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    /// Get-or-register a counter.
    ///
    /// # Panics
    /// When `name` is already registered as a different kind.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut m = self.inner.lock().unwrap();
        let (_, metric) = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::Counter(Counter::default())));
        match metric {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-register a float counter.
    ///
    /// # Panics
    /// When `name` is already registered as a different kind.
    pub fn fcounter(&self, name: &str, help: &str) -> FCounter {
        let mut m = self.inner.lock().unwrap();
        let (_, metric) = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::FCounter(FCounter::default())));
        match metric {
            Metric::FCounter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-register a gauge.
    ///
    /// # Panics
    /// When `name` is already registered as a different kind.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut m = self.inner.lock().unwrap();
        let (_, metric) = m
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Metric::Gauge(Gauge::default())));
        match metric {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-register a histogram with the registry's bucket layout.
    ///
    /// # Panics
    /// When `name` is already registered as a different kind.
    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        let mut m = self.inner.lock().unwrap();
        let (_, metric) = m.entry(name.to_string()).or_insert_with(|| {
            (help.to_string(), Metric::Histogram(Histogram::with_bounds(self.hist_bounds.clone())))
        });
        match metric {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Point-in-time copy of every family, ascending by name.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let m = self.inner.lock().unwrap();
        let metrics = m
            .iter()
            .map(|(name, (help, metric))| MetricSnapshot {
                name: name.clone(),
                help: help.clone(),
                value: match metric {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::FCounter(c) => MetricValue::FCounter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                },
            })
            .collect();
        RegistrySnapshot { metrics }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_fcounter_roundtrip() {
        let r = Registry::new();
        let c = r.counter("c_total", "a counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(r.counter("c_total", "a counter").get(), 5, "get-or-register shares cells");

        let g = r.gauge("g", "a gauge");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);

        let f = r.fcounter("f_seconds_total", "a float counter");
        f.add(0.25);
        f.add(0.5);
        assert!((f.get() - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("x", "c");
        r.gauge("x", "g");
    }

    #[test]
    fn log_bounds_shape() {
        let b = log_bounds(1e-6, 2.0, 4);
        assert_eq!(b.len(), 4);
        assert!((b[0] - 1e-6).abs() < 1e-18);
        assert!((b[3] - 8e-6).abs() < 1e-18);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn histogram_empty_quantiles_are_zero() {
        let h = Histogram::with_bounds(log_bounds(1e-6, 2.0, 10));
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn histogram_single_sample_lands_in_its_bucket() {
        let h = Histogram::with_bounds(log_bounds(1e-6, 2.0, 30));
        h.observe(3e-3);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        // every quantile of a single sample lies inside the covering
        // bucket, i.e. within a ×2 band of the observation
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let v = s.quantile(q);
            assert!(v <= 4.096e-3 + 1e-12 && v >= 0.0, "q={q}: {v}");
        }
        assert!(s.quantile(1.0) >= 3e-3 / 2.0, "upper quantile below the bucket floor");
        assert!((s.mean() - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        // uniform mass in one bucket (1.0, 2.0]: quantiles interpolate
        let h = Histogram::with_bounds(vec![1.0, 2.0, 4.0]);
        for _ in 0..100 {
            h.observe(1.5);
        }
        let s = h.snapshot();
        assert!((s.p50() - 1.5).abs() < 0.02, "{}", s.p50());
        assert!((s.quantile(0.25) - 1.25).abs() < 0.02);
        assert!((s.p99() - 1.99).abs() < 0.02);
    }

    #[test]
    fn histogram_overflow_clamps_to_top_bound() {
        let h = Histogram::with_bounds(vec![1.0, 2.0]);
        h.observe(100.0);
        let s = h.snapshot();
        assert_eq!(s.counts[2], 1);
        assert_eq!(s.p50(), 2.0);
        assert_eq!(s.p99(), 2.0);
    }

    #[test]
    fn histogram_merge_equals_combined_stream() {
        let bounds = log_bounds(1e-6, 2.0, 24);
        let a = Histogram::with_bounds(bounds.clone());
        let b = Histogram::with_bounds(bounds.clone());
        let all = Histogram::with_bounds(bounds);
        for i in 0..50 {
            let v = 1e-5 * (1.0 + i as f64);
            a.observe(v);
            all.observe(v);
        }
        for i in 0..80 {
            let v = 3e-4 * (1.0 + i as f64);
            b.observe(v);
            all.observe(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let want = all.snapshot();
        assert_eq!(merged.counts, want.counts);
        assert_eq!(merged.count, want.count);
        assert!((merged.sum - want.sum).abs() < 1e-9 * want.sum.abs());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(merged.quantile(q), want.quantile(q), "q={q}");
        }
    }

    #[test]
    #[should_panic(expected = "bucket layouts differ")]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::with_bounds(vec![1.0, 2.0]).snapshot();
        let b = Histogram::with_bounds(vec![1.0, 3.0]).snapshot();
        a.merge(&b);
    }

    #[test]
    fn histogram_time_records() {
        let h = Histogram::with_bounds(log_bounds(1e-6, 2.0, 30));
        let out = h.time(|| 41 + 1);
        assert_eq!(out, 42);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert!(s.sum >= 0.0);
    }

    #[test]
    fn snapshot_sorted_and_typed() {
        let r = Registry::with_buckets(8);
        r.gauge("zz", "last");
        r.counter("aa_total", "first");
        r.histogram("mm_seconds", "middle").observe(1e-3);
        let s = r.snapshot();
        let names: Vec<&str> = s.metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["aa_total", "mm_seconds", "zz"]);
        assert!(matches!(s.get("aa_total").unwrap().value, MetricValue::Counter(0)));
        assert_eq!(s.histogram("mm_seconds").unwrap().count, 1);
        assert!(s.histogram("aa_total").is_none());
    }
}
