//! Unified observability: metrics, hierarchical spans and exposition.
//!
//! One substrate replaces the previous four ad-hoc timing mechanisms
//! (`util::timer::Profile`, hand-rolled `CoordinatorMetrics` counter
//! fields, bench-local JSON, `StageTimings`-only provenance):
//!
//! * [`registry`] — typed counters, gauges, float counters and
//!   log-bucketed latency histograms (p50/p90/p99, mergeable across
//!   shard workers) behind cheap atomic handles;
//! * [`span`] — hierarchical spans with explicit parent handles and
//!   per-thread scoping, recorded into a bounded in-memory flight
//!   recorder ring buffer;
//! * [`expo`] — Prometheus-style text and JSON renderers plus a span
//!   tree formatter.
//!
//! The crate keeps one process-global [`Obs`] (histograms for kernel /
//! wire / merge latencies, the flight recorder) reachable through
//! [`global`], while stateful components such as the coordinator own
//! private [`Registry`] instances so tests never observe each other's
//! counts.
//!
//! Span recording is *opt-in by ancestry*: child spans ([`span`]
//! function) record only while the calling thread is inside an active
//! span, so unit tests hammering kernel code do not flood the recorder.
//! Roots are opened at request entry points ([`root_span`]) — e.g.
//! `api::execute` — and every instrumented stage below them nests
//! automatically, across threads via explicit parent handles.

pub mod expo;
pub mod registry;
pub mod span;

pub use registry::{
    Counter, FCounter, Gauge, Histogram, HistogramSnapshot, MetricSnapshot, MetricValue, Registry,
    RegistrySnapshot,
};
pub use span::{FlightRecorder, SpanGuard, SpanRecord};

use std::sync::OnceLock;

/// Histogram of per-call CPU-oracle `gains` latency (seconds).
pub const GAINS_SECONDS: &str = "ebc_gains_seconds";
/// Histogram of stage-2 greedy-merge latency per sharded run (seconds).
pub const MERGE_SECONDS: &str = "ebc_merge_seconds";
/// Histogram of wire frame encode latency (job + result frames).
pub const WIRE_ENCODE_SECONDS: &str = "ebc_wire_encode_seconds";
/// Histogram of wire frame decode latency (job + result frames).
pub const WIRE_DECODE_SECONDS: &str = "ebc_wire_decode_seconds";
/// Histogram of blocked Gram-matrix (`gemm_nt`) call latency.
pub const GEMM_SECONDS: &str = "ebc_gemm_seconds";
/// Histogram of engine `gains` graph execution latency.
pub const ENGINE_GAINS_SECONDS: &str = "ebc_engine_gains_seconds";
/// Histogram of engine `update` graph execution latency.
pub const ENGINE_UPDATE_SECONDS: &str = "ebc_engine_update_seconds";
/// Histogram of engine `eval_sets` graph execution latency.
pub const ENGINE_EVAL_SETS_SECONDS: &str = "ebc_engine_eval_sets_seconds";
/// Counter of summarize requests executed through `api::execute`.
pub const REQUESTS_TOTAL: &str = "ebc_requests_total";
/// Counter of TCP connections the coordinator established to replicas.
pub const NET_CONNECTS: &str = "ebc_net_connects";
/// Counter of socket operations that hit their read/write/connect deadline.
pub const NET_TIMEOUTS: &str = "ebc_net_timeouts";
/// Counter of job attempts retried after a transient network failure.
pub const NET_RETRIES: &str = "ebc_net_retries";
/// Counter of bytes that crossed a real socket (both legs, as seen by
/// the coordinator).
pub const NET_BYTES: &str = "ebc_net_bytes";
/// Gauge of heartbeat lag: registry ticks since the freshest live
/// replica heartbeat at the end of the last scheduling round.
pub const NET_HEARTBEAT_LAG: &str = "ebc_net_heartbeat_lag";
/// Histogram of per-sieve prune latency (stage-1 shard prunes and
/// merge-node `max_merge_n` caps alike).
pub const PRUNE_SECONDS: &str = "ebc_prune_seconds";
/// Counter of ground rows sieved away (and charged to a dominator)
/// across all prunes.
pub const PRUNE_DROPPED_TOTAL: &str = "ebc_prune_dropped_total";
/// Gauge of the merge-tree depth of the last sharded run (1 = flat).
pub const PRUNE_MERGE_DEPTH: &str = "ebc_prune_merge_depth";

/// Tunables for the process-global observability state — the `[obs]`
/// config section. `enabled` gates only span recording; metric handles
/// always count (they are load-bearing for snapshots and tests).
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Record spans into the flight recorder (metrics are unaffected).
    pub enabled: bool,
    /// Flight-recorder ring capacity (completed spans held before the
    /// oldest is evicted).
    pub recorder_capacity: usize,
    /// Log-spaced latency buckets per histogram on the global registry.
    pub hist_buckets: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: true, recorder_capacity: 4096, hist_buckets: 40 }
    }
}

/// A metrics registry paired with a span flight recorder.
pub struct Obs {
    /// Metric families (counters / gauges / histograms).
    pub registry: Registry,
    /// Bounded ring of completed spans.
    pub recorder: FlightRecorder,
}

impl Obs {
    /// Build a fresh instance from a config (tests use private ones).
    pub fn new(cfg: &ObsConfig) -> Obs {
        let recorder = FlightRecorder::new(cfg.recorder_capacity);
        recorder.set_enabled(cfg.enabled);
        Obs { registry: Registry::with_buckets(cfg.hist_buckets), recorder }
    }
}

static GLOBAL: OnceLock<Obs> = OnceLock::new();

/// The process-global observability state (lazily built with
/// [`ObsConfig::default`] on first touch).
pub fn global() -> &'static Obs {
    GLOBAL.get_or_init(|| Obs::new(&ObsConfig::default()))
}

/// Apply a config to the global state. The span on/off switch always
/// applies; `recorder_capacity` / `hist_buckets` only take effect when
/// this call is the first touch of the global state (ring capacity and
/// bucket layout are fixed at construction so snapshots stay mergeable).
pub fn configure(cfg: &ObsConfig) {
    let obs = GLOBAL.get_or_init(|| Obs::new(cfg));
    obs.recorder.set_enabled(cfg.enabled);
}

/// Open a root span on the global recorder (records when enabled).
pub fn root_span(name: &'static str) -> SpanGuard<'static> {
    global().recorder.root(name)
}

/// Open a child span under the calling thread's current span. No-op
/// (and free) outside an active span — see the module docs.
pub fn span(name: &'static str) -> SpanGuard<'static> {
    global().recorder.child(name)
}

/// Open a child span under an explicit parent handle (for crossing
/// threads). No-op when `parent` is 0.
pub fn span_under(name: &'static str, parent: u64) -> SpanGuard<'static> {
    global().recorder.child_of(name, parent)
}

/// The calling thread's current span handle (0 outside any span).
pub fn current_span() -> u64 {
    FlightRecorder::current()
}

/// Get-or-register a histogram on the global registry.
pub fn histogram(name: &str, help: &str) -> Histogram {
    global().registry.histogram(name, help)
}

/// Get-or-register a counter on the global registry.
pub fn counter(name: &str, help: &str) -> Counter {
    global().registry.counter(name, help)
}

/// Get-or-register a gauge on the global registry.
pub fn gauge(name: &str, help: &str) -> Gauge {
    global().registry.gauge(name, help)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_handles_are_shared() {
        let a = counter("ebc_obs_mod_test_total", "test counter");
        let b = counter("ebc_obs_mod_test_total", "test counter");
        let before = a.get();
        b.inc();
        assert_eq!(a.get(), before + 1);
    }

    #[test]
    fn configure_toggles_span_recording() {
        // only the enabled switch is asserted — capacity is first-touch
        configure(&ObsConfig { enabled: false, ..ObsConfig::default() });
        assert!(!global().recorder.enabled());
        {
            let g = root_span("obs.mod.disabled");
            assert_eq!(g.id(), 0);
        }
        configure(&ObsConfig::default());
        assert!(global().recorder.enabled());
    }

    #[test]
    fn child_span_outside_root_is_noop() {
        configure(&ObsConfig::default());
        let g = span("obs.mod.orphan");
        assert_eq!(g.id(), 0);
    }
}
