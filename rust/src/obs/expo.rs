//! Exposition: render registry snapshots as Prometheus-style text or
//! JSON, and span traces as an indented tree.

use crate::obs::registry::{MetricValue, RegistrySnapshot};
use crate::obs::span::SpanRecord;
use crate::util::json::{Json, ObjBuilder};
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn fmt_f64(v: f64) -> String {
    // shortest round-trip repr; deterministic across platforms
    format!("{v}")
}

/// Prometheus-style text exposition. Histogram buckets are cumulative
/// (`le` semantics) with a terminal `+Inf` bucket, followed by `_sum`
/// and `_count` — the classic scrape format, minus labels.
pub fn render_text(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    for m in &snap.metrics {
        let _ = writeln!(out, "# HELP {} {}", m.name, m.help);
        match &m.value {
            MetricValue::Counter(v) => {
                let _ = writeln!(out, "# TYPE {} counter", m.name);
                let _ = writeln!(out, "{} {}", m.name, v);
            }
            MetricValue::FCounter(v) => {
                let _ = writeln!(out, "# TYPE {} counter", m.name);
                let _ = writeln!(out, "{} {}", m.name, fmt_f64(*v));
            }
            MetricValue::Gauge(v) => {
                let _ = writeln!(out, "# TYPE {} gauge", m.name);
                let _ = writeln!(out, "{} {}", m.name, v);
            }
            MetricValue::Histogram(h) => {
                let _ = writeln!(out, "# TYPE {} histogram", m.name);
                let mut cum = 0u64;
                for (i, &bound) in h.bounds.iter().enumerate() {
                    cum += h.counts[i];
                    let _ = writeln!(
                        out,
                        "{}_bucket{{le=\"{}\"}} {}",
                        m.name,
                        fmt_f64(bound),
                        cum
                    );
                }
                let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", m.name, h.count);
                let _ = writeln!(out, "{}_sum {}", m.name, fmt_f64(h.sum));
                let _ = writeln!(out, "{}_count {}", m.name, h.count);
            }
        }
    }
    out
}

/// JSON exposition: one object per family keyed by name, with
/// histograms carrying count/sum/mean + interpolated p50/p90/p99 and
/// their raw (non-cumulative) buckets as `[upper_bound, count]` pairs.
pub fn render_json(snap: &RegistrySnapshot) -> Json {
    let mut b = ObjBuilder::new();
    for m in &snap.metrics {
        let entry = match &m.value {
            MetricValue::Counter(v) => ObjBuilder::new()
                .str("type", "counter")
                .str("help", m.help.clone())
                .num("value", *v as f64)
                .build(),
            MetricValue::FCounter(v) => ObjBuilder::new()
                .str("type", "counter")
                .str("help", m.help.clone())
                .num("value", *v)
                .build(),
            MetricValue::Gauge(v) => ObjBuilder::new()
                .str("type", "gauge")
                .str("help", m.help.clone())
                .num("value", *v as f64)
                .build(),
            MetricValue::Histogram(h) => {
                let buckets: Vec<Json> = h
                    .bounds
                    .iter()
                    .enumerate()
                    .map(|(i, &bound)| {
                        Json::Arr(vec![Json::Num(bound), Json::Num(h.counts[i] as f64)])
                    })
                    .chain(std::iter::once(Json::Arr(vec![
                        Json::Null,
                        Json::Num(*h.counts.last().unwrap_or(&0) as f64),
                    ])))
                    .collect();
                ObjBuilder::new()
                    .str("type", "histogram")
                    .str("help", m.help.clone())
                    .int("count", h.count as usize)
                    .num("sum", h.sum)
                    .num("mean", h.mean())
                    .num("p50", h.p50())
                    .num("p90", h.p90())
                    .num("p99", h.p99())
                    .val("buckets", Json::Arr(buckets))
                    .build()
            }
        };
        b = b.val(&m.name, entry);
    }
    b.build()
}

/// JSON form of a trace: one `{name, id, parent, start_ns, dur_ns}`
/// object per span (parent 0 = root), in the order given.
pub fn trace_json(spans: &[SpanRecord]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|r| {
                ObjBuilder::new()
                    .str("name", r.name)
                    .int("id", r.id as usize)
                    .int("parent", r.parent as usize)
                    .int("start_ns", r.start_ns as usize)
                    .int("dur_ns", r.dur_ns as usize)
                    .build()
            })
            .collect(),
    )
}

fn fmt_ns(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Render a trace (as returned by `FlightRecorder::trace`) as an
/// indented tree, one span per line with its wall-clock duration and
/// start offset inside the trace.
pub fn render_trace(spans: &[SpanRecord]) -> String {
    if spans.is_empty() {
        return "(no spans recorded)\n".to_string();
    }
    let mut depth: BTreeMap<u64, usize> = BTreeMap::new();
    let t0 = spans.iter().map(|r| r.start_ns).min().unwrap_or(0);
    let mut out = String::new();
    for r in spans {
        let d = depth.get(&r.parent).map(|d| d + 1).unwrap_or(0);
        depth.insert(r.id, d);
        let _ = writeln!(
            out,
            "{:indent$}{:<32} {:>10}  (+{})",
            "",
            r.name,
            fmt_ns(r.dur_ns),
            fmt_ns(r.start_ns - t0),
            indent = 2 * d
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;
    use crate::obs::span::FlightRecorder;

    use crate::obs::registry::{HistogramSnapshot, MetricSnapshot};

    /// Hand-built snapshot: every rendered number comes from a literal,
    /// so the golden text is exact by construction (a computed float
    /// sum's shortest-round-trip repr would be brittle to predict).
    fn golden_snapshot() -> RegistrySnapshot {
        RegistrySnapshot {
            metrics: vec![
                MetricSnapshot {
                    name: "demo_requests_total".into(),
                    help: "requests seen".into(),
                    value: MetricValue::Counter(3),
                },
                MetricSnapshot {
                    name: "lat_seconds".into(),
                    help: "op latency".into(),
                    value: MetricValue::Histogram(HistogramSnapshot {
                        bounds: vec![0.001, 0.01, 0.1, 1.0],
                        counts: vec![0, 1, 1, 0, 1],
                        count: 3,
                        sum: 0.75,
                    }),
                },
                MetricSnapshot {
                    name: "queue_len".into(),
                    help: "queue depth".into(),
                    value: MetricValue::Gauge(-2),
                },
            ],
        }
    }

    #[test]
    fn text_exposition_matches_golden() {
        let got = render_text(&golden_snapshot());
        let want = "\
# HELP demo_requests_total requests seen
# TYPE demo_requests_total counter
demo_requests_total 3
# HELP lat_seconds op latency
# TYPE lat_seconds histogram
lat_seconds_bucket{le=\"0.001\"} 0
lat_seconds_bucket{le=\"0.01\"} 1
lat_seconds_bucket{le=\"0.1\"} 2
lat_seconds_bucket{le=\"1\"} 2
lat_seconds_bucket{le=\"+Inf\"} 3
lat_seconds_sum 0.75
lat_seconds_count 3
# HELP queue_len queue depth
# TYPE queue_len gauge
queue_len -2
";
        assert_eq!(got, want);
    }

    fn demo_registry() -> Registry {
        // 4 log buckets: 1e-6, 2e-6, 4e-6, 8e-6
        let r = Registry::with_buckets(4);
        r.counter("demo_requests_total", "requests seen").add(3);
        let h = r.histogram("lat_seconds", "op latency");
        h.observe(1.5e-6);
        h.observe(1e-2);
        r.gauge("queue_len", "queue depth").set(-2);
        r
    }

    #[test]
    fn text_exposition_of_live_registry_has_cumulative_buckets() {
        let got = render_text(&demo_registry().snapshot());
        // 1.5e-6 lands in the le=2e-6 bucket, 1e-2 overflows
        assert!(got.contains("lat_seconds_bucket{le=\"0.000002\"} 1"), "{got}");
        assert!(got.contains("lat_seconds_bucket{le=\"0.000008\"} 1"), "{got}");
        assert!(got.contains("lat_seconds_bucket{le=\"+Inf\"} 2"), "{got}");
        assert!(got.contains("lat_seconds_count 2"), "{got}");
        assert!(got.contains("demo_requests_total 3"), "{got}");
    }

    #[test]
    fn json_exposition_parses_and_carries_quantiles() {
        let j = render_json(&demo_registry().snapshot());
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.get("demo_requests_total").unwrap().get("value").unwrap().as_usize(),
            Some(3)
        );
        let h = back.get("lat_seconds").unwrap();
        assert_eq!(h.get("type").unwrap().as_str(), Some("histogram"));
        assert_eq!(h.get("count").unwrap().as_usize(), Some(2));
        assert!(h.get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert!(h.get("p99").unwrap().as_f64().unwrap() > 0.0);
        // 4 finite buckets + overflow
        assert_eq!(h.get("buckets").unwrap().as_arr().unwrap().len(), 5);
        let gauge = back.get("queue_len").unwrap();
        assert_eq!(gauge.get("value").unwrap().as_f64(), Some(-2.0));
    }

    #[test]
    fn trace_tree_indents_children() {
        let fr = FlightRecorder::new(16);
        let root_id;
        {
            let root = fr.root("demo.root");
            root_id = root.id();
            let _child = fr.child("demo.child");
        }
        let text = render_trace(&fr.trace(root_id));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("demo.root"));
        assert!(lines[1].starts_with("  demo.child"));
        assert_eq!(render_trace(&[]), "(no spans recorded)\n");
    }
}
