//! The Greedy optimizer (paper §3): k steps, each selecting the
//! candidate with the maximal marginal gain. Achieves the (1 − 1/e)
//! approximation of Nemhauser–Wolsey–Fisher.
//!
//! Candidates are evaluated in batches of `batch` — exactly the
//! `S_multi = {S ∪ {c_1}, ..., S ∪ {c_m}}` pattern of paper §4.1 that
//! the accelerator engine turns into one work-matrix launch.

use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::{fold_mindist, initial_mindist, Oracle};
use std::time::Instant;

pub struct Greedy {
    /// Candidate-batch size per oracle call (the engine pads this to its
    /// C bucket; larger batches amortize launch overhead).
    pub batch: usize,
}

impl Default for Greedy {
    fn default() -> Self {
        Greedy { batch: 1024 }
    }
}

impl Optimizer for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let all: Vec<usize> = (0..oracle.n()).collect();
        greedy_over_candidates(oracle, &all, k, self.batch)
    }
}

/// Batched greedy over an explicit candidate pool (ascending ground
/// indices, deduplicated): the one selection loop behind both
/// [`Greedy`] (pool = whole ground set) and the shard subsystem's
/// second-stage merge ([`crate::shard::merge`], pool = union of shard
/// exemplars) — sharing it is what makes the sharded P = 1 path
/// reproduce single-node greedy bit for bit by construction.
pub fn greedy_over_candidates(
    oracle: &mut dyn Oracle,
    candidates: &[usize],
    k: usize,
    batch: usize,
) -> SummaryResult {
    let t0 = Instant::now();
    let work0 = oracle.work_counter();
    debug_assert!(
        candidates.windows(2).all(|w| w[0] < w[1]),
        "candidates must be sorted + deduplicated"
    );
    let mut mindist = initial_mindist(oracle);
    let mut selected: Vec<usize> = Vec::with_capacity(k.min(candidates.len()));
    let mut remaining: Vec<usize> = candidates.to_vec();
    let mut traj = Vec::with_capacity(k.min(candidates.len()));
    let mut calls = 0usize;

    for _ in 0..k.min(candidates.len()) {
        // batched argmax over the remaining candidates; ties go to the
        // lowest index (ascending scan keeps the first maximum)
        let mut best: Option<(usize, f32)> = None;
        for chunk in remaining.chunks(batch.max(1)) {
            let gains = oracle.gains(&mindist, chunk);
            calls += 1;
            for (&c, &g) in chunk.iter().zip(&gains) {
                match best {
                    Some((_, bg)) if g <= bg => {}
                    _ => best = Some((c, g)),
                }
            }
        }
        let Some((j, gain)) = best else { break };
        if gain <= 0.0 && !selected.is_empty() {
            // no candidate improves f — summary saturated
            break;
        }
        fold_mindist(&mut mindist, &oracle.dist_col(j));
        remaining.retain(|&c| c != j);
        selected.push(j);
        // `f_of_state` defaults to `f_from_mindist`; weighted oracles
        // (pruned cores) report their unbiased full-ground estimate
        traj.push(oracle.f_of_state(&mindist));
    }

    let f_final = traj.last().copied().unwrap_or(0.0);
    SummaryResult {
        indices: selected,
        f_trajectory: traj,
        f_final,
        wall_seconds: t0.elapsed().as_secs_f64(),
        oracle_calls: calls,
        oracle_work: oracle.work_counter() - work0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::exhaustive_best;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn selects_cluster_exemplars() {
        let v = Matrix::from_rows(&[
            &[0.0, 10.0],
            &[0.2, 10.0],
            &[10.0, 0.0],
            &[10.0, 0.2],
            &[-10.0, -10.0],
            &[-10.0, -10.2],
        ]);
        let mut o = CpuOracle::new(v);
        let res = Greedy::default().run(&mut o, 3);
        assert_eq!(res.k(), 3);
        // one exemplar per cluster
        let clusters: Vec<usize> = res.indices.iter().map(|&i| i / 2).collect();
        let mut c = clusters.clone();
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), 3, "{:?}", res.indices);
    }

    #[test]
    fn trajectory_monotone_nondecreasing() {
        let mut rng = Rng::new(4);
        let v = Matrix::random_normal(60, 5, &mut rng);
        let mut o = CpuOracle::new(v);
        let res = Greedy { batch: 16 }.run(&mut o, 10);
        for w in res.f_trajectory.windows(2) {
            assert!(w[1] >= w[0] - 1e-5, "{:?}", res.f_trajectory);
        }
    }

    #[test]
    fn respects_guarantee_vs_exhaustive() {
        // greedy >= (1 - 1/e) * OPT on random tiny instances
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let v = Matrix::random_normal(10, 3, &mut rng);
            let mut o = CpuOracle::new(v.clone());
            let res = Greedy::default().run(&mut o, 3);
            let mut o2 = CpuOracle::new(v);
            let (_, opt) = exhaustive_best(&mut o2, 3);
            assert!(
                res.f_final >= (1.0 - (-1.0f32).exp()) * opt - 1e-5,
                "seed {seed}: greedy {} < 0.632 * opt {opt}",
                res.f_final
            );
        }
    }

    #[test]
    fn no_duplicate_selections() {
        let mut rng = Rng::new(6);
        let v = Matrix::random_normal(30, 4, &mut rng);
        let mut o = CpuOracle::new(v);
        let res = Greedy { batch: 7 }.run(&mut o, 12);
        let mut s = res.indices.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), res.indices.len());
    }

    #[test]
    fn k_larger_than_n_terminates() {
        let v = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let mut o = CpuOracle::new(v);
        let res = Greedy::default().run(&mut o, 10);
        assert!(res.k() <= 2);
    }

    #[test]
    fn batch_size_does_not_change_result() {
        let mut rng = Rng::new(8);
        let v = Matrix::random_normal(40, 4, &mut rng);
        let r1 = Greedy { batch: 5 }.run(&mut CpuOracle::new(v.clone()), 6);
        let r2 = Greedy { batch: 64 }.run(&mut CpuOracle::new(v), 6);
        assert_eq!(r1.indices, r2.indices);
    }
}
