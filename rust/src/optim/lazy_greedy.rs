//! Lazy Greedy (Minoux's accelerated greedy): keeps a max-heap of stale
//! upper bounds on marginal gains — submodularity guarantees gains only
//! shrink, so a recomputed top-of-heap that stays on top is the true
//! argmax. Recomputation is *batched* (`refresh_batch` stale heads per
//! oracle call) so the engine still sees multi-candidate launches.

use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::{fold_mindist, initial_mindist, Oracle};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

#[derive(PartialEq)]
struct Entry {
    gain: f32,
    idx: usize,
    round: usize, // selection round when `gain` was computed
}

impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

pub struct LazyGreedy {
    /// How many stale heap heads to re-evaluate per oracle call.
    pub refresh_batch: usize,
}

impl Default for LazyGreedy {
    fn default() -> Self {
        LazyGreedy { refresh_batch: 64 }
    }
}

impl Optimizer for LazyGreedy {
    fn name(&self) -> &'static str {
        "lazy_greedy"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let mut mindist = initial_mindist(oracle);
        let mut calls = 0usize;

        // round 0: gains of all singletons (one batched pass)
        let all: Vec<usize> = (0..n).collect();
        let mut heap = BinaryHeap::with_capacity(n);
        for chunk in all.chunks(1024) {
            let gains = oracle.gains(&mindist, chunk);
            calls += 1;
            for (&i, &g) in chunk.iter().zip(&gains) {
                heap.push(Entry { gain: g, idx: i, round: 0 });
            }
        }

        let mut selected = Vec::with_capacity(k);
        let mut traj = Vec::with_capacity(k);
        let mut round = 0usize;

        while selected.len() < k.min(n) {
            // Collect up to refresh_batch stale heads.
            let mut stale: Vec<Entry> = Vec::new();
            let winner = loop {
                match heap.pop() {
                    None => break None,
                    Some(e) if e.round == round => break Some(e),
                    Some(e) => {
                        stale.push(e);
                        if stale.len() >= self.refresh_batch.max(1) {
                            break None;
                        }
                    }
                }
            };
            if let Some(w) = winner {
                // fresh head beat everything below it — select
                if w.gain <= 0.0 && !selected.is_empty() {
                    break;
                }
                fold_mindist(&mut mindist, &oracle.dist_col(w.idx));
                selected.push(w.idx);
                traj.push(oracle.f_of_state(&mindist));
                round += 1;
                // stale entries (still candidates) go back untouched
                for e in stale {
                    heap.push(e);
                }
                continue;
            }
            if stale.is_empty() {
                break; // heap exhausted
            }
            // batched refresh of the stale heads
            let idxs: Vec<usize> = stale.iter().map(|e| e.idx).collect();
            let gains = oracle.gains(&mindist, &idxs);
            calls += 1;
            for (e, g) in idxs.into_iter().zip(gains) {
                heap.push(Entry { gain: g, idx: e, round });
            }
        }

        let f_final = traj.last().copied().unwrap_or(0.0);
        SummaryResult {
            indices: selected,
            f_trajectory: traj,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: calls,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn matches_plain_greedy_value() {
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let v = Matrix::random_normal(50, 4, &mut rng);
            let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 8);
            let l = LazyGreedy::default().run(&mut CpuOracle::new(v), 8);
            // identical selections (ties broken by index in both)
            assert!(
                (g.f_final - l.f_final).abs() < 1e-5,
                "seed {seed}: {} vs {}",
                g.f_final,
                l.f_final
            );
        }
    }

    #[test]
    fn does_less_work_than_plain_greedy() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(200, 6, &mut rng);
        let g = Greedy { batch: 1024 }.run(&mut CpuOracle::new(v.clone()), 15);
        let l = LazyGreedy { refresh_batch: 32 }.run(&mut CpuOracle::new(v), 15);
        assert!(
            l.oracle_work < g.oracle_work,
            "lazy {} >= greedy {}",
            l.oracle_work,
            g.oracle_work
        );
    }

    #[test]
    fn small_refresh_batch_still_correct() {
        let mut rng = Rng::new(2);
        let v = Matrix::random_normal(30, 3, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 5);
        let l = LazyGreedy { refresh_batch: 1 }.run(&mut CpuOracle::new(v), 5);
        assert!((g.f_final - l.f_final).abs() < 1e-5);
    }

    #[test]
    fn k_zero() {
        let v = Matrix::from_rows(&[&[1.0f32, 2.0]]);
        let res = LazyGreedy::default().run(&mut CpuOracle::new(v), 0);
        assert!(res.indices.is_empty());
        assert_eq!(res.f_final, 0.0);
    }
}
