//! Three Sieves (Buschjäger et al., 2020 — the paper's reference [5],
//! used in Fig. 3): a single-summary streaming optimizer with O(k)
//! memory. It certifies thresholds *statistically*: starting from the
//! largest ladder rung under the OPT upper bound k·m, the threshold is
//! lowered one rung whenever `t` consecutive items fail the gain test —
//! giving a (1 − ε)(1 − 1/e) guarantee with high confidence on
//! exchangeable streams.

use crate::optim::sieve_streaming::{ladder_index, singleton_value, SieveState};
use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::Oracle;
use std::time::Instant;

pub struct ThreeSieves {
    pub epsilon: f32,
    /// Confidence window: consecutive rejections before lowering the rung.
    pub t: usize,
}

impl Default for ThreeSieves {
    fn default() -> Self {
        ThreeSieves { epsilon: 0.1, t: 500 }
    }
}

impl ThreeSieves {
    /// Confidence window tuned for coordinator-scale sliding windows
    /// (hundreds to a few thousand cycles), where the streaming-scale
    /// default `t = 500` would almost never lower the threshold. The
    /// [`crate::optim::build_optimizer`] registry uses this variant.
    pub fn for_windows() -> Self {
        ThreeSieves { epsilon: 0.1, t: 50 }
    }
}

impl Optimizer for ThreeSieves {
    fn name(&self) -> &'static str {
        "three_sieves"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let vsq = oracle.vsq().to_vec();
        let eps = self.epsilon;
        let mut state = SieveState::new(&vsq);
        let mut traj = Vec::new();
        let mut m = 0f32;
        let mut rung: Option<i32> = None; // current ladder index
        let mut fails = 0usize;
        let mut calls = 0usize;

        for x in 0..n {
            if k == 0 || state.set.len() >= k {
                break;
            }
            let dcol = oracle.dist_col(x);
            calls += 1;
            let fx = singleton_value(&vsq, &dcol);
            if fx > m {
                m = fx;
                if state.set.is_empty() {
                    // re-anchor at the top rung under the OPT bound k·m
                    rung = Some(ladder_index(k as f32 * m, eps));
                    fails = 0;
                }
            }
            let Some(r) = rung else { continue };
            let v = (1.0 + eps).powi(r);
            let need = (v / 2.0 - state.fval) / (k - state.set.len()) as f32;
            let g = state.gain(&dcol);
            if g >= need && g > 0.0 {
                state.add(x, &dcol, g);
                traj.push(state.fval);
                fails = 0;
            } else {
                fails += 1;
                if fails >= self.t {
                    // statistically certain the rung is too high: lower it,
                    // but never below the current lower bound f(S) + m
                    let floor = ladder_index((state.fval + m).max(m * 1e-3), eps);
                    if r > floor {
                        rung = Some(r - 1);
                    }
                    fails = 0;
                }
            }
        }

        let f_final = state.fval;
        SummaryResult {
            indices: state.set,
            f_trajectory: traj,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: calls,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn finds_reasonable_summary() {
        let mut rng = Rng::new(40);
        let v = Matrix::random_normal(300, 4, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 5);
        // small t so the threshold anneals within the stream
        let ts = ThreeSieves { epsilon: 0.1, t: 20 }.run(&mut CpuOracle::new(v), 5);
        assert!(!ts.indices.is_empty());
        assert!(
            ts.f_final >= 0.4 * g.f_final,
            "three sieves {} vs greedy {}",
            ts.f_final,
            g.f_final
        );
    }

    #[test]
    fn memory_is_single_summary() {
        // structural: uses one SieveState; here we just check cardinality + dedup
        let mut rng = Rng::new(41);
        let v = Matrix::random_normal(100, 3, &mut rng);
        let ts = ThreeSieves { epsilon: 0.2, t: 10 }.run(&mut CpuOracle::new(v), 7);
        assert!(ts.indices.len() <= 7);
        let mut d = ts.indices.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), ts.indices.len());
    }

    #[test]
    fn huge_t_never_lowers_threshold() {
        // with t >> n the rung never drops; may select nothing beyond
        // items clearing the initial (aggressive) threshold
        let mut rng = Rng::new(42);
        let v = Matrix::random_normal(50, 3, &mut rng);
        let ts = ThreeSieves { epsilon: 0.1, t: 10_000 }.run(&mut CpuOracle::new(v), 5);
        assert!(ts.indices.len() <= 5);
    }

    #[test]
    fn trajectory_monotone() {
        let mut rng = Rng::new(43);
        let v = Matrix::random_normal(200, 4, &mut rng);
        let ts = ThreeSieves { epsilon: 0.1, t: 15 }.run(&mut CpuOracle::new(v), 8);
        for w in ts.f_trajectory.windows(2) {
            assert!(w[1] >= w[0] - 1e-6);
        }
    }
}
