//! SieveStreaming++ (Kazemi et al., ICML 2019): same ladder idea as
//! SieveStreaming but tracks the best lower bound LB = max_v f(S_v) and
//! prunes every rung below max(LB, m) — an O(k/ε) memory footprint
//! instead of O(k log k / ε) with the same (1/2 − ε) guarantee.

use crate::optim::sieve_streaming::{ladder_index, singleton_value, SieveState};
use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::Oracle;
use std::collections::BTreeMap;
use std::time::Instant;

pub struct SieveStreamingPp {
    pub epsilon: f32,
}

impl Default for SieveStreamingPp {
    fn default() -> Self {
        SieveStreamingPp { epsilon: 0.1 }
    }
}

impl Optimizer for SieveStreamingPp {
    fn name(&self) -> &'static str {
        "sieve_streaming_pp"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let vsq = oracle.vsq().to_vec();
        let eps = self.epsilon;
        let mut m = 0f32;
        let mut lb = 0f32;
        let mut sieves: BTreeMap<i32, SieveState> = BTreeMap::new();
        let mut calls = 0usize;
        let mut peak_sieves = 0usize;

        for x in 0..n {
            if k == 0 {
                break;
            }
            let dcol = oracle.dist_col(x);
            calls += 1;
            let fx = singleton_value(&vsq, &dcol);
            if fx > m {
                m = fx;
            }
            // active window: thresholds in [max(LB, m), 2km]
            let floor = lb.max(m);
            if floor > 0.0 {
                let lo = ladder_index(floor, eps);
                let hi = ladder_index(2.0 * k as f32 * m, eps);
                sieves.retain(|&i, _| i >= lo && i <= hi);
                for i in lo..=hi {
                    sieves.entry(i).or_insert_with(|| SieveState::new(&vsq));
                }
            }
            for (&i, sv) in sieves.iter_mut() {
                if sv.set.len() >= k {
                    continue;
                }
                let v = (1.0 + eps).powi(i);
                let need = (v / 2.0 - sv.fval) / (k - sv.set.len()) as f32;
                let g = sv.gain(&dcol);
                if g >= need && g > 0.0 {
                    sv.add(x, &dcol, g);
                    if sv.fval > lb {
                        lb = sv.fval;
                    }
                }
            }
            peak_sieves = peak_sieves.max(sieves.len());
        }

        let best = sieves
            .into_values()
            .max_by(|a, b| a.fval.partial_cmp(&b.fval).unwrap());
        let (indices, f_final, traj) = match best {
            Some(s) => (s.set, s.fval, s.traj),
            None => (vec![], 0.0, vec![]),
        };
        SummaryResult {
            f_trajectory: traj,
            indices,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: calls,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::optim::sieve_streaming::SieveStreaming;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn comparable_to_sieve_streaming() {
        for seed in 0..4 {
            let mut rng = Rng::new(seed + 20);
            let v = Matrix::random_normal(80, 4, &mut rng);
            let ss = SieveStreaming { epsilon: 0.1 }.run(&mut CpuOracle::new(v.clone()), 5);
            let pp = SieveStreamingPp { epsilon: 0.1 }.run(&mut CpuOracle::new(v), 5);
            assert!(
                pp.f_final >= 0.8 * ss.f_final,
                "seed {seed}: ++ {} vs ss {}",
                pp.f_final,
                ss.f_final
            );
        }
    }

    #[test]
    fn half_guarantee_vs_greedy() {
        let mut rng = Rng::new(30);
        let v = Matrix::random_normal(100, 5, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 6);
        let pp = SieveStreamingPp { epsilon: 0.05 }.run(&mut CpuOracle::new(v), 6);
        assert!(pp.f_final >= 0.45 * g.f_final, "{} vs {}", pp.f_final, g.f_final);
    }

    #[test]
    fn cardinality_respected() {
        let mut rng = Rng::new(31);
        let v = Matrix::random_normal(50, 3, &mut rng);
        let pp = SieveStreamingPp::default().run(&mut CpuOracle::new(v), 3);
        assert!(pp.indices.len() <= 3);
    }
}
