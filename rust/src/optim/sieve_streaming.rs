//! SieveStreaming (Badanidiyuru et al., KDD 2014) — the streaming
//! optimizer the paper cites [2]: one pass, O(k log k / ε) memory,
//! (1/2 − ε) guarantee.
//!
//! A ladder of thresholds v = (1+ε)^i brackets OPT; each rung keeps its
//! own summary ("sieve"). Per stream item the oracle computes the
//! distance column d²(V, x) **once**; every sieve's marginal gain is
//! then a cheap host-side reduction over its private `mindist` state —
//! the multi-set evaluation pattern (`S_multi` = all sieves) of paper
//! §4.1.

use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::Oracle;
use std::collections::BTreeMap;
use std::time::Instant;

/// One sieve: a summary bound to a threshold rung.
pub(crate) struct SieveState {
    pub set: Vec<usize>,
    pub mindist: Vec<f32>,
    pub fval: f32,
    /// f after each accepted element (same length as `set`) — the
    /// winning sieve's trajectory becomes the run's `f_trajectory`.
    pub traj: Vec<f32>,
}

impl SieveState {
    pub fn new(vsq: &[f32]) -> SieveState {
        SieveState { set: Vec::new(), mindist: vsq.to_vec(), fval: 0.0, traj: Vec::new() }
    }

    /// Δf(x | S) from the cached distance column.
    pub fn gain(&self, dcol: &[f32]) -> f32 {
        let mut acc = 0f64;
        for i in 0..dcol.len() {
            let r = self.mindist[i] - dcol[i];
            if r > 0.0 {
                acc += r as f64;
            }
        }
        (acc / dcol.len() as f64) as f32
    }

    /// Accept x: fold the column into the state.
    pub fn add(&mut self, x: usize, dcol: &[f32], gain: f32) {
        for i in 0..dcol.len() {
            if dcol[i] < self.mindist[i] {
                self.mindist[i] = dcol[i];
            }
        }
        self.set.push(x);
        self.fval += gain;
        self.traj.push(self.fval);
    }
}

/// Singleton value f({x}) from a distance column.
pub(crate) fn singleton_value(vsq: &[f32], dcol: &[f32]) -> f32 {
    let mut acc = 0f64;
    for i in 0..vsq.len() {
        let r = vsq[i] - dcol[i];
        if r > 0.0 {
            acc += r as f64;
        }
    }
    (acc / vsq.len() as f64) as f32
}

/// Geometric ladder index: smallest integer i with (1+ε)^i >= x.
pub(crate) fn ladder_index(x: f32, eps: f32) -> i32 {
    assert!(x > 0.0);
    (x.ln() / (1.0 + eps).ln()).ceil() as i32
}

pub struct SieveStreaming {
    pub epsilon: f32,
}

impl Default for SieveStreaming {
    fn default() -> Self {
        SieveStreaming { epsilon: 0.1 }
    }
}

impl Optimizer for SieveStreaming {
    fn name(&self) -> &'static str {
        "sieve_streaming"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let vsq = oracle.vsq().to_vec();
        let eps = self.epsilon;
        let mut m = 0f32; // max singleton value seen
        let mut sieves: BTreeMap<i32, SieveState> = BTreeMap::new();
        let mut calls = 0usize;

        for x in 0..n {
            if k == 0 {
                break;
            }
            let dcol = oracle.dist_col(x);
            calls += 1;
            let fx = singleton_value(&vsq, &dcol);
            if fx > m {
                m = fx;
                // instantiate rungs covering [m, 2km]; prune rungs < m
                let lo = ladder_index(m, eps);
                let hi = ladder_index(2.0 * k as f32 * m, eps);
                sieves.retain(|&i, _| i >= lo && i <= hi);
                for i in lo..=hi {
                    sieves.entry(i).or_insert_with(|| SieveState::new(&vsq));
                }
            }
            for (&i, sv) in sieves.iter_mut() {
                if sv.set.len() >= k {
                    continue;
                }
                let v = (1.0 + eps).powi(i);
                let need = (v / 2.0 - sv.fval) / (k - sv.set.len()) as f32;
                let g = sv.gain(&dcol);
                if g >= need && g > 0.0 {
                    sv.add(x, &dcol, g);
                }
            }
        }

        // best sieve wins; its per-accept trajectory is the run's
        let best = sieves
            .into_values()
            .max_by(|a, b| a.fval.partial_cmp(&b.fval).unwrap());
        let (indices, f_final, traj) = match best {
            Some(s) => (s.set, s.fval, s.traj),
            None => (vec![], 0.0, vec![]),
        };
        SummaryResult {
            f_trajectory: traj,
            indices,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: calls,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn ladder_index_brackets() {
        let eps = 0.1f32;
        for &x in &[0.01f32, 1.0, 3.7, 100.0] {
            let i = ladder_index(x, eps);
            let v = (1.0 + eps).powi(i);
            assert!(v >= x * 0.999, "{v} < {x}");
            assert!(v / (1.0 + eps) < x * 1.001);
        }
    }

    #[test]
    fn achieves_half_guarantee_vs_greedy() {
        // (1/2 - ε) of OPT; greedy ≈ OPT here, so require >= 0.45 * greedy
        for seed in 0..4 {
            let mut rng = Rng::new(seed);
            let v = Matrix::random_normal(80, 4, &mut rng);
            let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 5);
            let s = SieveStreaming { epsilon: 0.05 }.run(&mut CpuOracle::new(v), 5);
            assert!(
                s.f_final >= 0.45 * g.f_final,
                "seed {seed}: sieve {} vs greedy {}",
                s.f_final,
                g.f_final
            );
        }
    }

    #[test]
    fn respects_cardinality() {
        let mut rng = Rng::new(5);
        let v = Matrix::random_normal(60, 3, &mut rng);
        let s = SieveStreaming::default().run(&mut CpuOracle::new(v), 4);
        assert!(s.indices.len() <= 4);
        let mut d = s.indices.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), s.indices.len());
    }

    #[test]
    fn trajectory_tracks_winning_sieve_per_accept() {
        let mut rng = Rng::new(11);
        let v = Matrix::random_normal(70, 4, &mut rng);
        let s = SieveStreaming::default().run(&mut CpuOracle::new(v), 6);
        assert!(s.indices.len() > 1, "want a multi-accept run, got {:?}", s.indices);
        // one trajectory point per accepted element, monotone, ending
        // at the final value — not the old degenerate length-<=1 vector
        assert_eq!(s.f_trajectory.len(), s.indices.len());
        for w in s.f_trajectory.windows(2) {
            assert!(w[1] >= w[0] - 1e-5, "{:?}", s.f_trajectory);
        }
        assert_eq!(*s.f_trajectory.last().unwrap(), s.f_final);
    }

    #[test]
    fn k_zero_empty() {
        let mut rng = Rng::new(6);
        let v = Matrix::random_normal(10, 2, &mut rng);
        let s = SieveStreaming::default().run(&mut CpuOracle::new(v), 0);
        assert!(s.indices.is_empty());
    }

    #[test]
    fn sieve_state_gain_matches_function() {
        let mut rng = Rng::new(7);
        let v = Matrix::random_normal(30, 4, &mut rng);
        let mut o = CpuOracle::new(v.clone());
        let vsq = o.vsq().to_vec();
        let mut st = SieveState::new(&vsq);
        let d3 = o.dist_col(3);
        let g3 = st.gain(&d3);
        let f = crate::submodular::EbcFunction::new(v);
        assert!((g3 - f.eval(&[3])).abs() < 1e-5);
        st.add(3, &d3, g3);
        let d9 = o.dist_col(9);
        let g9 = st.gain(&d9);
        assert!((st.fval + g9 - f.eval(&[3, 9])).abs() < 1e-4);
    }
}
