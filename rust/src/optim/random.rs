//! Uniform-random selection baseline: the floor every real optimizer
//! must beat (used by the case-study ablations).

use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::{fold_mindist, initial_mindist, Oracle};
use crate::util::rng::Rng;
use std::time::Instant;

pub struct RandomSelection {
    pub seed: u64,
}

impl Default for RandomSelection {
    fn default() -> Self {
        RandomSelection { seed: 0xEBC }
    }
}

impl Optimizer for RandomSelection {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let mut rng = Rng::new(self.seed);
        let indices = rng.sample_indices(n, k.min(n));
        let mut mindist = initial_mindist(oracle);
        let mut traj = Vec::with_capacity(indices.len());
        for &j in &indices {
            fold_mindist(&mut mindist, &oracle.dist_col(j));
            traj.push(oracle.f_of_state(&mindist));
        }
        let f_final = traj.last().copied().unwrap_or(0.0);
        SummaryResult {
            indices,
            f_trajectory: traj,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: 0,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::submodular::CpuOracle;

    #[test]
    fn greedy_beats_random() {
        let mut rng = Rng::new(9);
        let v = Matrix::random_normal(100, 5, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 6);
        let r = RandomSelection { seed: 11 }.run(&mut CpuOracle::new(v), 6);
        assert!(g.f_final >= r.f_final, "greedy {} < random {}", g.f_final, r.f_final);
    }

    #[test]
    fn distinct_indices() {
        let mut rng = Rng::new(10);
        let v = Matrix::random_normal(20, 3, &mut rng);
        let r = RandomSelection::default().run(&mut CpuOracle::new(v), 8);
        let mut s = r.indices.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }
}
