//! Stochastic Greedy (Mirzasoleiman et al. 2015): each step evaluates a
//! random candidate sample of size ⌈(n/k) ln(1/ε)⌉ instead of all
//! remaining candidates, giving a (1 − 1/e − ε) guarantee in expectation
//! with a k-independent total work of O(n log 1/ε).

use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::{fold_mindist, initial_mindist, Oracle};
use crate::util::rng::Rng;
use std::time::Instant;

pub struct StochasticGreedy {
    pub epsilon: f32,
    pub seed: u64,
}

impl Default for StochasticGreedy {
    fn default() -> Self {
        StochasticGreedy { epsilon: 0.1, seed: 0xEBC }
    }
}

impl StochasticGreedy {
    fn sample_size(&self, n: usize, k: usize) -> usize {
        let r = (n as f64 / k.max(1) as f64 * (1.0 / self.epsilon as f64).ln()).ceil() as usize;
        r.clamp(1, n)
    }
}

impl Optimizer for StochasticGreedy {
    fn name(&self) -> &'static str {
        "stochastic_greedy"
    }

    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult {
        let t0 = Instant::now();
        let work0 = oracle.work_counter();
        let n = oracle.n();
        let mut rng = Rng::new(self.seed);
        let mut mindist = initial_mindist(oracle);
        let mut in_set = vec![false; n];
        let mut selected = Vec::with_capacity(k);
        let mut traj = Vec::with_capacity(k);
        let mut calls = 0usize;
        let r = self.sample_size(n, k);

        for _ in 0..k.min(n) {
            // sample r candidates from the remaining ones
            let remaining: Vec<usize> = (0..n).filter(|&i| !in_set[i]).collect();
            if remaining.is_empty() {
                break;
            }
            let m = r.min(remaining.len());
            let picked = rng.sample_indices(remaining.len(), m);
            let cands: Vec<usize> = picked.iter().map(|&p| remaining[p]).collect();
            let gains = oracle.gains(&mindist, &cands);
            calls += 1;
            let mut best = (cands[0], f32::NEG_INFINITY);
            for (&c, &g) in cands.iter().zip(&gains) {
                if g > best.1 {
                    best = (c, g);
                }
            }
            fold_mindist(&mut mindist, &oracle.dist_col(best.0));
            in_set[best.0] = true;
            selected.push(best.0);
            traj.push(oracle.f_of_state(&mindist));
        }

        let f_final = traj.last().copied().unwrap_or(0.0);
        SummaryResult {
            indices: selected,
            f_trajectory: traj,
            f_final,
            wall_seconds: t0.elapsed().as_secs_f64(),
            oracle_calls: calls,
            oracle_work: oracle.work_counter() - work0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::optim::greedy::Greedy;
    use crate::submodular::CpuOracle;

    #[test]
    fn close_to_greedy_value() {
        let mut rng = Rng::new(3);
        let v = Matrix::random_normal(120, 5, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 8);
        let s = StochasticGreedy { epsilon: 0.05, seed: 1 }
            .run(&mut CpuOracle::new(v), 8);
        assert_eq!(s.k(), 8);
        assert!(
            s.f_final >= 0.8 * g.f_final,
            "stochastic {} too far below greedy {}",
            s.f_final,
            g.f_final
        );
    }

    #[test]
    fn does_less_work_for_large_k() {
        let mut rng = Rng::new(4);
        let v = Matrix::random_normal(150, 4, &mut rng);
        let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), 20);
        let s = StochasticGreedy { epsilon: 0.2, seed: 2 }
            .run(&mut CpuOracle::new(v), 20);
        assert!(s.oracle_work < g.oracle_work);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(5);
        let v = Matrix::random_normal(40, 3, &mut rng);
        let a = StochasticGreedy { epsilon: 0.1, seed: 7 }
            .run(&mut CpuOracle::new(v.clone()), 5);
        let b = StochasticGreedy { epsilon: 0.1, seed: 7 }
            .run(&mut CpuOracle::new(v), 5);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn sample_size_formula() {
        let sg = StochasticGreedy { epsilon: 0.1, seed: 0 };
        assert_eq!(sg.sample_size(1000, 10), 231); // 100 * ln(10) ≈ 230.3
        assert_eq!(sg.sample_size(10, 100), 1);
        assert!(sg.sample_size(50, 1) <= 50);
    }
}
