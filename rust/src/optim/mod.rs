//! Submodular maximization under a cardinality constraint (paper §3,
//! problem 2): the Greedy family and the streaming sieve family.
//!
//! Every optimizer runs against a [`crate::submodular::Oracle`], so the
//! same code drives the CPU baselines and the accelerated engine — the
//! paper's point that optimizers issue *multi-set* evaluation patterns
//! (`S_multi`) which the accelerator batches.

pub mod greedy;
pub mod lazy_greedy;
pub mod random;
pub mod sieve_streaming;
pub mod sieve_streaming_pp;
pub mod stochastic_greedy;
pub mod three_sieves;

pub use greedy::{greedy_over_candidates, Greedy};
pub use lazy_greedy::LazyGreedy;
pub use random::RandomSelection;
pub use sieve_streaming::SieveStreaming;
pub use sieve_streaming_pp::SieveStreamingPp;
pub use stochastic_greedy::StochasticGreedy;
pub use three_sieves::ThreeSieves;

use crate::submodular::Oracle;

/// Outcome of an optimization run.
#[derive(Debug, Clone)]
pub struct SummaryResult {
    /// Selected ground-set indices, in selection order.
    pub indices: Vec<usize>,
    /// f(S) after each selection (same length as `indices`).
    pub f_trajectory: Vec<f32>,
    /// Final function value.
    pub f_final: f32,
    /// Wall-clock seconds of the whole run.
    pub wall_seconds: f64,
    /// Number of oracle gain/eval calls issued.
    pub oracle_calls: usize,
    /// Oracle-reported scalar-distance work.
    pub oracle_work: u64,
}

impl SummaryResult {
    pub fn k(&self) -> usize {
        self.indices.len()
    }
}

/// A cardinality-constrained submodular maximizer.
///
/// `Sync` is a supertrait so one optimizer instance can drive several
/// shards concurrently (`run` takes `&self`; every implementor is plain
/// data) — see [`crate::shard::ShardedSummarizer`].
pub trait Optimizer: Sync {
    fn name(&self) -> &'static str;
    /// Produce a summary of at most `k` elements.
    fn run(&self, oracle: &mut dyn Oracle, k: usize) -> SummaryResult;
}

/// Algorithm names accepted by [`build_optimizer`] (and therefore by
/// `summary.algorithm` in the config schema and the CLI flags).
pub const ALGORITHMS: &[&str] = &[
    "greedy",
    "lazy_greedy",
    "stochastic_greedy",
    "sieve_streaming",
    "sieve_streaming_pp",
    "three_sieves",
    "random",
];

/// Construct an optimizer by name — the single registry shared by the
/// coordinator, the shard subsystem, the CLI and the bench harness.
/// `batch` is the candidate-batch size for the batched-greedy family.
/// Returns `None` for unknown names.
pub fn build_optimizer(name: &str, batch: usize) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "greedy" => Box::new(Greedy { batch: batch.max(1) }),
        "lazy_greedy" => Box::new(LazyGreedy::default()),
        "stochastic_greedy" => Box::new(StochasticGreedy::default()),
        "sieve_streaming" => Box::new(SieveStreaming::default()),
        "sieve_streaming_pp" => Box::new(SieveStreamingPp::default()),
        "three_sieves" => Box::new(ThreeSieves::for_windows()),
        "random" => Box::new(RandomSelection::default()),
        _ => return None,
    })
}

/// Exhaustive search over all subsets of size <= k — the gold standard
/// for tiny instances, used by the property tests to verify the greedy
/// (1 − 1/e) guarantee.
pub fn exhaustive_best(oracle: &mut dyn Oracle, k: usize) -> (Vec<usize>, f32) {
    let n = oracle.n();
    assert!(n <= 20, "exhaustive search only for tiny instances");
    let mut best = (vec![], 0f32);
    // enumerate all subsets with <= k bits over n items
    for mask in 0u32..(1u32 << n) {
        if (mask.count_ones() as usize) > k {
            continue;
        }
        let set: Vec<usize> = (0..n).filter(|i| mask & (1 << i) != 0).collect();
        let v = oracle.eval_sets(&[&set])[0];
        if v > best.1 {
            best = (set, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::submodular::CpuOracle;
    use crate::util::rng::Rng;

    #[test]
    fn build_optimizer_registry_complete() {
        for name in ALGORITHMS {
            let o = build_optimizer(name, 64).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(o.name(), *name);
        }
        assert!(build_optimizer("magic", 64).is_none());
    }

    #[test]
    fn exhaustive_on_separated_clusters() {
        let v = Matrix::from_rows(&[
            &[0.0, 10.0],
            &[0.1, 10.0],
            &[10.0, 0.0],
            &[10.0, 0.1],
        ]);
        let mut o = CpuOracle::new(v);
        let (set, val) = exhaustive_best(&mut o, 2);
        assert_eq!(set.len(), 2);
        assert!(val > 0.0);
        // optimal 2-summary must take one point from each cluster
        let c0 = set.iter().filter(|&&i| i < 2).count();
        assert_eq!(c0, 1, "{set:?}");
    }

    #[test]
    fn exhaustive_monotone_in_k() {
        let mut rng = Rng::new(1);
        let v = Matrix::random_normal(8, 3, &mut rng);
        let mut o = CpuOracle::new(v);
        let (_, v1) = exhaustive_best(&mut o, 1);
        let (_, v2) = exhaustive_best(&mut o, 2);
        let (_, v3) = exhaustive_best(&mut o, 3);
        assert!(v2 >= v1 && v3 >= v2);
    }
}
