//! Analytical device performance model (DESIGN.md §S9).
//!
//! We do not have the paper's four testbeds (NVIDIA Quadro RTX 5000,
//! Jetson TX2, Intel Xeon W-2155, ARM Cortex-A72). This module predicts
//! their wall-clock for an EBC evaluation workload from a roofline-style
//! model — compute throughput vs. memory bandwidth vs. interconnect —
//! and regenerates the *shape* of the paper's Table 1 (who wins, by
//! roughly what factor, FP16 vs FP32, workstation vs embedded).

pub mod devices;
pub mod roofline;

pub use devices::{
    a72_mt, mt_variant, xeon_mt, DeviceClass, DeviceSpec, A72, QUADRO_RTX_5000, TX2, XEON_W2155,
};
pub use roofline::{predict_seconds, speedup, EbcWorkload, Precision as ModelPrecision};
