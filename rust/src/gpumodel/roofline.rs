//! Roofline prediction for the EBC multi-set evaluation workload.
//!
//! Workload model (paper §4): evaluating `l` sets of `k` exemplars
//! against `N` ground vectors of dimension `d` costs
//!
//! * FLOPs:   3 · N · l · k · d      (sub, mul, add per element)
//! * traffic: the ground tile is cached (shared memory / VMEM / L2), so
//!   DRAM traffic ≈ N·d + l·k·d reads + N·l write of the work matrix,
//!   in `bytes_per_elem`;
//! * link:    payload upload l·k·d (ground set resident per the paper);
//! * launch:  one kernel + one reduce launch.
//!
//! Predicted time = max(compute, memory) + link + launches — the
//! standard overlap-free roofline upper bound.

use super::devices::{DeviceClass, DeviceSpec};

/// Precision of the modeled run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Fp16,
}

impl Precision {
    pub fn bytes(&self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
        }
    }
}

/// An EBC multi-set evaluation problem instance (the paper's N, l, k, d).
#[derive(Debug, Clone, Copy)]
pub struct EbcWorkload {
    pub n: usize,
    pub l: usize,
    pub k: usize,
    pub d: usize,
}

impl EbcWorkload {
    pub fn flops(&self) -> f64 {
        3.0 * self.n as f64 * self.l as f64 * self.k as f64 * self.d as f64
    }

    /// DRAM traffic in elements (ground tile cached on-chip per block).
    pub fn dram_elems(&self) -> f64 {
        let ground = self.n as f64 * self.d as f64;
        let sets = self.l as f64 * self.k as f64 * self.d as f64;
        let work_matrix = self.n as f64 * self.l as f64;
        ground + sets + work_matrix
    }

    /// Per-call interconnect payload in elements (sets only; V resident).
    pub fn link_elems(&self) -> f64 {
        self.l as f64 * self.k as f64 * self.d as f64
    }
}

/// Predicted wall-clock seconds for one evaluation on `dev`.
pub fn predict_seconds(dev: &DeviceSpec, w: &EbcWorkload, p: Precision) -> f64 {
    let flops = w.flops();
    let gflops = dev.fp32_gflops
        * dev.efficiency
        * if p == Precision::Fp16 { dev.fp16_speedup } else { 1.0 };
    let t_compute = flops / (gflops * 1e9);

    let bytes = w.dram_elems() * p.bytes();
    let t_mem = bytes / (dev.mem_bw_gbs * 1e9);

    let t_link = match dev.class {
        DeviceClass::DiscreteGpu => w.link_elems() * p.bytes() / (dev.link_bw_gbs * 1e9),
        _ => 0.0,
    };

    let t_launch = 2.0 * dev.launch_overhead_us * 1e-6;

    t_compute.max(t_mem) + t_link + t_launch
}

/// Speedup of `fast` over `slow` on the same workload.
/// `p_fast`/`p_slow` may differ — the paper's FP16-GPU-vs-FP32-CPU cells.
pub fn speedup(
    fast: &DeviceSpec,
    p_fast: Precision,
    slow: &DeviceSpec,
    p_slow: Precision,
    w: &EbcWorkload,
) -> f64 {
    predict_seconds(slow, w, p_slow) / predict_seconds(fast, w, p_fast)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpumodel::devices::*;

    fn paper_base() -> EbcWorkload {
        // the paper's initial point: N=50000, l=5000, k=10, d=100
        EbcWorkload { n: 50_000, l: 5_000, k: 10, d: 100 }
    }

    #[test]
    fn quadro_vs_xeon_fp32_in_paper_band() {
        // paper Table 1: FP32 ST speedups 34x–72x
        let s = speedup(
            &QUADRO_RTX_5000,
            Precision::Fp32,
            &XEON_W2155,
            Precision::Fp32,
            &paper_base(),
        );
        assert!((20.0..150.0).contains(&s), "modeled {s}x outside plausibility band");
    }

    #[test]
    fn fp16_beats_fp32_on_gpu() {
        let w = paper_base();
        let f32t = predict_seconds(&QUADRO_RTX_5000, &w, Precision::Fp32);
        let f16t = predict_seconds(&QUADRO_RTX_5000, &w, Precision::Fp16);
        assert!(f16t < f32t);
    }

    #[test]
    fn tx2_vs_a72_smaller_than_quadro_vs_xeon() {
        // the paper's embedded speedups (<= ~35x) are far below the
        // workstation ones (<= ~450x)
        let w = paper_base();
        let emb = speedup(&TX2, Precision::Fp32, &A72, Precision::Fp32, &w);
        let wk = speedup(&QUADRO_RTX_5000, Precision::Fp16, &XEON_W2155, Precision::Fp32, &w);
        assert!(emb < wk);
        assert!(emb > 1.0, "TX2 must beat the A72 ({emb}x)");
    }

    #[test]
    fn tiny_workload_hurts_gpu() {
        // launch + PCIe overhead dominates small problems: speedup shrinks
        let tiny = EbcWorkload { n: 100, l: 2, k: 2, d: 10 };
        let big = paper_base();
        let s_tiny = speedup(&QUADRO_RTX_5000, Precision::Fp32, &XEON_W2155, Precision::Fp32, &tiny);
        let s_big = speedup(&QUADRO_RTX_5000, Precision::Fp32, &XEON_W2155, Precision::Fp32, &big);
        assert!(s_tiny < s_big);
    }

    #[test]
    fn mt_xeon_closes_gap() {
        // paper: MT CPU reduces the GPU advantage to 3.3x–5.1x (FP32)
        let w = paper_base();
        let s = speedup(&QUADRO_RTX_5000, Precision::Fp32, &xeon_mt(), Precision::Fp32, &w);
        let st = speedup(&QUADRO_RTX_5000, Precision::Fp32, &XEON_W2155, Precision::Fp32, &w);
        assert!(s < st);
        assert!((2.0..8.0).contains(&s), "{s}x outside the paper's MT band shape");
    }

    #[test]
    fn fp16_band_matches_paper_scale() {
        // paper Table 1 FP16 vs FP32-CPU (ST): mean ~ 250-400x at the base point
        let w = paper_base();
        let s = speedup(&QUADRO_RTX_5000, Precision::Fp16, &XEON_W2155, Precision::Fp32, &w);
        assert!((100.0..500.0).contains(&s), "{s}");
    }

    #[test]
    fn embedded_band_matches_paper_scale() {
        // paper: TX2 fp32 vs A72 ST = 4.3-6x
        let w = paper_base();
        let s = speedup(&TX2, Precision::Fp32, &A72, Precision::Fp32, &w);
        assert!((3.0..9.0).contains(&s), "{s}");
    }
}
