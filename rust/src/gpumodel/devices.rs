//! Public spec-sheet parameters of the paper's four devices.
//!
//! Sources: vendor datasheets (peak FLOP/s at base clocks, memory
//! bandwidth, PCIe generation). CPU effective FLOP/s are derated to a
//! realistic fraction of peak for a distance kernel (no FMA-perfect
//! code), matching commonly reported LINPACK-vs-stream behavior.

/// Device class — controls which overheads apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    Cpu,
    /// Discrete GPU behind PCIe (payload transfers cross the bus).
    DiscreteGpu,
    /// Integrated GPU sharing DRAM with the host (no PCIe hop).
    IntegratedGpu,
}

/// Roofline-style device description.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub class: DeviceClass,
    /// Sustained FP32 GFLOP/s for fused multiply-add dominated kernels.
    pub fp32_gflops: f64,
    /// FP16 (half / bf16) throughput multiplier over FP32 (tensor paths).
    pub fp16_speedup: f64,
    /// Sustained memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Host<->device interconnect bandwidth, GB/s (f64::INFINITY for CPUs
    /// and integrated GPUs — no copy needed).
    pub link_bw_gbs: f64,
    /// Fixed per-launch overhead, microseconds (kernel launch + driver).
    pub launch_overhead_us: f64,
    /// Fraction of peak the EBC kernel sustains (occupancy / efficiency).
    pub efficiency: f64,
}

/// NVIDIA Quadro RTX 5000: 11.2 TFLOPS FP32 peak, 448 GB/s GDDR6,
/// PCIe 3 x16. `efficiency` is calibrated so the FP32 kernel sustains
/// ~55% of peak (shared-memory tiling, near-full occupancy).
pub const QUADRO_RTX_5000: DeviceSpec = DeviceSpec {
    name: "Quadro RTX 5000",
    class: DeviceClass::DiscreteGpu,
    fp32_gflops: 11_200.0,
    // Turing tensor path: FP16 throughput is several x FP32 (TU104 dense
    // FP16 ≈ 6-8x FP32 for matmul-shaped inner loops). Calibrated to 6x
    // from the paper's own FP16-vs-FP32 Table 1 band.
    fp16_speedup: 6.0,
    mem_bw_gbs: 448.0,
    link_bw_gbs: 12.0, // PCIe 3.0 x16 effective
    launch_overhead_us: 8.0,
    efficiency: 0.55,
};

/// NVIDIA Jetson TX2 (Pascal, 256 CUDA cores): 0.665 TFLOPS FP32 peak,
/// 58.3 GB/s LPDDR4 shared with the CPU complex. The tiny GPU (1.33 MB
/// L2, few SMs) cannot hide the latency of the streamed evaluation-set
/// matrix, so the kernel is memory-latency bound — `efficiency` is
/// calibrated to the paper's measured TX2-vs-A72 band (4.3-6x FP32).
pub const TX2: DeviceSpec = DeviceSpec {
    name: "Jetson TX2",
    class: DeviceClass::IntegratedGpu,
    fp32_gflops: 665.0,
    fp16_speedup: 4.0, // fp16x2 path + halved traffic
    mem_bw_gbs: 58.3,
    link_bw_gbs: f64::INFINITY,
    launch_overhead_us: 15.0,
    efficiency: 0.05,
};

/// Intel Xeon W-2155 (10C/20T Skylake-W, AVX-512): single-core peak
/// ≈ 211 GFLOP/s FP32 (2 FMA ports x 16 lanes x 3.3 GHz); the OpenMP-SIMD
/// distance loop sustains ~43% of that.
pub const XEON_W2155: DeviceSpec = DeviceSpec {
    name: "Xeon W-2155",
    class: DeviceClass::Cpu,
    fp32_gflops: 90.0, // single-thread sustained (ST baseline)
    fp16_speedup: 1.0, // x86 has no fast scalar FP16 path
    mem_bw_gbs: 64.0,
    link_bw_gbs: f64::INFINITY,
    launch_overhead_us: 0.0,
    efficiency: 1.0, // derate folded into fp32_gflops
};

/// ARM Cortex-A72 @1.5GHz (Raspberry Pi 4): ~6 GFLOP/s single-thread
/// NEON sustained, ~4 GB/s LPDDR4 streaming per core.
pub const A72: DeviceSpec = DeviceSpec {
    name: "Cortex-A72",
    class: DeviceClass::Cpu,
    fp32_gflops: 6.0,
    fp16_speedup: 1.0,
    mem_bw_gbs: 4.0,
    link_bw_gbs: f64::INFINITY,
    launch_overhead_us: 0.0,
    efficiency: 1.0,
};

/// Multi-threaded variant of a CPU spec (the paper's MT baseline).
///
/// `scale` is the measured MT-over-ST throughput ratio, calibrated from
/// the paper's own Table 1 (ST speedup / MT speedup): ~14x for the Xeon
/// (10C/20T + all-core AVX-512) and ~2.3x for the Pi 4's A72 (4 cores,
/// bandwidth-capped). See [`xeon_mt`] / [`a72_mt`].
pub fn mt_variant(spec: &DeviceSpec, scale: f64) -> DeviceSpec {
    DeviceSpec { fp32_gflops: spec.fp32_gflops * scale, ..*spec }
}

/// The paper's MT Xeon baseline.
pub fn xeon_mt() -> DeviceSpec {
    mt_variant(&XEON_W2155, 14.0)
}

/// The paper's MT Cortex-A72 baseline.
pub fn a72_mt() -> DeviceSpec {
    mt_variant(&A72, 2.3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanity_ordering() {
        assert!(QUADRO_RTX_5000.fp32_gflops > TX2.fp32_gflops);
        assert!(TX2.fp32_gflops > XEON_W2155.fp32_gflops);
        assert!(XEON_W2155.fp32_gflops > A72.fp32_gflops);
    }

    #[test]
    fn mt_scales() {
        assert!((xeon_mt().fp32_gflops - 14.0 * XEON_W2155.fp32_gflops).abs() < 1e-9);
        assert!((a72_mt().fp32_gflops - 2.3 * A72.fp32_gflops).abs() < 1e-9);
    }
}
