//! The unified typed request/response façade — the **only** way work
//! enters the system.
//!
//! Every entrypoint (CLI subcommands, the coordinator's `@fleet` route,
//! benches, examples, and the future socket listener) describes a run
//! as one [`SummarizeRequest`] — dataset + k + optimizer + precision /
//! kernel knobs + optional [`ShardSpec`] — and receives one
//! [`SummarizeResponse`] — exemplars as ground ids, the f-trajectory,
//! stage timings and a [`Provenance`] record of what actually executed
//! (backend, plan, transport, wire traffic, retries). Failures are
//! typed [`ApiError`]s; no user-input path panics.
//!
//! ```text
//!   CLI flags ──┐
//!   config ─────┤→ SummarizeRequest ──→ api::Service ──→ SummarizeResponse
//!   coordinator ┤      (validate)        (execute)         (provenance)
//!   WireRequest ┘
//! ```
//!
//! The same request serializes to a byte-frozen
//! [`crate::shard::wire::WireRequest`] frame (golden-pinned in
//! `tests/wire_golden.rs`), so "what to run" survives the wire
//! unchanged — the socket leg in ROADMAP becomes a transport drop-in
//! rather than another round of bespoke plumbing. Because only registry
//! optimizers can be rebuilt remotely (the remote-rebuild contract on
//! [`crate::shard::wire::ShardJobMsg::optimizer`]),
//! [`SummarizeRequest::validate`] rejects non-registry optimizers
//! whenever the shard transport is not `inproc`.
//!
//! Quickstart:
//!
//! ```no_run
//! use ebc::api::{DatasetRef, Service, SummarizeRequest};
//!
//! let service = Service::cpu();
//! let req = SummarizeRequest::new(DatasetRef::synthetic(1000, 32, 42), 5)
//!     .optimizer("greedy");
//! let res = service.summarize(&req).expect("valid request");
//! println!("exemplars: {:?}  f(S) = {}", res.exemplars, res.f_final);
//! ```

pub mod error;
pub mod request;
pub mod response;
pub mod service;

pub use error::ApiError;
pub use request::{DatasetRef, OptimizerSel, ShardSpec, SummarizeRequest};
pub use response::{BaselineRun, Provenance, StageTimings, SummarizeResponse};
pub use service::{execute, ExecEnv, PlanBuild, Service, BACKENDS};
