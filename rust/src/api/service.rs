//! The façade's executor: one [`Service`] per evaluation backend, one
//! [`execute`] core shared by every entrypoint (CLI, coordinator,
//! benches, examples — and, via [`crate::shard::wire`] frames, the TCP
//! replica servers of [`crate::shard::net`]).

use crate::api::error::ApiError;
use crate::api::request::{OptimizerSel, SummarizeRequest};
use crate::api::response::{BaselineRun, Provenance, StageTimings, SummarizeResponse};
use crate::config::schema::ServiceConfig;
use crate::coordinator::{Coordinator, OracleFactory};
use crate::engine::{
    Engine, EngineConfig, OracleSpec, PlanRequest, PlanSource, Precision, ShardPlan, XlaOracle,
};
use crate::linalg::{CpuKernel, Matrix, SharedMatrix};
use crate::obs;
use crate::optim::{build_optimizer, Optimizer, ALGORITHMS};
use crate::runtime::Runtime;
use crate::shard::{
    build_partitioner, build_transport_with, ShardOracleFactory, ShardTransport,
    ShardedSummarizer, PARTITIONERS, TRANSPORTS,
};
use crate::submodular::{CpuOracle, Oracle};
use std::sync::{Arc, OnceLock};

/// Backend names accepted by [`Service::from_backend`] (and therefore
/// by every `--backend` CLI flag).
pub const BACKENDS: &[&str] = &["cpu", "xla"];

enum BackendKind {
    /// The CPU oracle (scalar or blocked Gram-matrix kernel).
    Cpu,
    /// The batched accelerator engine over PJRT, with CPU fallback.
    Xla(Runtime),
}

/// One evaluation backend, ready to execute [`SummarizeRequest`]s.
/// Collapses the per-subcommand factory/runtime wiring the launcher
/// used to rebuild by hand: construct once, summarize many times.
pub struct Service {
    backend: BackendKind,
}

impl Service {
    /// The CPU backend (no artifacts needed — benches, examples, tests).
    pub fn cpu() -> Service {
        Service { backend: BackendKind::Cpu }
    }

    /// Build by backend name (`cpu` | `xla`). The XLA variant discovers
    /// the PJRT runtime + artifact manifest up front, so a broken
    /// install fails here with a typed error instead of mid-run.
    pub fn from_backend(name: &str) -> Result<Service, ApiError> {
        match name {
            "cpu" => Ok(Service::cpu()),
            "xla" => {
                let rt = Runtime::discover()
                    .map_err(|e| ApiError::Backend { detail: format!("{e:#}") })?;
                Ok(Service { backend: BackendKind::Xla(rt) })
            }
            other => Err(ApiError::unknown("backend", other, BACKENDS)),
        }
    }

    /// This service's backend name.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            BackendKind::Cpu => "cpu",
            BackendKind::Xla(_) => "xla",
        }
    }

    /// The runtime handle of an XLA service (artifact inventory etc.).
    pub fn runtime(&self) -> Option<&Runtime> {
        match &self.backend {
            BackendKind::Cpu => None,
            BackendKind::Xla(rt) => Some(rt),
        }
    }

    /// Build the oracle-factory seam for the given knobs — the same
    /// closure shape the coordinator and the shard subsystem consume.
    pub fn oracle_factory(
        &self,
        precision: Precision,
        cpu_kernel: CpuKernel,
        threads: usize,
    ) -> OracleFactory {
        match &self.backend {
            BackendKind::Cpu => Box::new(move |m: SharedMatrix, spec: &OracleSpec| {
                // threads == 0 resolves to default_threads() downstream;
                // a planned spec overrides with its per-oracle split
                let t = spec.threads_or(threads);
                Box::new(CpuOracle::with_kernel_shared(m, cpu_kernel, precision, t))
                    as Box<dyn Oracle>
            }),
            BackendKind::Xla(rt) => {
                let engine = Engine::new(
                    rt.clone(),
                    EngineConfig {
                        precision,
                        cpu_fallback: true,
                        cpu_kernel,
                        cpu_threads: threads,
                        ..Default::default()
                    },
                );
                Box::new(move |m: SharedMatrix, spec: &OracleSpec| {
                    let mut engine = engine.clone();
                    if let Some(plan) = &spec.plan {
                        engine.set_plan(Arc::clone(plan));
                    }
                    if let Some(t) = spec.threads {
                        engine.set_cpu_threads(t);
                    }
                    Box::new(XlaOracle::from_shared(engine, m)) as Box<dyn Oracle>
                })
            }
        }
    }

    /// Plan-builder closure for this backend: the XLA variant pins
    /// engine buckets from its artifact manifest, the CPU one plans the
    /// worker × kernel-thread split only.
    fn plan_fn(
        &self,
        precision: Precision,
        cpu_kernel: CpuKernel,
    ) -> impl Fn(&PlanRequest) -> Arc<ShardPlan> + Send + Sync + 'static {
        let rt = match &self.backend {
            BackendKind::Cpu => None,
            BackendKind::Xla(rt) => Some(rt.clone()),
        };
        move |req: &PlanRequest| {
            let mut req = req.clone();
            req.precision = precision;
            req.cpu_kernel = cpu_kernel;
            Arc::new(ShardPlan::plan(rt.as_ref().map(|r| r.manifest()), &req))
        }
    }

    /// The boxed plan-builder seam ([`PlanSource`]) the coordinator
    /// caches fleet plans through.
    pub fn plan_source(&self, precision: Precision, cpu_kernel: CpuKernel) -> PlanSource {
        Box::new(self.plan_fn(precision, cpu_kernel))
    }

    /// Owned-matrix oracle factory for the case-study seam
    /// ([`crate::imm::casestudy::run_table2`]): the request supplies
    /// the precision / kernel / thread knobs.
    pub fn case_factory(
        &self,
        req: &SummarizeRequest,
    ) -> impl Fn(Matrix) -> Box<dyn Oracle> + 'static {
        let factory = self.oracle_factory(req.precision, req.cpu_kernel, req.threads);
        move |m: Matrix| factory(Arc::new(m), &OracleSpec::unplanned())
    }

    /// Validate and execute one request end to end.
    pub fn summarize(&self, req: &SummarizeRequest) -> Result<SummarizeResponse, ApiError> {
        req.validate()?;
        let data = req.dataset.materialize()?;
        let factory = self.oracle_factory(req.precision, req.cpu_kernel, req.threads);
        let f = |m: SharedMatrix, spec: &OracleSpec| factory(m, spec);
        let planner = self.plan_fn(req.precision, req.cpu_kernel);
        let env = ExecEnv {
            factory: &f,
            backend: self.backend_name(),
            plan: None,
            planner: Some(&planner),
            transport: None,
        };
        execute(req, &data, &env)
    }

    /// Wire a streaming [`Coordinator`] to this backend: oracle factory
    /// and fleet planner built from the `[engine]` config section, the
    /// shard transport from `[shard]` (inside `Coordinator::new`), and
    /// the process-wide observability layer from `[obs]`.
    pub fn coordinator(&self, cfg: ServiceConfig) -> Coordinator {
        obs::configure(&cfg.obs.obs_config());
        let factory =
            self.oracle_factory(cfg.engine.precision, cfg.engine.cpu_kernel, cfg.engine.cpu_threads);
        let planner = self.plan_source(cfg.engine.precision, cfg.engine.cpu_kernel);
        Coordinator::new(cfg, factory)
            .with_planner(planner)
            .with_backend_label(self.backend_name())
    }
}

/// Plan-builder seam [`execute`] consults for planned runs the
/// environment has not already planned.
pub type PlanBuild = dyn Fn(&PlanRequest) -> Arc<ShardPlan>;

/// Execution environment: what varies between the [`Service`] path
/// (owned factory, fresh transport) and the coordinator path (its
/// long-lived factory, cached plan, persistent replica transport).
pub struct ExecEnv<'a> {
    /// Oracle constructor seam.
    pub factory: &'a ShardOracleFactory,
    /// Backend label for [`Provenance`].
    pub backend: &'a str,
    /// Pre-resolved plan (the coordinator's per-shape cache); `None`
    /// lets [`execute`] build one when the request asks for planning.
    pub plan: Option<Arc<ShardPlan>>,
    /// Plan builder for unresolved planned runs; `None` falls back to a
    /// manifest-less CPU-split plan.
    pub planner: Option<&'a PlanBuild>,
    /// Persistent transport override; `None` builds one from the
    /// request's [`crate::api::ShardSpec`] (`inproc` stays the
    /// summarizer's run-local default).
    pub transport: Option<&'a dyn ShardTransport>,
}

fn requests_total() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| {
        obs::counter(obs::REQUESTS_TOTAL, "summarize requests executed through api::execute")
    })
}

/// The façade's execution core: validate, then run `req` over `data`
/// in `env`. Single entry for both the single-node and the sharded
/// pipeline — every response carries full [`Provenance`].
///
/// Opens an `api.execute` span — a root when called directly, a child
/// when a caller (e.g. a fleet query) already holds one — and, when
/// the request's `trace` knob is set, attaches the completed span tree
/// to the response provenance.
pub fn execute(
    req: &SummarizeRequest,
    data: &SharedMatrix,
    env: &ExecEnv,
) -> Result<SummarizeResponse, ApiError> {
    requests_total().inc();
    let span = if obs::current_span() == 0 {
        obs::root_span("api.execute")
    } else {
        obs::span("api.execute")
    };
    let span_id = span.id();
    let result = execute_inner(req, data, env);
    drop(span); // record before extracting: the tree is whole only now
    match result {
        Ok(mut resp) => {
            if req.trace && span_id != 0 {
                resp.provenance.trace = Some(obs::global().recorder.trace(span_id));
            }
            Ok(resp)
        }
        err => err,
    }
}

fn execute_inner(
    req: &SummarizeRequest,
    data: &SharedMatrix,
    env: &ExecEnv,
) -> Result<SummarizeResponse, ApiError> {
    req.validate()?;
    let n = data.rows();
    if n == 0 || data.cols() == 0 {
        return Err(ApiError::invalid(
            "dataset",
            format!("materialized matrix is degenerate ({n}x{})", data.cols()),
        ));
    }
    if req.k > n {
        return Err(ApiError::invalid(
            "k",
            format!("k = {} exceeds the ground-set size n = {n}", req.k),
        ));
    }
    let built;
    let optimizer: &dyn Optimizer = match &req.optimizer {
        OptimizerSel::Registry(name) => {
            built = build_optimizer(name, req.batch.max(1))
                .ok_or_else(|| ApiError::unknown("optimizer", name, ALGORITHMS))?;
            built.as_ref()
        }
        OptimizerSel::Custom(o) => o.as_ref(),
    };

    let Some(spec) = &req.shard else {
        // ---------------- single-node path ----------------
        let mut oracle = (env.factory)(Arc::clone(data), &OracleSpec::unplanned());
        let res = optimizer.run(oracle.as_mut(), req.k);
        return Ok(SummarizeResponse {
            exemplars: res.indices.iter().map(|&i| i as u64).collect(),
            f_trajectory: res.f_trajectory,
            f_final: res.f_final,
            oracle_calls: res.oracle_calls as u64,
            oracle_work: res.oracle_work,
            timings: StageTimings { wall_seconds: res.wall_seconds, ..Default::default() },
            provenance: Provenance {
                backend: env.backend.to_string(),
                optimizer: optimizer.name().to_string(),
                precision: req.precision,
                cpu_kernel: req.cpu_kernel,
                partitioner: None,
                plan: None,
                plan_split: None,
                transport: None,
                wire_bytes: 0,
                shard_retries: 0,
                shards_used: 0,
                peak_jobs_held: 0,
                degraded: false,
                pruned_n: 0,
                prune_seconds: 0.0,
                merge_depth: 0,
                merge_optimizer: String::new(),
                trace: None,
            },
            baseline: None,
        });
    };

    // ------------------- sharded path -------------------
    let partitioner = build_partitioner(&spec.partitioner, req.seed)
        .ok_or_else(|| ApiError::unknown("shard.partitioner", &spec.partitioner, PARTITIONERS))?;
    let owned_transport: Option<Box<dyn ShardTransport>> =
        match (env.transport.is_some(), spec.transport.as_str()) {
            // a persistent transport (coordinator) always wins; the
            // summarizer's run-local inproc default needs no handle
            (true, _) | (false, "inproc") => None,
            (false, name) => Some(
                build_transport_with(name, spec.replicas.max(1), &spec.net)
                    .ok_or_else(|| ApiError::unknown("shard.transport", name, TRANSPORTS))?,
            ),
        };
    let transport: Option<&dyn ShardTransport> = env.transport.or(owned_transport.as_deref());
    let plan: Option<Arc<ShardPlan>> = match (&env.plan, spec.plan) {
        (Some(p), _) => Some(Arc::clone(p)),
        (None, true) => {
            let mut preq = PlanRequest::new(n, data.cols(), spec.partitions, req.k);
            preq.batch = req.batch;
            preq.precision = req.precision;
            preq.cpu_kernel = req.cpu_kernel;
            preq.cores = spec.cores;
            preq.prune_rate = spec.prune;
            preq.max_merge_n = spec.max_merge_n;
            Some(match env.planner {
                Some(build) => build(&preq),
                None => Arc::new(ShardPlan::plan(None, &preq)),
            })
        }
        (None, false) => None,
    };

    let mut sharded = ShardedSummarizer::from_request(req, partitioner.as_ref(), optimizer);
    sharded.plan = plan.clone();
    sharded.transport = transport;
    // a non-greedy merge optimizer is rebuilt from the registry at the
    // request's batch width (validate() vouched for the id)
    let merge_built: Option<Box<dyn Optimizer>> = (spec.merge_optimizer != "greedy")
        .then(|| {
            build_optimizer(&spec.merge_optimizer, req.batch.max(1)).ok_or_else(|| {
                ApiError::unknown("shard.merge_optimizer", &spec.merge_optimizer, ALGORITHMS)
            })
        })
        .transpose()?;
    sharded.merge_optimizer = merge_built.as_deref();
    let res = if req.with_baseline {
        sharded.summarize_with_baseline(data, env.factory, req.k)
    } else {
        sharded.summarize(data, env.factory, req.k)
    };

    let stage1_calls: u64 = res.per_shard.iter().map(|s| s.result.oracle_calls as u64).sum();
    let stage1_work: u64 = res.per_shard.iter().map(|s| s.result.oracle_work).sum();
    Ok(SummarizeResponse {
        exemplars: res.merged.indices.iter().map(|&i| i as u64).collect(),
        f_trajectory: res.merged.f_trajectory.clone(),
        f_final: res.merged.f_final,
        oracle_calls: res.merged.oracle_calls as u64 + stage1_calls,
        oracle_work: res.merged.oracle_work + stage1_work,
        timings: StageTimings {
            partition_seconds: res.partition_seconds,
            shard_seconds: res.shard_seconds,
            merge_seconds: res.merge_seconds,
            wall_seconds: res.total_seconds(),
        },
        provenance: Provenance {
            backend: env.backend.to_string(),
            optimizer: optimizer.name().to_string(),
            precision: req.precision,
            cpu_kernel: req.cpu_kernel,
            partitioner: Some(res.partitioner),
            plan: plan.as_ref().map(|p| p.describe()),
            plan_split: plan.as_ref().map(|p| p.split_label()),
            transport: Some(res.transport),
            wire_bytes: res.wire_bytes,
            shard_retries: res.shard_retries,
            shards_used: res.shards_used,
            peak_jobs_held: res.peak_jobs_held,
            degraded: res.degraded,
            pruned_n: res.pruned_n,
            prune_seconds: res.prune_seconds,
            merge_depth: res.merge_depth,
            merge_optimizer: spec.merge_optimizer.clone(),
            trace: None,
        },
        baseline: res.baseline.map(|b| BaselineRun {
            exemplars: b.indices.iter().map(|&i| i as u64).collect(),
            f_final: b.f_final,
            wall_seconds: b.wall_seconds,
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::request::{DatasetRef, ShardSpec};
    use crate::optim::Greedy;
    use crate::util::rng::Rng;

    fn inline(n: usize, d: usize, seed: u64) -> (SharedMatrix, DatasetRef) {
        let mut rng = Rng::new(seed);
        let m: SharedMatrix = Arc::new(Matrix::random_normal(n, d, &mut rng));
        (Arc::clone(&m), DatasetRef::Inline(m))
    }

    #[test]
    fn single_node_matches_direct_greedy_bit_for_bit() {
        let (m, ds) = inline(50, 5, 3);
        let service = Service::cpu();
        let res = service
            .summarize(&SummarizeRequest::new(ds, 6).cpu_kernel(CpuKernel::Scalar).threads(1))
            .unwrap();
        let direct = Greedy { batch: 1024 }.run(
            &mut CpuOracle::with_kernel_shared(m, CpuKernel::Scalar, Precision::F32, 1),
            6,
        );
        let want: Vec<u64> = direct.indices.iter().map(|&i| i as u64).collect();
        assert_eq!(res.exemplars, want);
        assert_eq!(res.f_final.to_bits(), direct.f_final.to_bits());
        assert_eq!(res.provenance.backend, "cpu");
        assert!(res.provenance.transport.is_none());
        assert_eq!(res.provenance.wire_bytes, 0);
        assert!(res.baseline.is_none());
    }

    #[test]
    fn simd_kernel_request_matches_blocked_selection() {
        // the simd backend shares the blocked kernel's numerical
        // contract bit-for-bit, so the whole greedy trajectory —
        // exemplars and objective — must coincide
        let (_, ds) = inline(48, 7, 11);
        let service = Service::cpu();
        let simd = service
            .summarize(&SummarizeRequest::new(ds.clone(), 5).cpu_kernel(CpuKernel::Simd))
            .unwrap();
        let blocked = service
            .summarize(&SummarizeRequest::new(ds, 5).cpu_kernel(CpuKernel::Blocked))
            .unwrap();
        assert_eq!(simd.exemplars, blocked.exemplars);
        assert_eq!(simd.f_final.to_bits(), blocked.f_final.to_bits());
    }

    #[test]
    fn sharded_response_carries_full_provenance() {
        let (_, ds) = inline(60, 4, 7);
        let service = Service::cpu();
        let req = SummarizeRequest::new(ds, 5)
            .with_baseline(true)
            .sharded(ShardSpec::new(3).transport("loopback").replicas(2).plan(true).cores(4));
        let res = service.summarize(&req).unwrap();
        assert_eq!(res.k(), 5);
        let p = &res.provenance;
        assert_eq!(p.transport, Some("loopback"));
        assert_eq!(p.partitioner, Some("round_robin"));
        assert_eq!(p.shards_used, 3);
        assert!(p.wire_bytes > 0);
        assert_eq!(p.shard_retries, 0);
        assert!(!p.degraded, "healthy loopback fleet reported degraded");
        assert!(p.plan.as_deref().unwrap().contains("P=3"));
        assert!(p.plan_split.is_some());
        assert!(p.peak_jobs_held >= 1);
        assert!(res.baseline.is_some());
        let q = res.quality_ratio().unwrap();
        assert!(q > 0.5 && q <= 1.0 + 1e-6, "quality {q}");
        assert!(res.timings.wall_seconds > 0.0);
    }

    #[test]
    fn invalid_requests_never_reach_execution() {
        let (_, ds) = inline(10, 3, 1);
        let service = Service::cpu();
        let err = service
            .summarize(&SummarizeRequest::new(ds, 11))
            .unwrap_err();
        assert!(matches!(err, ApiError::Invalid { field: "k", .. }));
        assert!(matches!(
            Service::from_backend("quantum"),
            Err(ApiError::UnknownName { field: "backend", .. })
        ));
    }

    #[test]
    fn imm_dataset_k_overflow_is_checked_after_generation() {
        use crate::imm::{Part, ProcessState};
        let service = Service::cpu();
        // 1000 cycles per campaign; k beyond that must be a typed error
        let req = SummarizeRequest::new(
            DatasetRef::imm(Part::Cover, ProcessState::Stable, 8, 5),
            100_000,
        );
        assert!(req.validate().is_ok(), "size unknowable before generation");
        assert!(matches!(
            service.summarize(&req),
            Err(ApiError::Invalid { field: "k", .. })
        ));
    }
}
