//! Typed failure modes of the api façade.

use crate::shard::wire::WireError;
use std::fmt;

/// Why a request could not be validated or executed. Every variant is
/// reachable from user input — `.expect()`/panics are reserved for
/// internal invariants, never for request content.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// A field failed a structural check (k = 0, k > n, empty dataset,
    /// zero batch, ...).
    Invalid { field: &'static str, detail: String },
    /// A name field did not resolve against its registry (optimizer /
    /// partitioner / transport / backend).
    UnknownName { field: &'static str, name: String, expected: Vec<String> },
    /// A non-registry (custom live instance) optimizer was combined
    /// with a transport that cannot rebuild it remotely — the
    /// remote-rebuild contract on
    /// [`crate::shard::wire::ShardJobMsg::optimizer`].
    NonRegistryOptimizer { transport: String },
    /// The evaluation backend failed (runtime discovery, oracle build).
    Backend { detail: String },
    /// The shard transport failed irrecoverably.
    Transport { detail: String },
    /// A wire frame failed to encode/decode.
    Wire(WireError),
}

impl ApiError {
    /// Helper for registry misses: captures the expected name set.
    pub fn unknown(field: &'static str, name: &str, expected: &[&str]) -> ApiError {
        ApiError::UnknownName {
            field,
            name: name.to_string(),
            expected: expected.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Helper for structural failures.
    pub fn invalid(field: &'static str, detail: impl Into<String>) -> ApiError {
        ApiError::Invalid { field, detail: detail.into() }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::Invalid { field, detail } => write!(f, "invalid request: {field}: {detail}"),
            ApiError::UnknownName { field, name, expected } => {
                write!(f, "unknown {field} '{name}' (expected one of {expected:?})")
            }
            ApiError::NonRegistryOptimizer { transport } => write!(
                f,
                "non-registry optimizer cannot run over transport '{transport}': only \
                 registry optimizers reproduce local selection remotely (use 'inproc' or a \
                 registry optimizer id)"
            ),
            ApiError::Backend { detail } => write!(f, "backend error: {detail}"),
            ApiError::Transport { detail } => write!(f, "transport error: {detail}"),
            ApiError::Wire(e) => write!(f, "wire error: {e}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<WireError> for ApiError {
    fn from(e: WireError) -> ApiError {
        ApiError::Wire(e)
    }
}
