//! The typed, validated "what to run" description.

use crate::api::error::ApiError;
use crate::engine::Precision;
use crate::imm::{generate_dataset_with, Part, ProcessState};
use crate::linalg::{CpuKernel, Matrix, SharedMatrix};
use crate::optim::{Optimizer, ALGORITHMS};
use crate::shard::wire::{WireDataset, WireRequest, WireShardSpec};
use crate::shard::{NetOptions, PARTITIONERS, TRANSPORTS};
use crate::util::rng::Rng;
use std::fmt;
use std::sync::Arc;

/// What to summarize: an inline matrix or a generatable reference.
/// References keep request frames small — the executor materializes
/// them deterministically from the embedded seed.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetRef {
    /// The ground matrix itself (shared, so requests built from live
    /// data alias the caller's allocation).
    Inline(SharedMatrix),
    /// A standard-normal synthetic matrix (the `summarize` demo shape).
    Synthetic { n: usize, d: usize, seed: u64 },
    /// A generated injection-molding campaign (the case-study/bench
    /// substrate): one dataset of `samples`-dimensional cycle rows.
    Imm { part: Part, state: ProcessState, samples: usize, seed: u64 },
}

impl DatasetRef {
    /// Inline matrix from a shared handle.
    pub fn inline(m: SharedMatrix) -> DatasetRef {
        DatasetRef::Inline(m)
    }

    /// Standard-normal synthetic matrix reference.
    pub fn synthetic(n: usize, d: usize, seed: u64) -> DatasetRef {
        DatasetRef::Synthetic { n, d, seed }
    }

    /// Injection-molding campaign reference.
    pub fn imm(part: Part, state: ProcessState, samples: usize, seed: u64) -> DatasetRef {
        DatasetRef::Imm { part, state, samples, seed }
    }

    /// Ground-set size, when it is knowable without materializing
    /// (IMM campaigns derive their row count during generation).
    pub fn rows_hint(&self) -> Option<usize> {
        match self {
            DatasetRef::Inline(m) => Some(m.rows()),
            DatasetRef::Synthetic { n, .. } => Some(*n),
            DatasetRef::Imm { .. } => None,
        }
    }

    /// Produce the ground matrix. Inline datasets alias the caller's
    /// allocation; references generate deterministically.
    pub fn materialize(&self) -> Result<SharedMatrix, ApiError> {
        match self {
            DatasetRef::Inline(m) => Ok(Arc::clone(m)),
            DatasetRef::Synthetic { n, d, seed } => {
                let mut rng = Rng::new(*seed);
                Ok(Arc::new(Matrix::random_normal(*n, *d, &mut rng)))
            }
            DatasetRef::Imm { part, state, samples, seed } => {
                Ok(Arc::new(generate_dataset_with(*part, *state, *seed, *samples).cycles))
            }
        }
    }
}

/// Which optimizer runs: a registry id (serializable, remotely
/// rebuildable) or a custom live instance (local transports only — see
/// [`SummarizeRequest::validate`]).
#[derive(Clone)]
pub enum OptimizerSel {
    /// One of [`crate::optim::ALGORITHMS`], built at the request's
    /// batch width via [`crate::optim::build_optimizer`].
    Registry(String),
    /// A caller-owned live instance (e.g. a custom
    /// `SieveStreaming { epsilon }`). Cannot cross the wire.
    Custom(Arc<dyn Optimizer>),
}

impl fmt::Debug for OptimizerSel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptimizerSel::Registry(name) => write!(f, "Registry({name:?})"),
            OptimizerSel::Custom(o) => write!(f, "Custom({})", o.name()),
        }
    }
}

impl PartialEq for OptimizerSel {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (OptimizerSel::Registry(a), OptimizerSel::Registry(b)) => a == b,
            (OptimizerSel::Custom(a), OptimizerSel::Custom(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Sharded (two-stage) execution configuration — request-side mirror of
/// the `[shard]` config section.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    /// Shard count P (≥ 1).
    pub partitions: usize,
    /// Partition strategy: one of [`crate::shard::PARTITIONERS`].
    pub partitioner: String,
    /// Exemplars each shard contributes in stage 1 (0 = final k).
    pub per_shard_k: usize,
    /// Stage-1 worker threads (0 = auto; a plan's split wins).
    pub threads: usize,
    /// Stage-1 transport: one of [`crate::shard::TRANSPORTS`].
    pub transport: String,
    /// Replica count for replica transports.
    pub replicas: usize,
    /// Pre-plan the run (shared bucket shape + P·T ≤ cores split).
    pub plan: bool,
    /// Core budget for planned runs (0 = auto).
    pub cores: usize,
    /// Network knobs for the `tcp` transport: replica endpoints,
    /// deadlines, retry budget, chaos seed. Local-only — the knobs
    /// never cross the wire (a remote executor fans out with its own
    /// fleet configuration), so the v2 request frame stays frozen.
    pub net: NetOptions,
    /// Fraction of each shard's ground sieved away before stage 1
    /// (see [`crate::prune`]); 0 = off. Local-only — the coordinator
    /// prunes before jobs are built, so nothing prune-related ever
    /// crosses the frozen v2 wire.
    pub prune: f64,
    /// Merge-tree fanout (children per merge node); 0 = single root.
    /// Local-only, same as `prune`.
    pub fanout: usize,
    /// Ground-row cap per merge node; 0 = unlimited. Local-only.
    pub max_merge_n: usize,
    /// Registry optimizer for the merge stage(s); `"greedy"` keeps the
    /// exact candidate-greedy merge. Local-only.
    pub merge_optimizer: String,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            partitions: 2,
            partitioner: "round_robin".into(),
            per_shard_k: 0,
            threads: 0,
            transport: "inproc".into(),
            replicas: 2,
            plan: false,
            cores: 0,
            net: NetOptions::default(),
            prune: 0.0,
            fanout: 0,
            max_merge_n: 0,
            merge_optimizer: "greedy".into(),
        }
    }
}

impl ShardSpec {
    /// `partitions` shards, everything else at defaults.
    pub fn new(partitions: usize) -> ShardSpec {
        ShardSpec { partitions, ..ShardSpec::default() }
    }

    pub fn partitioner(mut self, name: &str) -> ShardSpec {
        self.partitioner = name.to_string();
        self
    }

    pub fn per_shard_k(mut self, k: usize) -> ShardSpec {
        self.per_shard_k = k;
        self
    }

    pub fn threads(mut self, threads: usize) -> ShardSpec {
        self.threads = threads;
        self
    }

    pub fn transport(mut self, name: &str) -> ShardSpec {
        self.transport = name.to_string();
        self
    }

    pub fn replicas(mut self, n: usize) -> ShardSpec {
        self.replicas = n;
        self
    }

    pub fn plan(mut self, plan: bool) -> ShardSpec {
        self.plan = plan;
        self
    }

    pub fn cores(mut self, cores: usize) -> ShardSpec {
        self.cores = cores;
        self
    }

    /// Network knobs for the `tcp` transport (endpoints, deadlines,
    /// retry budget, chaos seed).
    pub fn net(mut self, net: NetOptions) -> ShardSpec {
        self.net = net;
        self
    }

    /// Sieve away this fraction of each shard's ground before stage 1.
    pub fn prune(mut self, rate: f64) -> ShardSpec {
        self.prune = rate;
        self
    }

    /// Merge-tree fanout (0 = single root).
    pub fn fanout(mut self, fanout: usize) -> ShardSpec {
        self.fanout = fanout;
        self
    }

    /// Cap the ground rows any merge node scores (0 = unlimited).
    pub fn max_merge_n(mut self, n: usize) -> ShardSpec {
        self.max_merge_n = n;
        self
    }

    /// Registry optimizer for the merge stage(s).
    pub fn merge_optimizer(mut self, name: &str) -> ShardSpec {
        self.merge_optimizer = name.to_string();
        self
    }
}

/// One summarization work order — the single typed description every
/// entrypoint produces and every executor consumes. Build with the
/// chainable setters, then hand to [`crate::api::Service::summarize`]
/// (which validates first) or check explicitly with [`Self::validate`].
#[derive(Debug, Clone, PartialEq)]
pub struct SummarizeRequest {
    /// What to summarize.
    pub dataset: DatasetRef,
    /// Summary cardinality (1 ≤ k ≤ n).
    pub k: usize,
    /// Which optimizer runs.
    pub optimizer: OptimizerSel,
    /// Candidate-batch width for the batched-greedy family (≥ 1).
    pub batch: usize,
    /// Oracle compute precision (the paper's FP32/FP16 axis).
    pub precision: Precision,
    /// CPU kernel backend for CPU/fallback oracles.
    pub cpu_kernel: CpuKernel,
    /// Oracle kernel threads (0 = auto; a plan's split wins).
    pub threads: usize,
    /// Sharded two-stage execution; `None` = single-node.
    pub shard: Option<ShardSpec>,
    /// Seed for partitioners (hash mixing / locality projection).
    pub seed: u64,
    /// Also run a single-node reference pass of the same optimizer for
    /// quality/speedup accounting (sharded runs only).
    pub with_baseline: bool,
    /// Attach the request's span tree to the response provenance
    /// (see [`crate::obs`]). Local-only: the flag never crosses the
    /// wire — remote executors keep their own flight recorders, and
    /// the v2 request frame layout stays frozen.
    pub trace: bool,
}

impl SummarizeRequest {
    /// A greedy f32 single-node request over `dataset` at budget `k`.
    pub fn new(dataset: DatasetRef, k: usize) -> SummarizeRequest {
        SummarizeRequest {
            dataset,
            k,
            optimizer: OptimizerSel::Registry("greedy".into()),
            batch: 1024,
            precision: Precision::F32,
            cpu_kernel: CpuKernel::Blocked,
            threads: 0,
            shard: None,
            seed: 0xEBC,
            with_baseline: false,
            trace: false,
        }
    }

    /// Select a registry optimizer by id.
    pub fn optimizer(mut self, name: &str) -> SummarizeRequest {
        self.optimizer = OptimizerSel::Registry(name.to_string());
        self
    }

    /// Run a caller-owned optimizer instance (local transports only).
    pub fn custom_optimizer(mut self, optimizer: Arc<dyn Optimizer>) -> SummarizeRequest {
        self.optimizer = OptimizerSel::Custom(optimizer);
        self
    }

    pub fn batch(mut self, batch: usize) -> SummarizeRequest {
        self.batch = batch;
        self
    }

    pub fn precision(mut self, precision: Precision) -> SummarizeRequest {
        self.precision = precision;
        self
    }

    pub fn cpu_kernel(mut self, kernel: CpuKernel) -> SummarizeRequest {
        self.cpu_kernel = kernel;
        self
    }

    pub fn threads(mut self, threads: usize) -> SummarizeRequest {
        self.threads = threads;
        self
    }

    /// Run the sharded two-stage pipeline instead of single-node.
    pub fn sharded(mut self, spec: ShardSpec) -> SummarizeRequest {
        self.shard = Some(spec);
        self
    }

    pub fn seed(mut self, seed: u64) -> SummarizeRequest {
        self.seed = seed;
        self
    }

    pub fn with_baseline(mut self, with_baseline: bool) -> SummarizeRequest {
        self.with_baseline = with_baseline;
        self
    }

    /// Ask for the span tree in the response provenance (local-only;
    /// see the [`Self::trace`] field).
    pub fn trace(mut self, trace: bool) -> SummarizeRequest {
        self.trace = trace;
        self
    }

    /// The registry id of the selected optimizer, if it has one.
    pub fn optimizer_name(&self) -> &str {
        match &self.optimizer {
            OptimizerSel::Registry(name) => name,
            OptimizerSel::Custom(o) => o.name(),
        }
    }

    /// Check every field against its registry and structural bounds.
    /// Cheap (nothing is materialized); `k > n` for datasets whose size
    /// is only known after generation is re-checked by the executor.
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.k == 0 {
            return Err(ApiError::invalid("k", "summary cardinality must be >= 1"));
        }
        if self.batch == 0 {
            return Err(ApiError::invalid("batch", "candidate batch must be >= 1"));
        }
        match &self.dataset {
            DatasetRef::Inline(m) => {
                if m.rows() == 0 || m.cols() == 0 {
                    return Err(ApiError::invalid(
                        "dataset",
                        format!("inline matrix is degenerate ({}x{})", m.rows(), m.cols()),
                    ));
                }
            }
            DatasetRef::Synthetic { n, d, .. } => {
                if *n == 0 || *d == 0 {
                    return Err(ApiError::invalid(
                        "dataset",
                        format!("synthetic shape is degenerate ({n}x{d})"),
                    ));
                }
            }
            DatasetRef::Imm { samples, .. } => {
                if *samples == 0 {
                    return Err(ApiError::invalid("dataset", "imm samples must be >= 1"));
                }
            }
        }
        if let Some(n) = self.dataset.rows_hint() {
            if self.k > n {
                return Err(ApiError::invalid(
                    "k",
                    format!("k = {} exceeds the ground-set size n = {n}", self.k),
                ));
            }
        }
        let remote_transport = self
            .shard
            .as_ref()
            .map(|s| s.transport.as_str())
            .filter(|t| *t != "inproc");
        match &self.optimizer {
            OptimizerSel::Registry(name) => {
                if !ALGORITHMS.contains(&name.as_str()) {
                    return Err(ApiError::unknown("optimizer", name, ALGORITHMS));
                }
            }
            OptimizerSel::Custom(_) => {
                // the remote-rebuild contract: only registry optimizers
                // reproduce local selection on the other side of a wire
                if let Some(t) = remote_transport {
                    return Err(ApiError::NonRegistryOptimizer { transport: t.to_string() });
                }
            }
        }
        if let Some(spec) = &self.shard {
            if spec.partitions == 0 {
                return Err(ApiError::invalid("shard.partitions", "shard count must be >= 1"));
            }
            if !PARTITIONERS.contains(&spec.partitioner.as_str()) {
                return Err(ApiError::unknown(
                    "shard.partitioner",
                    &spec.partitioner,
                    PARTITIONERS,
                ));
            }
            if !TRANSPORTS.contains(&spec.transport.as_str()) {
                return Err(ApiError::unknown("shard.transport", &spec.transport, TRANSPORTS));
            }
            if spec.transport != "inproc" && spec.replicas == 0 {
                return Err(ApiError::invalid(
                    "shard.replicas",
                    "replica transports need at least one replica",
                ));
            }
            if spec.transport == "tcp" && spec.net.addrs.is_empty() {
                return Err(ApiError::invalid(
                    "shard.net.addrs",
                    "the tcp transport needs at least one replica endpoint",
                ));
            }
            if !(0.0..1.0).contains(&spec.prune) {
                return Err(ApiError::invalid(
                    "shard.prune",
                    format!("prune rate {} outside [0, 1)", spec.prune),
                ));
            }
            if !ALGORITHMS.contains(&spec.merge_optimizer.as_str()) {
                return Err(ApiError::unknown(
                    "shard.merge_optimizer",
                    &spec.merge_optimizer,
                    ALGORITHMS,
                ));
            }
        }
        Ok(())
    }

    /// Serialize into the wire form (v2 request frame payload).
    /// `payload` selects how an inline dataset ships (f32 lossless,
    /// bf16 halved — the edge-link option); reference datasets ignore
    /// it. Fails for custom optimizers — only registry ids survive the
    /// wire (the same contract [`Self::validate`] enforces for remote
    /// transports).
    pub fn to_wire(&self, payload: Precision) -> Result<WireRequest, ApiError> {
        let optimizer = match &self.optimizer {
            OptimizerSel::Registry(name) => name.clone(),
            OptimizerSel::Custom(_) => {
                return Err(ApiError::NonRegistryOptimizer { transport: "wire".into() })
            }
        };
        Ok(WireRequest {
            k: self.k as u32,
            batch: self.batch as u32,
            optimizer,
            precision: self.precision,
            cpu_kernel: self.cpu_kernel,
            threads: self.threads as u32,
            seed: self.seed,
            with_baseline: self.with_baseline,
            shard: self.shard.as_ref().map(|s| WireShardSpec {
                partitions: s.partitions as u32,
                partitioner: s.partitioner.clone(),
                per_shard_k: s.per_shard_k as u32,
                threads: s.threads as u32,
                transport: s.transport.clone(),
                replicas: s.replicas as u32,
                plan: s.plan,
                cores: s.cores as u32,
            }),
            dataset: match &self.dataset {
                DatasetRef::Inline(m) => {
                    WireDataset::Inline { payload, data: (**m).clone() }
                }
                DatasetRef::Synthetic { n, d, seed } => WireDataset::Synthetic {
                    n: *n as u32,
                    d: *d as u32,
                    seed: *seed,
                },
                DatasetRef::Imm { part, state, samples, seed } => WireDataset::Imm {
                    part: *part,
                    state: *state,
                    samples: *samples as u32,
                    seed: *seed,
                },
            },
        })
    }

    /// Rebuild a request from its wire form (the executor side of the
    /// codec). Purely structural — run [`Self::validate`] on the result
    /// before executing.
    pub fn from_wire(w: &WireRequest) -> SummarizeRequest {
        SummarizeRequest {
            dataset: match &w.dataset {
                WireDataset::Inline { data, .. } => DatasetRef::Inline(Arc::new(data.clone())),
                WireDataset::Synthetic { n, d, seed } => DatasetRef::Synthetic {
                    n: *n as usize,
                    d: *d as usize,
                    seed: *seed,
                },
                WireDataset::Imm { part, state, samples, seed } => DatasetRef::Imm {
                    part: *part,
                    state: *state,
                    samples: *samples as usize,
                    seed: *seed,
                },
            },
            k: w.k as usize,
            optimizer: OptimizerSel::Registry(w.optimizer.clone()),
            batch: w.batch as usize,
            precision: w.precision,
            cpu_kernel: w.cpu_kernel,
            threads: w.threads as usize,
            shard: w.shard.as_ref().map(|s| ShardSpec {
                partitions: s.partitions as usize,
                partitioner: s.partitioner.clone(),
                per_shard_k: s.per_shard_k as usize,
                threads: s.threads as usize,
                transport: s.transport.clone(),
                replicas: s.replicas as usize,
                plan: s.plan,
                cores: s.cores as usize,
                // local-only knobs: remote executors keep their own
                // fleet configuration, and pruning happens before jobs
                // are built on whichever side runs the shards
                net: NetOptions::default(),
                prune: 0.0,
                fanout: 0,
                max_merge_n: 0,
                merge_optimizer: "greedy".into(),
            }),
            seed: w.seed,
            with_baseline: w.with_baseline,
            // local-only knob: a remote executor's spans stay in its
            // own flight recorder rather than shipping back
            trace: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::SieveStreaming;
    use crate::shard::wire::{decode_request, encode_request};

    fn inline(n: usize, d: usize, seed: u64) -> DatasetRef {
        let mut rng = Rng::new(seed);
        DatasetRef::Inline(Arc::new(Matrix::random_normal(n, d, &mut rng)))
    }

    #[test]
    fn builder_defaults_validate() {
        let req = SummarizeRequest::new(inline(20, 4, 1), 5);
        assert!(req.validate().is_ok());
        assert_eq!(req.optimizer_name(), "greedy");
    }

    #[test]
    fn structural_failures_are_typed() {
        let base = SummarizeRequest::new(inline(20, 4, 1), 5);
        assert!(matches!(
            base.clone().batch(0).validate(),
            Err(ApiError::Invalid { field: "batch", .. })
        ));
        let mut k0 = base.clone();
        k0.k = 0;
        assert!(matches!(k0.validate(), Err(ApiError::Invalid { field: "k", .. })));
        let mut big = base.clone();
        big.k = 21;
        assert!(matches!(big.validate(), Err(ApiError::Invalid { field: "k", .. })));
        assert!(matches!(
            SummarizeRequest::new(DatasetRef::synthetic(0, 3, 1), 1).validate(),
            Err(ApiError::Invalid { field: "dataset", .. })
        ));
    }

    #[test]
    fn registry_misses_are_typed() {
        let base = SummarizeRequest::new(inline(20, 4, 1), 5);
        assert!(matches!(
            base.clone().optimizer("psychic").validate(),
            Err(ApiError::UnknownName { field: "optimizer", .. })
        ));
        assert!(matches!(
            base.clone().sharded(ShardSpec::new(2).partitioner("magic")).validate(),
            Err(ApiError::UnknownName { field: "shard.partitioner", .. })
        ));
        assert!(matches!(
            base.clone().sharded(ShardSpec::new(2).transport("telepathy")).validate(),
            Err(ApiError::UnknownName { field: "shard.transport", .. })
        ));
        assert!(matches!(
            base.sharded(ShardSpec::new(0)).validate(),
            Err(ApiError::Invalid { field: "shard.partitions", .. })
        ));
    }

    #[test]
    fn tcp_transport_requires_endpoints() {
        let base = SummarizeRequest::new(inline(20, 4, 1), 5);
        assert!(matches!(
            base.clone().sharded(ShardSpec::new(2).transport("tcp")).validate(),
            Err(ApiError::Invalid { field: "shard.net.addrs", .. })
        ));
        let net = NetOptions {
            addrs: vec!["127.0.0.1:7700".into()],
            ..NetOptions::default()
        };
        assert!(base
            .sharded(ShardSpec::new(2).transport("tcp").net(net))
            .validate()
            .is_ok());
    }

    #[test]
    fn custom_optimizer_ok_locally_rejected_remotely() {
        let custom: Arc<dyn Optimizer> = Arc::new(SieveStreaming::default());
        let base = SummarizeRequest::new(inline(20, 4, 1), 3)
            .custom_optimizer(Arc::clone(&custom));
        assert!(base.clone().validate().is_ok());
        assert!(base.clone().sharded(ShardSpec::new(2)).validate().is_ok());
        match base
            .clone()
            .sharded(ShardSpec::new(2).transport("loopback"))
            .validate()
        {
            Err(ApiError::NonRegistryOptimizer { transport }) => {
                assert_eq!(transport, "loopback");
            }
            other => panic!("{other:?}"),
        }
        // ...and custom instances never serialize
        assert!(matches!(
            base.to_wire(Precision::F32),
            Err(ApiError::NonRegistryOptimizer { .. })
        ));
    }

    #[test]
    fn prune_knobs_validate_and_stay_local() {
        let base = SummarizeRequest::new(inline(20, 4, 1), 5);
        assert!(base
            .clone()
            .sharded(ShardSpec::new(2).prune(0.5).fanout(4).max_merge_n(100))
            .validate()
            .is_ok());
        assert!(matches!(
            base.clone().sharded(ShardSpec::new(2).prune(1.0)).validate(),
            Err(ApiError::Invalid { field: "shard.prune", .. })
        ));
        assert!(matches!(
            base.clone().sharded(ShardSpec::new(2).prune(-0.1)).validate(),
            Err(ApiError::Invalid { field: "shard.prune", .. })
        ));
        assert!(matches!(
            base.clone()
                .sharded(ShardSpec::new(2).merge_optimizer("psychic"))
                .validate(),
            Err(ApiError::UnknownName { field: "shard.merge_optimizer", .. })
        ));
        // the knobs never cross the frozen v2 wire: a round trip of a
        // pruned request comes back with pruning forced off
        let req = base.sharded(
            ShardSpec::new(3)
                .prune(0.4)
                .fanout(2)
                .max_merge_n(50)
                .merge_optimizer("stochastic_greedy"),
        );
        let frame = encode_request(&req.to_wire(Precision::F32).unwrap());
        let back = SummarizeRequest::from_wire(&decode_request(&frame).unwrap());
        let spec = back.shard.unwrap();
        assert_eq!(spec.prune, 0.0);
        assert_eq!(spec.fanout, 0);
        assert_eq!(spec.max_merge_n, 0);
        assert_eq!(spec.merge_optimizer, "greedy");
    }

    #[test]
    fn wire_roundtrip_preserves_the_request() {
        let req = SummarizeRequest::new(inline(6, 3, 9), 2)
            .optimizer("lazy_greedy")
            .batch(256)
            .precision(Precision::Bf16)
            .cpu_kernel(CpuKernel::Scalar)
            .threads(3)
            .seed(77)
            .with_baseline(true)
            .sharded(ShardSpec::new(3).partitioner("hash").transport("loopback").replicas(2));
        let frame = encode_request(&req.to_wire(Precision::F32).unwrap());
        let back = SummarizeRequest::from_wire(&decode_request(&frame).unwrap());
        assert_eq!(back, req);
    }
}
