//! The typed outcome of a summarize request.

use crate::engine::Precision;
use crate::linalg::CpuKernel;

/// Wall-clock accounting per pipeline stage. Single-node runs report
/// only `wall_seconds`; sharded runs split it into partition / shard /
/// merge legs (`wall_seconds` is their sum).
#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    /// Partitioning the ground set (sharded runs).
    pub partition_seconds: f64,
    /// The parallel per-shard first stage (sharded runs).
    pub shard_seconds: f64,
    /// The greedy merge over the union of shard picks (sharded runs).
    pub merge_seconds: f64,
    /// End-to-end optimization wall-clock.
    pub wall_seconds: f64,
}

/// What actually executed — the audit trail a response carries so
/// callers never have to re-derive it from config.
#[derive(Debug, Clone)]
pub struct Provenance {
    /// Evaluation backend (`cpu` | `xla` | a caller-supplied label).
    pub backend: String,
    /// Optimizer that ran (registry id or the custom instance's name).
    pub optimizer: String,
    /// Oracle compute precision.
    pub precision: Precision,
    /// CPU kernel backend CPU/fallback oracles ran on.
    pub cpu_kernel: CpuKernel,
    /// Partitioner of a sharded run.
    pub partitioner: Option<&'static str>,
    /// Fleet-plan description of a planned run — the worker × thread
    /// split and the pinned engine bucket picks
    /// ([`crate::engine::ShardPlan::describe`]).
    pub plan: Option<String>,
    /// Compact `Pw x Tt` split label of a planned run (bench tables).
    pub plan_split: Option<String>,
    /// Transport stage 1 actually ran over (after any fallback).
    pub transport: Option<&'static str>,
    /// Bytes moved as wire frames (job + result, both legs).
    pub wire_bytes: u64,
    /// Shards re-queued after replica failures.
    pub shard_retries: u64,
    /// Non-empty shards executed (0 for single-node runs).
    pub shards_used: usize,
    /// Most stage-1 job payloads alive at once (bounded by transport
    /// concurrency — see [`crate::shard::JobSource`]).
    pub peak_jobs_held: usize,
    /// The configured shard transport failed outright (e.g. every
    /// remote replica dead) and stage 1 degraded to the in-process
    /// fallback. The exemplars are still correct; the fleet did not
    /// produce them. Always `false` for single-node runs.
    pub degraded: bool,
    /// Ground rows sieved away before stage 1 (see [`crate::prune`];
    /// 0 = pruning off or single-node).
    pub pruned_n: usize,
    /// Wall-clock of the coordinator-side prune stage.
    pub prune_seconds: f64,
    /// Merge-tree depth of a sharded run (1 = flat merge, 0 =
    /// single-node).
    pub merge_depth: usize,
    /// Optimizer the merge stage(s) ran (`"greedy"` = the exact
    /// candidate-greedy merge). Empty for single-node runs.
    pub merge_optimizer: String,
    /// The request's span tree (children after parents is not
    /// guaranteed; sort key is start time). Populated only when the
    /// request set its `trace` knob and span recording is enabled —
    /// see [`crate::obs`].
    pub trace: Option<Vec<crate::obs::SpanRecord>>,
}

/// The single-node reference run of a `with_baseline` request.
#[derive(Debug, Clone)]
pub struct BaselineRun {
    /// Exemplars the single-node run selected (ground ids).
    pub exemplars: Vec<u64>,
    /// Its final f(S).
    pub f_final: f32,
    /// Its wall-clock.
    pub wall_seconds: f64,
}

/// Outcome of one [`crate::api::SummarizeRequest`].
#[derive(Debug, Clone)]
pub struct SummarizeResponse {
    /// Selected exemplars as **ground ids** (row indices of the
    /// materialized dataset), in selection order.
    pub exemplars: Vec<u64>,
    /// f(S) after each selection (same length as `exemplars`).
    pub f_trajectory: Vec<f32>,
    /// Final function value (sharded runs: measured against the full
    /// ground set, so values are comparable to single-node runs).
    pub f_final: f32,
    /// Oracle gain/eval calls issued.
    pub oracle_calls: u64,
    /// Oracle-reported scalar-distance work.
    pub oracle_work: u64,
    /// Per-stage wall-clock.
    pub timings: StageTimings,
    /// What actually executed.
    pub provenance: Provenance,
    /// Reference run, when the request asked for one.
    pub baseline: Option<BaselineRun>,
}

impl SummarizeResponse {
    /// Number of exemplars selected.
    pub fn k(&self) -> usize {
        self.exemplars.len()
    }

    /// merged f / baseline f — the two-stage quality ratio (`None`
    /// without a baseline; 1.0 when the baseline is degenerate).
    pub fn quality_ratio(&self) -> Option<f64> {
        self.baseline.as_ref().map(|b| {
            if b.f_final <= 0.0 {
                1.0
            } else {
                self.f_final as f64 / b.f_final as f64
            }
        })
    }

    /// baseline wall / this run's wall — the sharded speedup (`None`
    /// without a baseline or with a zero-duration run).
    pub fn speedup(&self) -> Option<f64> {
        self.baseline.as_ref().and_then(|b| {
            (self.timings.wall_seconds > 0.0)
                .then(|| b.wall_seconds / self.timings.wall_seconds)
        })
    }
}
