//! Minimal `log`-crate backend writing to stderr with level filtering
//! via `EBC_LOG` (error|warn|info|debug|trace; default info).

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::Once;
use std::time::Instant;

static INIT: Once = Once::new();

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:10.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let filter = match std::env::var("EBC_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
        let _ = log::set_logger(logger);
        log::set_max_level(filter);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
