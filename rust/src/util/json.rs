//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Covers the full JSON grammar needed by the artifact manifest
//! (`artifacts/manifest.json`), bench reports and coordinator snapshots:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (manifest never emits them)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience builder for writing JSON objects field by field.
#[derive(Default)]
pub struct ObjBuilder {
    m: BTreeMap<String, Json>,
}

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.m.insert(k.into(), Json::Str(v.into()));
        self
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.m.insert(k.into(), Json::Num(v));
        self
    }
    pub fn int(mut self, k: &str, v: usize) -> Self {
        self.m.insert(k.into(), Json::Num(v as f64));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.m.insert(k.into(), Json::Bool(v));
        self
    }
    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.m.insert(k.into(), v);
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "entries": [{"name": "gains_n1024", "n": 1024,
            "mxu_flops": 6.7e7, "inputs": ["v", "vsq"], "ok": true, "x": null}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("gains_n1024"));
        assert_eq!(e.get("n").unwrap().as_usize(), Some(1024));
        assert_eq!(e.get("mxu_flops").unwrap().as_f64(), Some(6.7e7));
        assert_eq!(e.get("inputs").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(e.get("x"), Some(&Json::Null));
        // dump -> parse round trip
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1", -1.0), ("3.5", 3.5), ("1e3", 1000.0),
                       ("-2.5E-2", -0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn obj_builder() {
        let j = ObjBuilder::new().str("a", "x").int("b", 3).bool("c", false).build();
        assert_eq!(j.dump(), r#"{"a":"x","b":3,"c":false}"#);
    }
}
