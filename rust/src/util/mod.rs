//! std-only infrastructure substrate.
//!
//! The build environment is fully offline (DESIGN.md §4), so the usual
//! ecosystem crates (rand, serde, rayon, criterion, proptest, clap) are
//! unavailable; this module tree provides the small, tested subset of
//! their functionality the rest of the crate needs.

pub mod csv;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod testing;
pub mod threadpool;
pub mod timer;

/// Argmax over an f32 slice; ties broken toward the lower index.
/// Returns `None` for an empty slice or all-NaN input.
pub fn argmax_f32(xs: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some((_, b)) if x <= b => {}
            _ => best = Some((i, x)),
        }
    }
    best.map(|(i, _)| i)
}

/// Round `x` up to the next multiple of `q` (q > 0).
pub fn round_up(x: usize, q: usize) -> usize {
    debug_assert!(q > 0);
    x.div_ceil(q) * q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax_f32(&[1.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax_f32(&[]), None);
        assert_eq!(argmax_f32(&[f32::NAN, 1.0]), Some(1));
        assert_eq!(argmax_f32(&[f32::NAN]), None);
        // ties go to the first index
        assert_eq!(argmax_f32(&[2.0, 2.0, 1.0]), Some(0));
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), Some(0));
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(1000, 1024), 1024);
    }
}
