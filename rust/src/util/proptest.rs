//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! Deterministic, seed-driven case generation with shrinking-lite: on
//! failure the failing seed is reported so the case replays exactly.
//! Used by `rust/tests/proptests.rs` for the submodularity/monotonicity
//! invariants and the coordinator invariants.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("EBC_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xEBC0_FFEE);
        let cases = std::env::var("EBC_PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(32);
        Config { cases, seed }
    }
}

/// Run `prop` over `cases` generated inputs. `gen` draws an arbitrary
/// input from the RNG; `prop` returns `Err(reason)` on violation.
///
/// Panics with the offending case index + seed on first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{} (EBC_PROPTEST_SEED={} replays \
                 the run; case seed {case_seed:#x}):\n  reason: {reason}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Draw a small random dataset: (n, d, row-major data) with n in
/// [1, max_n], d in [1, max_d], values ~ N(0, scale).
pub fn arb_dataset(rng: &mut Rng, max_n: usize, max_d: usize, scale: f32) -> (usize, usize, Vec<f32>) {
    let n = 1 + rng.below(max_n);
    let d = 1 + rng.below(max_d);
    let data = (0..n * d).map(|_| rng.normal() * scale).collect();
    (n, d, data)
}

/// Draw a random subset of [0, n) of size <= max_k (possibly empty).
pub fn arb_subset(rng: &mut Rng, n: usize, max_k: usize) -> Vec<usize> {
    let k = rng.below(max_k.min(n) + 1);
    rng.sample_indices(n, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        let cfg = Config { cases: 16, seed: 1 };
        forall("x*x >= 0", &cfg, |r| r.normal(), |x| {
            if x * x >= 0.0 {
                Ok(())
            } else {
                Err("negative square".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        let cfg = Config { cases: 4, seed: 2 };
        forall("always fails", &cfg, |r| r.f32(), |_| Err("nope".into()));
    }

    #[test]
    fn arb_dataset_shapes() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let (n, d, data) = arb_dataset(&mut rng, 20, 10, 1.0);
            assert!(n >= 1 && n <= 20);
            assert!(d >= 1 && d <= 10);
            assert_eq!(data.len(), n * d);
        }
    }

    #[test]
    fn arb_subset_valid() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let s = arb_subset(&mut rng, 10, 5);
            assert!(s.len() <= 5);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), s.len());
        }
    }
}
