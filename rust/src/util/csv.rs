//! Tiny CSV writer/reader for bench outputs and case-study exports
//! (Fig. 4 curves, Fig. 2/3 series). RFC-4180-style quoting.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// In-memory CSV table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Push a row of display-able values.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| v.to_string()).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for r in &self.rows {
            write_row(&mut out, r);
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn parse(s: &str) -> Result<Table, String> {
        let mut lines = parse_csv(s)?;
        if lines.is_empty() {
            return Err("empty csv".into());
        }
        let header = lines.remove(0);
        for (i, r) in lines.iter().enumerate() {
            if r.len() != header.len() {
                return Err(format!("row {i} arity {} != header {}", r.len(), header.len()));
            }
        }
        Ok(Table { header, rows: lines })
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }
}

fn needs_quote(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, cell) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quote(cell) {
            out.push('"');
            for c in cell.chars() {
                if c == '"' {
                    out.push('"');
                }
                out.push(c);
            }
            out.push('"');
        } else {
            let _ = write!(out, "{cell}");
        }
    }
    out.push('\n');
}

fn parse_csv(s: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut cell = String::new();
    let mut chars = s.chars().peekable();
    let mut in_quotes = false;
    let mut row_started = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cell.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => cell.push(c),
            }
        } else {
            match c {
                '"' => {
                    in_quotes = true;
                    row_started = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut cell));
                    row_started = true;
                }
                '\r' => {}
                '\n' => {
                    if row_started || !cell.is_empty() || !row.is_empty() {
                        row.push(std::mem::take(&mut cell));
                        rows.push(std::mem::take(&mut row));
                    }
                    row_started = false;
                }
                c => {
                    cell.push(c);
                    row_started = true;
                }
            }
        }
    }
    if in_quotes {
        return Err("unterminated quote".into());
    }
    if row_started || !cell.is_empty() || !row.is_empty() {
        row.push(cell);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t.push(vec!["he said \"hi\"".into(), "line\nbreak".into()]);
        let s = t.to_csv();
        let t2 = Table::parse(&s).unwrap();
        assert_eq!(t.header, t2.header);
        assert_eq!(t.rows, t2.rows);
    }

    #[test]
    fn col_lookup() {
        let t = Table::new(&["n", "runtime_s"]);
        assert_eq!(t.col("runtime_s"), Some(1));
        assert_eq!(t.col("nope"), None);
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(Table::parse("a,b\n1\n").is_err());
        assert!(Table::parse("").is_err());
        assert!(Table::parse("a,\"b").is_err());
    }

    #[test]
    fn push_display() {
        let mut t = Table::new(&["x", "y"]);
        t.push_display(&[&1.5f64, &"s"]);
        assert_eq!(t.rows[0], vec!["1.5", "s"]);
    }
}
