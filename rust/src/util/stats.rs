//! Summary statistics used by the bench harness and the reports
//! (criterion is unavailable offline — see DESIGN.md §4).

/// Summary of a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// min/mean/max triple — the shape of the paper's Table 1 cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinMeanMax {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

impl MinMeanMax {
    pub fn of(xs: &[f64]) -> MinMeanMax {
        assert!(!xs.is_empty());
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
            sum += x;
        }
        MinMeanMax { min, mean: sum / xs.len() as f64, max }
    }
}

/// Pearson correlation (used by case-study sanity tests).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentiles() {
        let sorted = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 4.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 2.0);
        assert!((percentile_sorted(&sorted, 25.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_mean_max() {
        let m = MinMeanMax::of(&[3.0, 1.0, 2.0]);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 3.0);
        assert!((m.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0]), 0.0);
    }
}
