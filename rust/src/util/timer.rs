//! Wall-clock timing helpers for the bench harness (perf(1)/flamegraph
//! are unavailable in the container). Scoped/accumulating profiling
//! lives in [`crate::obs`] — histograms + spans replaced the old
//! `Profile` recorder.

use std::time::{Duration, Instant};

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly for at least `min_time` and `min_iters`, returning
/// per-iteration seconds. One warmup call is discarded.
pub fn sample(mut f: impl FnMut(), min_iters: usize, min_time: Duration) -> Vec<f64> {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures() {
        let (v, secs) = time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(secs >= 0.004, "{secs}");
    }

    #[test]
    fn sample_counts() {
        let s = sample(|| {}, 5, Duration::from_millis(1));
        assert!(s.len() >= 5);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

}
