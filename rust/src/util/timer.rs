//! Wall-clock timing helpers + a lightweight hierarchical profile
//! recorder used by the perf pass (perf(1)/flamegraph are unavailable in
//! the container; the bench harness relies on these scoped timers).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly for at least `min_time` and `min_iters`, returning
/// per-iteration seconds. One warmup call is discarded.
pub fn sample(mut f: impl FnMut(), min_iters: usize, min_time: Duration) -> Vec<f64> {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples
}

/// Accumulating profile: named counters of (calls, total seconds).
/// Cheap enough to leave enabled on the hot path of the coordinator.
#[derive(Default)]
pub struct Profile {
    inner: Mutex<BTreeMap<String, (u64, Duration)>>,
}

impl Profile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, name: &str, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert((0, Duration::ZERO));
        e.0 += 1;
        e.1 += d;
    }

    /// Time a closure under `name`.
    pub fn scope<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed());
        out
    }

    pub fn snapshot(&self) -> Vec<(String, u64, f64)> {
        let m = self.inner.lock().unwrap();
        m.iter()
            .map(|(k, (n, d))| (k.clone(), *n, d.as_secs_f64()))
            .collect()
    }

    pub fn report(&self) -> String {
        let mut rows = self.snapshot();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        let mut out = format!("{:<40} {:>10} {:>12} {:>12}\n", "scope", "calls", "total_s", "per_call_us");
        for (name, calls, secs) in rows {
            out.push_str(&format!(
                "{:<40} {:>10} {:>12.4} {:>12.2}\n",
                name,
                calls,
                secs,
                secs / calls.max(1) as f64 * 1e6
            ));
        }
        out
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures() {
        let (v, secs) = time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(secs >= 0.004, "{secs}");
    }

    #[test]
    fn sample_counts() {
        let s = sample(|| {}, 5, Duration::from_millis(1));
        assert!(s.len() >= 5);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn profile_accumulates() {
        let p = Profile::new();
        p.scope("a", || std::thread::sleep(Duration::from_millis(2)));
        p.scope("a", || {});
        p.scope("b", || {});
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        let a = snap.iter().find(|(n, _, _)| n == "a").unwrap();
        assert_eq!(a.1, 2);
        assert!(a.2 > 0.001);
        assert!(p.report().contains("per_call_us"));
        p.reset();
        assert!(p.snapshot().is_empty());
    }
}
