//! Test-support helpers shared by the integration test binaries.

/// Gate for end-to-end tests that need the real PJRT runtime + AOT
/// artifacts (`make artifacts`), which the offline stub build cannot
/// provide. Returns `true` when `RUN_E2E=1`; otherwise prints a visible
/// skip line (so CI output shows *why* the test did nothing) and
/// returns `false` — callers `return` early instead of `#[ignore]`-ing
/// silently.
pub fn e2e_enabled(test: &str) -> bool {
    if std::env::var("RUN_E2E").map(|v| v == "1").unwrap_or(false) {
        return true;
    }
    eprintln!(
        "skipping {test}: set RUN_E2E=1 to run (needs PJRT artifacts via `make artifacts` \
         and the real `xla` crate instead of the offline stub)"
    );
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_follows_env() {
        // temp-env juggling is race-prone under the parallel test
        // runner, so only assert the env-independent contract: the
        // gate's answer matches the live environment.
        let want = std::env::var("RUN_E2E").map(|v| v == "1").unwrap_or(false);
        assert_eq!(e2e_enabled("gate_follows_env"), want);
    }
}
