//! Deterministic PRNG: xoshiro256++ (Blackman & Vigna) plus the sampling
//! helpers the workload generators and optimizers need.
//!
//! `rand` is unavailable offline; this is a compact, well-tested stand-in
//! with reproducible streams (seed → identical sequences on every
//! platform), which the experiment harness relies on.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/serial seeds give well-mixed
    /// initial states (the xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high-quality mantissa bits
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached second variate dropped for
    /// simplicity — throughput is not a concern for data generation).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        // partial Fisher-Yates over an index vec; fine for bench-scale n
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Derive an independent stream for a subcomponent.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
