//! Fixed-size thread pool over std::thread + mpsc (rayon/tokio are
//! unavailable offline).
//!
//! Two use sites:
//! * the **MT CPU baseline** of the paper's §4.1 (set-parallel EBC) —
//!   [`scoped_chunks_mut`] mirrors the OpenMP `parallel for` over
//!   subsets, writing disjoint output chunks;
//! * the **coordinator**'s worker pool ([`ThreadPool`]) for background
//!   ingestion and summary refresh jobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size worker pool; jobs are executed FIFO.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("ebc-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel-for over a mutable output slice: `out` is split into one
/// disjoint contiguous chunk per thread and `f(chunk_index, start,
/// chunk)` writes its chunk directly — no per-slot locking, the borrow
/// split is what proves disjointness.
pub fn scoped_chunks_mut<T: Send, F>(out: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    if threads == 1 {
        f(0, 0, out);
        return;
    }
    thread::scope(|scope| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(t, t * chunk, slice));
        }
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    scoped_chunks_mut(&mut out, threads, |_, start, slice| {
        for (off, slot) in slice.iter_mut().enumerate() {
            *slot = Some(f(&items[start + off]));
        }
    });
    out.into_iter().map(|x| x.expect("filled")).collect()
}

/// Default worker count: honours `EBC_THREADS`, else available_parallelism.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("EBC_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join all
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_chunks_mut_fills_disjoint_chunks() {
        let mut out = vec![0usize; 103];
        scoped_chunks_mut(&mut out, 4, |_, start, slice| {
            for (off, slot) in slice.iter_mut().enumerate() {
                *slot = start + off + 1;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i + 1));
        // empty + single-element edges
        scoped_chunks_mut(&mut [] as &mut [usize], 4, |_, _, _| panic!("should not run"));
        let mut one = [0usize];
        scoped_chunks_mut(&mut one, 8, |t, start, slice| {
            assert_eq!((t, start, slice.len()), (0, 0, 1));
            slice[0] = 9;
        });
        assert_eq!(one[0], 9);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = par_map(&items, 3, |&x| x * 2);
        assert_eq!(out, (0..57).map(|x| x * 2).collect::<Vec<_>>());
    }
}
