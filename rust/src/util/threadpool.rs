//! Fixed-size thread pool over std::thread + mpsc (rayon/tokio are
//! unavailable offline).
//!
//! Two use sites:
//! * the **MT CPU baseline** of the paper's §4.1 (set-parallel EBC) —
//!   [`scoped_chunks`] mirrors the OpenMP `parallel for` over subsets;
//! * the **coordinator**'s worker pool ([`ThreadPool`]) for background
//!   ingestion and summary refresh jobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size worker pool; jobs are executed FIFO.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("ebc-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel-for over chunked index ranges using scoped threads: calls
/// `f(chunk_index, start, end)` with [start, end) partitioning [0, n).
/// The MT-CPU-baseline analog of the paper's OpenMP parallelization.
pub fn scoped_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(t, start, end));
        }
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T: Sync, R: Send>(items: &[T], threads: usize, f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
        scoped_chunks(items.len(), threads, |_, start, end| {
            for i in start..end {
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            }
        });
    }
    out.into_iter().map(|x| x.expect("filled")).collect()
}

/// Default worker count: honours `EBC_THREADS`, else available_parallelism.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("EBC_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join all
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_chunks_cover_range() {
        let seen = Mutex::new(vec![false; 103]);
        scoped_chunks(103, 4, |_, start, end| {
            for i in start..end {
                let mut s = seen.lock().unwrap();
                assert!(!s[i], "index {i} visited twice");
                s[i] = true;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn scoped_chunks_empty_and_single() {
        scoped_chunks(0, 4, |_, _, _| panic!("should not run"));
        let hits = AtomicU64::new(0);
        scoped_chunks(1, 8, |_, s, e| {
            assert_eq!((s, e), (0, 1));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = par_map(&items, 3, |&x| x * 2);
        assert_eq!(out, (0..57).map(|x| x * 2).collect::<Vec<_>>());
    }
}
