//! Padding/packing policy: every request is padded up to the fixed
//! shapes of the chosen artifact bucket and masked (DESIGN.md §5).
//!
//! * zero-padded feature dims are exact for squared Euclidean
//!   (they contribute (0−0)² = 0);
//! * padded ground rows carry `vmask = 0` → excluded from every mean;
//! * padded candidates carry `cmask = 0` → gain forced to −BIG;
//! * padded set slots carry `smask = 0` → distance forced to +BIG
//!   (never win the min) — the paper's "entry simply remains empty".

use crate::linalg::Matrix;

/// Pack a (rows x cols) matrix into a zero-padded row-major buffer of
/// shape (rows_pad x cols_pad).
pub fn pad_matrix(m: &Matrix, rows_pad: usize, cols_pad: usize) -> Vec<f32> {
    assert!(rows_pad >= m.rows() && cols_pad >= m.cols());
    let mut out = vec![0f32; rows_pad * cols_pad];
    for i in 0..m.rows() {
        out[i * cols_pad..i * cols_pad + m.cols()].copy_from_slice(m.row(i));
    }
    out
}

/// Zero-pad a vector to `len`, filling with `fill`.
pub fn pad_vec(v: &[f32], len: usize, fill: f32) -> Vec<f32> {
    assert!(len >= v.len());
    let mut out = vec![fill; len];
    out[..v.len()].copy_from_slice(v);
    out
}

/// 1/0 mask with `real` ones followed by `len - real` zeros.
pub fn mask(real: usize, len: usize) -> Vec<f32> {
    assert!(len >= real);
    let mut m = vec![0f32; len];
    m[..real].fill(1.0);
    m
}

/// Pack ragged index sets into the dense evaluation-set matrix of the
/// paper's memory layout: rows gathered from `ground`, `k_pad` slots per
/// set, `l_pad` sets. Returns (s_flat, smask_flat) with s_flat of shape
/// (l_pad * k_pad, d_pad) row-major.
pub fn pack_sets(
    ground: &Matrix,
    sets: &[&[usize]],
    l_pad: usize,
    k_pad: usize,
    d_pad: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(l_pad >= sets.len());
    let d = ground.cols();
    assert!(d_pad >= d);
    let mut s_flat = vec![0f32; l_pad * k_pad * d_pad];
    let mut smask = vec![0f32; l_pad * k_pad];
    for (j, set) in sets.iter().enumerate() {
        assert!(set.len() <= k_pad, "set {j} larger than k bucket");
        for (slot, &idx) in set.iter().enumerate() {
            let row = (j * k_pad + slot) * d_pad;
            s_flat[row..row + d].copy_from_slice(ground.row(idx));
            smask[j * k_pad + slot] = 1.0;
        }
    }
    (s_flat, smask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_matrix_layout() {
        let m = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        let p = pad_matrix(&m, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1., 2., 0., 0.]);
        assert_eq!(&p[4..8], &[3., 4., 0., 0.]);
        assert_eq!(&p[8..12], &[0., 0., 0., 0.]);
    }

    #[test]
    fn mask_and_pad_vec() {
        assert_eq!(mask(2, 4), vec![1., 1., 0., 0.]);
        assert_eq!(pad_vec(&[5., 6.], 4, 9.), vec![5., 6., 9., 9.]);
    }

    #[test]
    fn pack_sets_layout() {
        let g = Matrix::from_rows(&[&[1., 1.], &[2., 2.], &[3., 3.]]);
        let sets: Vec<&[usize]> = vec![&[2], &[0, 1]];
        let (s, m) = pack_sets(&g, &sets, 3, 2, 3);
        // set 0 slot 0 = row 2
        assert_eq!(&s[0..3], &[3., 3., 0.]);
        // set 0 slot 1 empty
        assert_eq!(&s[3..6], &[0., 0., 0.]);
        // set 1 slots = rows 0, 1
        assert_eq!(&s[6..9], &[1., 1., 0.]);
        assert_eq!(&s[9..12], &[2., 2., 0.]);
        assert_eq!(m, vec![1., 0., 1., 1., 0., 0.]);
    }

    #[test]
    #[should_panic(expected = "larger than k bucket")]
    fn pack_sets_rejects_oversized() {
        let g = Matrix::from_rows(&[&[1.], &[2.], &[3.]]);
        let sets: Vec<&[usize]> = vec![&[0, 1, 2]];
        pack_sets(&g, &sets, 1, 2, 1);
    }
}
