//! The accelerated EBC evaluation engine — the Rust face of the paper's
//! contribution. It drives the AOT-compiled Pallas/JAX work-matrix
//! graphs through PJRT, with the paper's memory discipline:
//!
//! * ground set uploaded **once** per bucket ([`dataset::DeviceDataset`]);
//! * per-call payload (candidate batch / packed evaluation-set matrix)
//!   shipped in a single transfer each (paper §4.2 Memory Layout);
//! * all shapes padded + masked to fixed buckets ([`tiling`]);
//! * precision selectable per engine: f32 or bf16 (the paper's FP32/FP16
//!   axis, DESIGN.md §4);
//! * optionally bucket selection pinned by a fleet [`plan::ShardPlan`],
//!   so all P shard oracles of a sharded run execute the same loaded
//!   executables instead of re-picking buckets per shard.
//!
//! [`XlaOracle`] adapts the engine to the [`crate::submodular::Oracle`]
//! trait so every optimizer in [`crate::optim`] runs on it unchanged.
//! When the engine cannot serve a call (no bucket fits, runtime error),
//! it degrades to the dataset's cached CPU-fallback evaluator instead of
//! panicking — a dead PJRT backend must not kill shard pool workers.

pub mod dataset;
pub mod plan;
pub mod tiling;

pub use crate::linalg::gemm::CpuKernel;
pub use crate::runtime::artifact::{KernelImpl, Precision};
pub use dataset::DeviceDataset;
pub use plan::{plan_cpu_split, OracleSpec, PlanRequest, PlanSource, ShardPlan};

use crate::linalg::{Matrix, SharedMatrix};
use crate::obs;
use crate::runtime::artifact::ArtifactEntry;
use crate::runtime::Runtime;
use crate::submodular::Oracle;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use tiling::{mask, pad_matrix, pad_vec, pack_sets};

fn gains_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::ENGINE_GAINS_SECONDS, "engine gains graph execution latency (seconds)")
    })
}

fn update_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(
            obs::ENGINE_UPDATE_SECONDS,
            "engine update graph execution latency (seconds)",
        )
    })
}

fn eval_sets_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(
            obs::ENGINE_EVAL_SETS_SECONDS,
            "engine eval_sets graph execution latency (seconds)",
        )
    })
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub precision: Precision,
    /// Fall back to the CPU evaluator when no bucket fits (otherwise error).
    pub cpu_fallback: bool,
    /// CPU kernel backend the fallback evaluator runs on (the
    /// `[engine] cpu_kernel` seam; `Blocked` = tiled Gram-matrix).
    pub cpu_kernel: CpuKernel,
    /// Ground-parallel threads for the blocked fallback kernel
    /// (0 = `default_threads()`).
    pub cpu_threads: usize,
    /// Preferred kernel implementation. `Jnp` (default) is the fused
    /// fast path on the CPU PJRT backend; `Pallas` selects the tiled
    /// TPU-shaped L1 kernels (see EXPERIMENTS.md §Perf). The manifest
    /// pick falls back to the other impl when no bucket of the
    /// preferred impl fits.
    pub kernel: KernelImpl,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            precision: Precision::F32,
            cpu_fallback: true,
            cpu_kernel: CpuKernel::Blocked,
            cpu_threads: 0,
            kernel: KernelImpl::Jnp,
        }
    }
}

/// The batched evaluation engine.
#[derive(Clone)]
pub struct Engine {
    rt: Runtime,
    cfg: EngineConfig,
    /// Fleet plan: when set, bucket selection is pinned to the plan's
    /// pre-picked entries (falling back to per-call manifest picks only
    /// for requests the plan does not cover).
    plan: Option<Arc<ShardPlan>>,
    work: Arc<AtomicU64>,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Engine {
        Engine { rt, cfg, plan: None, work: Arc::new(AtomicU64::new(0)) }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn precision(&self) -> Precision {
        self.cfg.precision
    }

    /// Pin bucket selection to a fleet plan (see [`plan::ShardPlan`]).
    pub fn set_plan(&mut self, plan: Arc<ShardPlan>) {
        self.plan = Some(plan);
    }

    pub fn plan(&self) -> Option<&ShardPlan> {
        self.plan.as_deref()
    }

    /// Override the CPU-fallback thread width (the planner's per-oracle
    /// split — see [`plan_cpu_split`]).
    pub fn set_cpu_threads(&mut self, threads: usize) {
        self.cfg.cpu_threads = threads;
    }

    /// Batched greedy marginal gains for external candidate vectors.
    ///
    /// Returns Δf(c_j | S) for each row of `cands` given the state
    /// `mindist` over `ds`'s ground set.
    pub fn gains(
        &self,
        ds: &mut DeviceDataset,
        mindist: &[f32],
        cands: &Matrix,
    ) -> Result<Vec<f32>> {
        let (n, d, c) = (ds.n(), ds.d(), cands.rows());
        assert_eq!(mindist.len(), n);
        assert_eq!(cands.cols(), d);
        let planned: Option<ArtifactEntry> = self
            .plan
            .as_ref()
            .and_then(|p| p.gains_entry(n, d, c, self.cfg.precision))
            .cloned();
        let entry = match planned.or_else(|| {
            self.rt
                .manifest()
                .pick_gains(n, d, c, self.cfg.precision, self.cfg.kernel)
                .cloned()
        }) {
            Some(e) => e,
            None => {
                // candidate batch exceeds every C bucket: chunk it over
                // the widest-C bucket that fits (n, d) — the planned one
                // first, so a planned run never loads extra executables
                let largest = self
                    .plan
                    .as_ref()
                    .and_then(|p| p.gains_chunk_entry(n, d, self.cfg.precision))
                    .cloned()
                    .or_else(|| {
                        self.rt
                            .manifest()
                            .pick_gains_largest_c(n, d, self.cfg.precision, self.cfg.kernel)
                            .cloned()
                    });
                // a 0-wide C bucket is malformed and cannot chunk
                let largest = largest.filter(|e| e.c > 0);
                let Some(largest) = largest else {
                    if self.cfg.cpu_fallback {
                        log::warn!(
                            "gains: no bucket fits (n={n}, d={d}, c={c}); CPU fallback \
                             ({} kernel)",
                            self.cfg.cpu_kernel.name()
                        );
                        return Ok(ds.fallback_gains(&self.cfg, mindist, cands));
                    }
                    return Err(anyhow!("no gains bucket fits (n={n}, d={d}, c={c})"));
                };
                let mut out = Vec::with_capacity(c);
                let idx: Vec<usize> = (0..c).collect();
                for chunk in idx.chunks(largest.c) {
                    let sub = cands.gather(chunk);
                    out.extend(self.gains(ds, mindist, &sub)?);
                }
                return Ok(out);
            }
        };
        let graph = self.rt.load(&entry)?;
        let gb = ds.buffers(&self.rt, entry.n, entry.d)?;

        let _span = obs::span("engine.gains");
        let out = gains_hist().time(|| -> Result<_> {
            let mind_b = self.rt.upload(&pad_vec(mindist, entry.n, 0.0), &[entry.n])?;
            let c_b = self
                .rt
                .upload(&pad_matrix(cands, entry.c, entry.d), &[entry.c, entry.d])?;
            let cmask_b = self.rt.upload(&mask(c, entry.c), &[entry.c])?;
            let outs = graph
                .execute_buffers(&[&gb.v, &gb.vsq, &gb.vmask, &mind_b, &c_b, &cmask_b])?;
            Ok(outs[0].to_vec::<f32>()?)
        })?;
        self.work.fetch_add((n * c) as u64, Ordering::Relaxed);
        Ok(out[..c].to_vec())
    }

    /// d²(v_i, s) for every ground vector (one column of the distance
    /// matrix) — implemented as `update` with mindist = +BIG.
    pub fn dist_col_vec(&self, ds: &mut DeviceDataset, s: &[f32]) -> Result<Vec<f32>> {
        let (nm, _f) = self.update_inner(ds, None, s)?;
        Ok(nm)
    }

    /// Fold a selected exemplar into the state on-device:
    /// returns (new mindist, new f value).
    pub fn update(
        &self,
        ds: &mut DeviceDataset,
        mindist: &[f32],
        s: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.update_inner(ds, Some(mindist), s)
    }

    fn update_inner(
        &self,
        ds: &mut DeviceDataset,
        mindist: Option<&[f32]>,
        s: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        let (n, d) = (ds.n(), ds.d());
        assert_eq!(s.len(), d);
        let planned: Option<ArtifactEntry> = self
            .plan
            .as_ref()
            .and_then(|p| p.update_entry(n, d, self.cfg.precision))
            .cloned();
        let entry = match planned
            .or_else(|| self.rt.manifest().pick_update(n, d, self.cfg.precision).cloned())
        {
            Some(e) => e,
            None if self.cfg.cpu_fallback => {
                log::warn!(
                    "update: no bucket fits (n={n}, d={d}); CPU fallback ({} kernel)",
                    self.cfg.cpu_kernel.name()
                );
                return Ok(ds.fallback_update(&self.cfg, mindist, s));
            }
            None => return Err(anyhow!("no update bucket fits (n={n}, d={d})")),
        };
        let graph = self.rt.load(&entry)?;
        let gb = ds.buffers(&self.rt, entry.n, entry.d)?;

        let _span = obs::span("engine.update");
        let (nm, f) = update_hist().time(|| -> Result<_> {
            let s_b = self.rt.upload(&pad_vec(s, entry.d, 0.0), &[entry.d])?;
            let outs = match mindist {
                Some(md) => {
                    assert_eq!(md.len(), n);
                    let mind_b = self.rt.upload(&pad_vec(md, entry.n, 0.0), &[entry.n])?;
                    graph.execute_buffers(&[&gb.v, &gb.vsq, &gb.vmask, &mind_b, &s_b])?
                }
                // +BIG state: output column == raw distances
                None => graph.execute_buffers(&[&gb.v, &gb.vsq, &gb.vmask, &gb.big, &s_b])?,
            };
            let nm = outs[0].to_vec::<f32>()?;
            let f = outs[1].to_vec::<f32>()?[0];
            Ok((nm, f))
        })?;
        self.work.fetch_add(n as u64, Ordering::Relaxed);
        Ok((nm[..n].to_vec(), f))
    }

    /// Work-matrix evaluation of many sets at once (paper Algorithm 2):
    /// EBC values f(S_j) for sets of ground-row indices.
    pub fn eval_sets(&self, ds: &mut DeviceDataset, sets: &[&[usize]]) -> Result<Vec<f32>> {
        let (n, d) = (ds.n(), ds.d());
        let l = sets.len();
        let kmax = sets.iter().map(|s| s.len()).max().unwrap_or(0).max(1);
        let planned: Option<ArtifactEntry> = self
            .plan
            .as_ref()
            .and_then(|p| p.eval_multi_entry(l, kmax, n, d, self.cfg.precision))
            .cloned();
        let entry = match planned.or_else(|| {
            self.rt
                .manifest()
                .pick_eval_multi(l, kmax, n, d, self.cfg.precision, self.cfg.kernel)
                .cloned()
        }) {
            Some(e) => e,
            None if self.cfg.cpu_fallback => {
                log::warn!(
                    "eval_sets: no bucket fits (l={l}, k={kmax}, n={n}, d={d}); CPU fallback \
                     ({} kernel)",
                    self.cfg.cpu_kernel.name()
                );
                return Ok(ds.fallback_eval_sets(&self.cfg, sets));
            }
            None => return Err(anyhow!("no eval_multi bucket fits (l={l}, k={kmax})")),
        };
        let graph = self.rt.load(&entry)?;
        // pack before taking the ground-buffer borrow
        let (s_flat, smask) = pack_sets(ds.ground(), sets, entry.l, entry.k, entry.d);
        let gb = ds.buffers(&self.rt, entry.n, entry.d)?;

        let _span = obs::span("engine.eval_sets");
        let out = eval_sets_hist().time(|| -> Result<_> {
            let s_b = self.rt.upload(&s_flat, &[entry.l * entry.k, entry.d])?;
            let smask_b = self.rt.upload(&smask, &[entry.l * entry.k])?;
            let outs = graph.execute_buffers(&[&gb.v, &gb.vsq, &gb.vmask, &s_b, &smask_b])?;
            Ok(outs[0].to_vec::<f32>()?)
        })?;
        self.work
            .fetch_add((n * sets.iter().map(|s| s.len()).sum::<usize>()) as u64, Ordering::Relaxed);
        Ok(out[..l].to_vec())
    }

    pub fn work_counter(&self) -> u64 {
        self.work.load(Ordering::Relaxed)
    }
}

/// [`Oracle`] adapter: optimizers drive the engine exactly like the CPU
/// baselines. Holds the dataset + a CPU mirror for index gathering.
///
/// Engine errors degrade this oracle to the dataset's cached CPU
/// fallback (same kernel/precision config) instead of panicking — a
/// panicking oracle would kill a shard pool worker mid–fleet query.
pub struct XlaOracle {
    engine: Engine,
    ds: DeviceDataset,
    /// Whether the degradation warning has fired for this oracle.
    degraded: bool,
}

impl XlaOracle {
    pub fn new(engine: Engine, v: Matrix) -> XlaOracle {
        Self::from_shared(engine, Arc::new(v))
    }

    /// Build over a shared ground handle (no matrix copy).
    pub fn from_shared(engine: Engine, v: SharedMatrix) -> XlaOracle {
        XlaOracle { ds: DeviceDataset::from_shared(v), engine, degraded: false }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn dataset(&mut self) -> &mut DeviceDataset {
        &mut self.ds
    }

    fn note_degraded(&mut self, op: &str, e: &anyhow::Error) {
        if self.degraded {
            log::debug!("engine {op} failed ({e:#}); serving from the CPU fallback");
        } else {
            self.degraded = true;
            log::warn!(
                "engine {op} failed ({e:#}); degrading this oracle to the CPU {} fallback",
                self.engine.cfg.cpu_kernel.name()
            );
        }
    }
}

impl Oracle for XlaOracle {
    fn n(&self) -> usize {
        self.ds.n()
    }
    fn dim(&self) -> usize {
        self.ds.d()
    }
    fn vsq(&self) -> &[f32] {
        self.ds.vsq()
    }

    fn gains(&mut self, mindist: &[f32], cands: &[usize]) -> Vec<f32> {
        let cmat = self.ds.ground().gather(cands);
        match self.engine.gains(&mut self.ds, mindist, &cmat) {
            Ok(g) => g,
            Err(e) => {
                self.note_degraded("gains", &e);
                self.ds.cpu_fallback(&self.engine.cfg).gains(mindist, cands)
            }
        }
    }

    fn dist_col(&mut self, j: usize) -> Vec<f32> {
        let s = self.ds.ground().row(j).to_vec();
        match self.engine.dist_col_vec(&mut self.ds, &s) {
            Ok(col) => col,
            Err(e) => {
                self.note_degraded("dist_col", &e);
                self.ds.cpu_fallback(&self.engine.cfg).dist_col(j)
            }
        }
    }

    fn eval_sets(&mut self, sets: &[&[usize]]) -> Vec<f32> {
        match self.engine.eval_sets(&mut self.ds, sets) {
            Ok(v) => v,
            Err(e) => {
                self.note_degraded("eval_sets", &e);
                self.ds.cpu_fallback(&self.engine.cfg).eval_sets_st(sets)
            }
        }
    }

    fn work_counter(&self) -> u64 {
        self.engine.work_counter() + self.ds.cpu_fallback_work()
    }
}
