//! Device-resident ground set.
//!
//! The paper (§4.2 Memory Layout): *"Since the ground matrix never
//! changes between different function evaluations it is copied to the
//! GPU's global memory on algorithm initialization."* — here: the padded
//! V / vsq / vmask trio is uploaded once per bucket shape and cached;
//! every subsequent call only transfers the per-call payload (mindist,
//! candidates or packed sets). The host copy is a [`SharedMatrix`], so
//! oracles built from the same dataset (merge stage, baseline, fleet
//! queries) alias one allocation, and the CPU-fallback evaluator built
//! from it shares the ground matrix too.

use crate::engine::tiling::{mask, pad_matrix, pad_vec};
use crate::engine::EngineConfig;
use crate::linalg::{sq_norms, Matrix, SharedMatrix};
use crate::runtime::xla;
use crate::runtime::Runtime;
use crate::submodular::{f_from_mindist, EbcFunction};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Ground-set buffers for one (n_pad, d_pad) bucket.
pub struct GroundBuffers {
    pub v: xla::PjRtBuffer,
    pub vsq: xla::PjRtBuffer,
    pub vmask: xla::PjRtBuffer,
    /// mindist column pre-filled with +BIG — reused by dist-column calls.
    pub big: xla::PjRtBuffer,
}

/// A dataset registered with the engine: host copy + per-bucket device
/// buffer cache.
pub struct DeviceDataset {
    v: SharedMatrix,
    vsq: Vec<f32>,
    buffers: HashMap<(usize, usize), GroundBuffers>,
    /// Lazily-built CPU evaluator for the engine's fallback path —
    /// cached so repeated fallback calls don't redo the O(n·d) norms /
    /// bf16-demotion setup (the ground matrix itself is aliased, never
    /// copied).
    fallback: Option<EbcFunction>,
    pub upload_bytes: u64,
}

pub const BIG: f32 = 1e30;

impl DeviceDataset {
    pub fn new(v: Matrix) -> DeviceDataset {
        Self::from_shared(Arc::new(v))
    }

    /// Build over a shared ground handle (no matrix copy).
    pub fn from_shared(v: SharedMatrix) -> DeviceDataset {
        let vsq = sq_norms(v.data(), v.cols());
        DeviceDataset { v, vsq, buffers: HashMap::new(), fallback: None, upload_bytes: 0 }
    }

    pub fn n(&self) -> usize {
        self.v.rows()
    }
    pub fn d(&self) -> usize {
        self.v.cols()
    }
    pub fn ground(&self) -> &Matrix {
        &self.v
    }
    pub fn vsq(&self) -> &[f32] {
        &self.vsq
    }

    /// Get (uploading on first use) the ground buffers for a bucket.
    pub fn buffers(&mut self, rt: &Runtime, n_pad: usize, d_pad: usize) -> Result<&GroundBuffers> {
        if !self.buffers.contains_key(&(n_pad, d_pad)) {
            let vp = pad_matrix(&self.v, n_pad, d_pad);
            let vsqp = pad_vec(&self.vsq, n_pad, 0.0);
            let vmaskp = mask(self.n(), n_pad);
            let bigp = vec![BIG; n_pad];
            let gb = GroundBuffers {
                v: rt.upload(&vp, &[n_pad, d_pad])?,
                vsq: rt.upload(&vsqp, &[n_pad])?,
                vmask: rt.upload(&vmaskp, &[n_pad])?,
                big: rt.upload(&bigp, &[n_pad])?,
            };
            self.upload_bytes += 4 * (vp.len() + vsqp.len() + vmaskp.len() + bigp.len()) as u64;
            log::debug!(
                "dataset: uploaded ground bucket ({n_pad}, {d_pad}) = {:.1} MB",
                4.0 * vp.len() as f64 / 1e6
            );
            self.buffers.insert((n_pad, d_pad), gb);
        }
        Ok(self.buffers.get(&(n_pad, d_pad)).unwrap())
    }

    /// Number of distinct bucket uploads so far.
    pub fn bucket_count(&self) -> usize {
        self.buffers.len()
    }

    /// Get (building on first use) the CPU fallback evaluator on the
    /// engine's configured `cpu_kernel`/`cpu_threads`/precision.
    pub fn cpu_fallback(&mut self, cfg: &EngineConfig) -> &EbcFunction {
        if self.fallback.is_none() {
            self.fallback = Some(EbcFunction::with_kernel_shared(
                Arc::clone(&self.v),
                cfg.cpu_kernel,
                cfg.precision,
                cfg.cpu_threads,
            ));
        }
        self.fallback.as_ref().expect("just built")
    }

    /// CPU-fallback marginal gains for external candidate rows — the
    /// host mirror of the engine's `gains` graph, used when no bucket
    /// fits and `cpu_fallback` is enabled.
    pub fn fallback_gains(
        &mut self,
        cfg: &EngineConfig,
        mindist: &[f32],
        cands: &Matrix,
    ) -> Vec<f32> {
        self.cpu_fallback(cfg).gains_external(mindist, cands)
    }

    /// CPU-fallback state update for an external exemplar vector `s`:
    /// returns (new mindist, new f) exactly like the engine's `update`
    /// graph — `mindist = None` reproduces the +BIG dist-column case.
    pub fn fallback_update(
        &mut self,
        cfg: &EngineConfig,
        mindist: Option<&[f32]>,
        s: &[f32],
    ) -> (Vec<f32>, f32) {
        let dcol = self.cpu_fallback(cfg).dist_col_external(s);
        let nm: Vec<f32> = match mindist {
            Some(md) => md.iter().zip(&dcol).map(|(&m, &d)| m.min(d)).collect(),
            None => dcol,
        };
        let f = f_from_mindist(&self.vsq, &nm);
        (nm, f)
    }

    /// CPU-fallback multi-set evaluation (paper Algorithm 2 on the host).
    pub fn fallback_eval_sets(&mut self, cfg: &EngineConfig, sets: &[&[usize]]) -> Vec<f32> {
        self.cpu_fallback(cfg).eval_sets_st(sets)
    }

    /// Distance work the CPU fallback evaluator has performed (0 if the
    /// fallback was never built) — folded into the oracle work counter
    /// so degraded calls still account their evaluations.
    pub fn cpu_fallback_work(&self) -> u64 {
        self.fallback.as_ref().map(|f| f.work_counter()).unwrap_or(0)
    }
}
