//! Fleet-wide execution planning for the sharded pipeline.
//!
//! The paper's memory discipline (§4.2) uploads the ground set once per
//! padded bucket and reuses compiled work-matrix graphs. A sharded run
//! used to defeat this: each of the P shard oracles re-picked its own
//! padding bucket from the manifest and compiled/loaded executables
//! independently, even though shards are near-equal sized — and on the
//! CPU side every shard worker span its own `default_threads()`-wide
//! ground-parallel kernel, oversubscribing the machine P-fold.
//!
//! [`ShardPlan`] fixes both axes up front, once per (n, d, P) window
//! shape:
//!
//! * **buckets** — one gains/update/eval_multi bucket each, picked for
//!   the *maximum* shape any stage requests (the merge stage's full
//!   (n, d) dominates every shard), so all P shard oracles and the
//!   merge oracle execute the same compiled graphs
//!   ([`crate::runtime::Manifest::pick_for_max_shape`]);
//! * **CPU split** — P shard workers × T ground-parallel kernel threads
//!   with P·T ≤ cores ([`plan_cpu_split`]), instead of P independent
//!   `default_threads()`-wide oracles.
//!
//! The plan travels through the oracle-factory seam as part of an
//! [`OracleSpec`]: the factory hands it to engine oracles
//! ([`crate::engine::Engine::set_plan`]) and resolves the per-oracle
//! thread width from it, so the summarizer stays backend-agnostic.

use crate::linalg::gemm::CpuKernel;
use crate::runtime::artifact::{KernelImpl, PlanBuckets, Precision};
use crate::runtime::Manifest;
use crate::util::threadpool::default_threads;
use std::sync::Arc;

/// Inputs to fleet planning: the window shape, the shard count and the
/// knobs that select executables.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    /// Full ground-set rows (the merge stage's — and therefore the
    /// maximum — evaluation shape).
    pub n: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Shard count P.
    pub shards: usize,
    /// Summary cardinality k (sizes the eval_multi bucket).
    pub k: usize,
    /// Candidate-batch cap (sizes the gains bucket's C axis).
    pub batch: usize,
    pub precision: Precision,
    pub kernel: KernelImpl,
    /// CPU kernel backend the fallback/CPU oracles run on.
    pub cpu_kernel: CpuKernel,
    /// Core budget for the whole fleet run (0 = `default_threads()`).
    pub cores: usize,
    /// Fraction of each shard's ground sieved away before stage 1
    /// (see [`crate::prune`]); shrinks the shapes oracles will actually
    /// evaluate, so buckets can be picked tighter. 0 = off.
    pub prune_rate: f64,
    /// Ground-row cap per merge node of a hierarchical run (0 = none):
    /// no merge oracle ever sees more rows than this.
    pub max_merge_n: usize,
}

impl PlanRequest {
    pub fn new(n: usize, d: usize, shards: usize, k: usize) -> PlanRequest {
        PlanRequest {
            n,
            d,
            shards,
            k,
            batch: 1024,
            precision: Precision::F32,
            kernel: KernelImpl::Jnp,
            cpu_kernel: CpuKernel::Blocked,
            cores: 0,
            prune_rate: 0.0,
            max_merge_n: 0,
        }
    }

    /// Rows the largest post-prune evaluation shape can reach: pruning
    /// keeps ⌈(1−rate)·n⌉ survivors of the full union, and a merge cap
    /// bounds every merge oracle below `max_merge_n` (stage-1 shards are
    /// smaller still). Plain `n` when both knobs are off.
    pub fn effective_n(&self) -> usize {
        let mut n_eff = if self.prune_rate > 0.0 && self.prune_rate < 1.0 {
            ((self.n as f64) * (1.0 - self.prune_rate)).ceil() as usize
        } else {
            self.n
        };
        if self.max_merge_n > 0 {
            n_eff = n_eff.min(self.max_merge_n);
        }
        n_eff.clamp(1, self.n.max(1))
    }
}

/// Split a core budget over P shard workers: `(workers, threads)` with
/// `workers · threads <= cores`, `workers = min(P, cores)` and each
/// worker's ground-parallel kernel `threads = cores / workers` wide.
pub fn plan_cpu_split(shards: usize, cores: usize) -> (usize, usize) {
    let cores = cores.max(1);
    let workers = shards.max(1).min(cores);
    (workers, (cores / workers).max(1))
}

/// The fleet-wide execution plan: one bucket shape + one CPU split,
/// shared by every shard oracle and the merge stage of a sharded run.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub n: usize,
    /// Post-prune maximum evaluation rows the buckets were picked for
    /// (= `n` with every prune knob off — see
    /// [`PlanRequest::effective_n`]).
    pub n_eff: usize,
    pub d: usize,
    pub shards: usize,
    pub k: usize,
    pub precision: Precision,
    pub kernel: KernelImpl,
    pub cpu_kernel: CpuKernel,
    /// Resolved core budget.
    pub cores: usize,
    /// Concurrent shard workers in stage 1 (≤ cores).
    pub shard_workers: usize,
    /// Ground-parallel kernel threads per shard oracle
    /// (shard_workers · oracle_threads ≤ cores).
    pub oracle_threads: usize,
    /// Kernel threads for the merge/baseline oracle (runs alone, so it
    /// gets the whole budget).
    pub merge_threads: usize,
    /// Pre-picked manifest buckets (empty when planning for a CPU-only
    /// backend — no manifest to pick from).
    pub buckets: PlanBuckets,
}

impl ShardPlan {
    /// Build the plan. `manifest` is the engine's artifact index when
    /// the run targets the XLA backend; `None` plans the CPU split only.
    pub fn plan(manifest: Option<&Manifest>, req: &PlanRequest) -> ShardPlan {
        let cores = if req.cores == 0 { default_threads() } else { req.cores };
        let (shard_workers, oracle_threads) = plan_cpu_split(req.shards, cores);
        // the merge stage evaluates against the full ground set, and the
        // largest shard holds at most n rows — one (n, d)-fitting shape
        // therefore serves every stage. Prune/cap knobs shrink that
        // maximum ([`PlanRequest::effective_n`]), so pruned fleets pick
        // tighter buckets; a full-n baseline pass of such a run falls
        // back to chunking instead.
        let n_eff = req.effective_n();
        let c = req.batch.min(n_eff).max(1);
        let buckets = manifest
            .map(|m| {
                m.pick_for_max_shape(n_eff, req.d, c, 1, req.k.max(1), req.precision, req.kernel)
            })
            .unwrap_or_default();
        ShardPlan {
            n: req.n,
            n_eff,
            d: req.d,
            shards: req.shards.max(1),
            k: req.k,
            precision: req.precision,
            kernel: req.kernel,
            cpu_kernel: req.cpu_kernel,
            cores,
            shard_workers,
            oracle_threads,
            merge_threads: cores,
            buckets,
        }
    }

    /// Planned gains bucket, if it fits a (n, d, c) request at `p`.
    pub fn gains_entry(
        &self,
        n: usize,
        d: usize,
        c: usize,
        p: Precision,
    ) -> Option<&crate::runtime::ArtifactEntry> {
        self.buckets
            .gains
            .as_ref()
            .filter(|e| e.precision == p && e.n >= n && e.d >= d && e.c >= c)
    }

    /// Planned gains bucket for chunking oversized candidate batches
    /// (must fit (n, d); the engine slices the batch to its C).
    pub fn gains_chunk_entry(
        &self,
        n: usize,
        d: usize,
        p: Precision,
    ) -> Option<&crate::runtime::ArtifactEntry> {
        self.buckets
            .gains
            .as_ref()
            .filter(|e| e.precision == p && e.n >= n && e.d >= d)
    }

    /// Planned update bucket, if it fits (n, d) at `p`.
    pub fn update_entry(
        &self,
        n: usize,
        d: usize,
        p: Precision,
    ) -> Option<&crate::runtime::ArtifactEntry> {
        self.buckets
            .update
            .as_ref()
            .filter(|e| e.precision == p && e.n >= n && e.d >= d)
    }

    /// Planned eval_multi bucket, if it fits (l, k, n, d) at `p`.
    pub fn eval_multi_entry(
        &self,
        l: usize,
        k: usize,
        n: usize,
        d: usize,
        p: Precision,
    ) -> Option<&crate::runtime::ArtifactEntry> {
        self.buckets
            .eval_multi
            .as_ref()
            .filter(|e| e.precision == p && e.l >= l && e.k >= k && e.n >= n && e.d >= d)
    }

    /// One-line human description for `shard-bench --plan` and the
    /// coordinator log.
    pub fn describe(&self) -> String {
        let bucket = |e: &Option<crate::runtime::ArtifactEntry>| -> String {
            match e {
                Some(e) => format!("{} ({}x{})", e.name, e.n, e.d),
                None => "-".to_string(),
            }
        };
        let eff = if self.n_eff < self.n {
            format!(" (pruned eval <= {} rows)", self.n_eff)
        } else {
            String::new()
        };
        format!(
            "window {}x{}{eff} P={} k={}: split {}w x {}t (merge {}t, cores {}), \
             cpu_kernel {}, buckets gains={} update={} eval_multi={}",
            self.n,
            self.d,
            self.shards,
            self.k,
            self.shard_workers,
            self.oracle_threads,
            self.merge_threads,
            self.cores,
            self.cpu_kernel.name(),
            bucket(&self.buckets.gains),
            bucket(&self.buckets.update),
            bucket(&self.buckets.eval_multi),
        )
    }

    /// Compact split label for bench tables, e.g. `4w x 2t`.
    pub fn split_label(&self) -> String {
        format!("{}w x {}t", self.shard_workers, self.oracle_threads)
    }
}

/// Per-oracle build context handed through the oracle-factory seam: the
/// factory captures the backend (runtime / kernel / precision), the
/// spec carries what varies per oracle inside one fleet run.
#[derive(Clone, Default)]
pub struct OracleSpec {
    /// Kernel-thread override for this oracle (None = the factory's
    /// configured default — legacy unplanned behavior).
    pub threads: Option<usize>,
    /// Fleet plan: engine oracles adopt its pre-picked buckets so all
    /// shards execute the same loaded graphs.
    pub plan: Option<Arc<ShardPlan>>,
}

impl OracleSpec {
    /// Legacy behavior: factory defaults, no plan.
    pub fn unplanned() -> OracleSpec {
        OracleSpec::default()
    }

    /// Spec for a stage-1 shard oracle of a planned run.
    pub fn for_shard(plan: &Arc<ShardPlan>) -> OracleSpec {
        OracleSpec { threads: Some(plan.oracle_threads), plan: Some(Arc::clone(plan)) }
    }

    /// Spec for the merge/baseline oracle of a planned run (full-budget
    /// threads; same shared buckets).
    pub fn for_merge(plan: &Arc<ShardPlan>) -> OracleSpec {
        OracleSpec { threads: Some(plan.merge_threads), plan: Some(Arc::clone(plan)) }
    }

    /// Resolve the thread width against a factory default.
    pub fn threads_or(&self, default: usize) -> usize {
        self.threads.unwrap_or(default)
    }
}

/// Boxed plan-builder seam: maps a window-shape request to a plan. The
/// launcher builds one per backend (the XLA variant captures the
/// runtime's manifest) and hands it to the coordinator, which caches
/// one plan per (n, d, P) window shape.
pub type PlanSource = Box<dyn Fn(&PlanRequest) -> Arc<ShardPlan> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    const MANIFEST: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "gains_small", "file": "a.hlo.txt", "kind": "gains",
         "dtype": "f32", "n": 256, "d": 64, "c": 128, "l": 0, "k": 0,
         "inputs": ["v","vsq","vmask","mindist","c","cmask"]},
        {"name": "gains_big", "file": "b.hlo.txt", "kind": "gains",
         "dtype": "f32", "n": 4096, "d": 128, "c": 1024, "l": 0, "k": 0,
         "inputs": ["v","vsq","vmask","mindist","c","cmask"]},
        {"name": "update_big", "file": "c.hlo.txt", "kind": "update",
         "dtype": "f32", "n": 4096, "d": 128, "c": 0, "l": 0, "k": 0,
         "inputs": ["v","vsq","vmask","mindist","s"]},
        {"name": "eval_big", "file": "d.hlo.txt", "kind": "eval_multi",
         "dtype": "f32", "n": 4096, "d": 128, "c": 0, "l": 64, "k": 16,
         "inputs": ["v","vsq","vmask","s_flat","smask_flat"]}
      ]
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse(MANIFEST, PathBuf::from("/tmp/plan")).unwrap()
    }

    #[test]
    fn cpu_split_never_oversubscribes() {
        for shards in [1usize, 2, 3, 7, 8, 100] {
            for cores in [1usize, 2, 4, 7, 8, 64] {
                let (w, t) = plan_cpu_split(shards, cores);
                assert!(w >= 1 && t >= 1, "P={shards} cores={cores}");
                assert!(w * t <= cores, "P={shards} cores={cores}: {w}x{t}");
                assert_eq!(w, shards.min(cores), "P={shards} cores={cores}");
            }
        }
    }

    #[test]
    fn plan_picks_one_bucket_covering_merge_and_shards() {
        let m = manifest();
        let mut req = PlanRequest::new(3000, 100, 8, 10);
        req.cores = 8;
        let plan = ShardPlan::plan(Some(&m), &req);
        // the merge stage (full n) and every shard (n_shard <= n) fit
        let g = plan.buckets.gains.as_ref().expect("gains bucket");
        assert_eq!(g.name, "gains_big");
        assert!(g.n >= req.n && g.d >= req.d);
        assert_eq!(plan.buckets.update.as_ref().unwrap().name, "update_big");
        assert_eq!(plan.buckets.eval_multi.as_ref().unwrap().name, "eval_big");
        // CPU split: 8 workers x 1 thread on an 8-core budget
        assert_eq!((plan.shard_workers, plan.oracle_threads), (8, 1));
        assert_eq!(plan.merge_threads, 8);
        // entry lookups honor fit + precision
        assert!(plan.gains_entry(3000, 100, 512, Precision::F32).is_some());
        assert!(plan.gains_entry(3000, 100, 512, Precision::Bf16).is_none());
        assert!(plan.gains_entry(5000, 100, 512, Precision::F32).is_none());
        assert!(plan.update_entry(4096, 128, Precision::F32).is_some());
        assert!(plan.eval_multi_entry(64, 16, 3000, 100, Precision::F32).is_some());
        assert!(plan.eval_multi_entry(65, 16, 3000, 100, Precision::F32).is_none());
    }

    #[test]
    fn plan_without_manifest_is_cpu_split_only() {
        let mut req = PlanRequest::new(1000, 16, 3, 5);
        req.cores = 12;
        let plan = ShardPlan::plan(None, &req);
        assert!(plan.buckets.gains.is_none());
        assert!(plan.buckets.update.is_none());
        assert_eq!((plan.shard_workers, plan.oracle_threads), (3, 4));
        assert_eq!(plan.merge_threads, 12);
        assert!(plan.describe().contains("3w x 4t"));
        assert!(plan.describe().contains("cpu_kernel blocked"));

        let mut req = PlanRequest::new(1000, 16, 3, 5);
        req.cores = 12;
        req.cpu_kernel = CpuKernel::Simd;
        let plan = ShardPlan::plan(None, &req);
        assert!(plan.describe().contains("cpu_kernel simd"));
    }

    #[test]
    fn oracle_spec_carries_split() {
        let mut req = PlanRequest::new(100, 4, 2, 3);
        req.cores = 4;
        let plan = Arc::new(ShardPlan::plan(None, &req));
        let shard = OracleSpec::for_shard(&plan);
        assert_eq!(shard.threads, Some(2));
        assert!(shard.plan.is_some());
        let merge = OracleSpec::for_merge(&plan);
        assert_eq!(merge.threads, Some(4));
        assert_eq!(OracleSpec::unplanned().threads_or(7), 7);
        assert_eq!(shard.threads_or(7), 2);
    }

    #[test]
    fn pruned_plan_picks_tighter_buckets() {
        let m = manifest();
        let mut req = PlanRequest::new(3000, 60, 4, 10);
        req.batch = 100;
        assert_eq!(req.effective_n(), 3000);
        let full = ShardPlan::plan(Some(&m), &req);
        assert_eq!(full.n_eff, 3000);
        assert_eq!(full.buckets.gains.as_ref().unwrap().name, "gains_big");

        // sieving 95% away shrinks the max evaluation shape into the
        // small bucket
        req.prune_rate = 0.95;
        assert_eq!(req.effective_n(), 150);
        let pruned = ShardPlan::plan(Some(&m), &req);
        assert_eq!(pruned.n_eff, 150);
        assert_eq!(pruned.n, 3000);
        assert_eq!(pruned.buckets.gains.as_ref().unwrap().name, "gains_small");
        assert!(pruned.describe().contains("pruned eval <= 150 rows"));

        // a merge cap composes the same way
        req.prune_rate = 0.0;
        req.max_merge_n = 200;
        assert_eq!(req.effective_n(), 200);
        // both knobs: the tighter bound wins
        req.prune_rate = 0.5;
        assert_eq!(req.effective_n(), 200);
        req.max_merge_n = 2000;
        assert_eq!(req.effective_n(), 1500);
    }

    #[test]
    fn oversized_request_falls_back_to_largest_c_for_chunking() {
        let m = manifest();
        // batch wider than any C bucket: plan still pins the widest
        // (n, d)-fitting bucket so the engine chunks over it
        let mut req = PlanRequest::new(3000, 100, 4, 10);
        req.batch = 100_000;
        let plan = ShardPlan::plan(Some(&m), &req);
        let g = plan.buckets.gains.as_ref().expect("chunk bucket");
        assert_eq!(g.name, "gains_big");
        assert!(plan.gains_entry(3000, 100, 100_000, Precision::F32).is_none());
        assert!(plan.gains_chunk_entry(3000, 100, Precision::F32).is_some());
    }
}
