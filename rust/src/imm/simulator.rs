//! The melt-pressure cycle model.
//!
//! One recorded window spans injection → holding → decompression 1 →
//! plasticization → decompression 2 (the paper sequences its time series
//! with exactly these trigger signals) at [`CYCLE_SAMPLES`] samples —
//! d = 3524, the dimensionality of the paper's Fig. 3.
//!
//! Physics-inspired effects:
//! * melt **viscosity** scales the injection peak (higher viscosity →
//!   higher pressure at controlled injection speed) and stretches the
//!   **plasticization time** (the two Fig. 4 effects);
//! * **melt temperature** lowers viscosity (Arrhenius-like factor);
//! * **injection speed** raises the peak;
//! * thermal **non-equilibrium** raises effective viscosity (cold mold).

use crate::imm::parts::PartSpec;
use crate::util::rng::Rng;

/// Samples per recorded cycle window — the paper's d = 3524.
pub const CYCLE_SAMPLES: usize = 3524;

/// Per-cycle physical parameters (after all state effects are applied).
#[derive(Debug, Clone, Copy)]
pub struct CycleParams {
    /// Relative melt viscosity (1.0 = nominal).
    pub viscosity: f32,
    /// Relative injection speed (1.0 = nominal).
    pub injection_speed: f32,
    /// Relative holding pressure (1.0 = nominal).
    pub holding_factor: f32,
    /// Relative back pressure (1.0 = nominal).
    pub back_factor: f32,
}

impl Default for CycleParams {
    fn default() -> Self {
        CycleParams {
            viscosity: 1.0,
            injection_speed: 1.0,
            holding_factor: 1.0,
            back_factor: 1.0,
        }
    }
}

/// Deterministic-shape melt-pressure generator for one part.
#[derive(Debug, Clone, Copy)]
pub struct MeltPressureModel {
    pub spec: PartSpec,
    pub samples: usize,
}

impl MeltPressureModel {
    pub fn new(spec: PartSpec) -> MeltPressureModel {
        MeltPressureModel { spec, samples: CYCLE_SAMPLES }
    }

    /// Synthesize one cycle's melt-pressure curve.
    pub fn cycle(&self, p: &CycleParams, rng: &mut Rng) -> Vec<f32> {
        let s = &self.spec;
        let n = self.samples;
        let mut out = vec![0f32; n];

        // phase boundaries (plasticization stretches with viscosity)
        let n_inj = (s.t_injection * n as f32) as usize;
        let n_hold = (s.t_holding * n as f32) as usize;
        let n_dec1 = (s.t_decomp1 * n as f32) as usize;
        let plast_stretch = 0.55 + 0.45 * p.viscosity; // Fig. 4 effect #2
        let n_plast = ((s.t_plast * plast_stretch) * n as f32) as usize;

        let peak = s.peak_pressure * p.viscosity.powf(0.8) * p.injection_speed.powf(0.6);
        let hold = s.holding_pressure * p.holding_factor;
        let back = s.back_pressure * p.back_factor * p.viscosity.powf(0.3);

        let mut i = 0usize;
        // --- injection: concave ramp to the peak -------------------------
        for t in 0..n_inj {
            let x = (t + 1) as f32 / n_inj as f32;
            // filling front: pressure grows superlinearly near the end
            out[i] = peak * (0.25 * x + 0.75 * x.powi(3));
            i += 1;
        }
        // --- switchover + holding: fast settle to hold, slow decay -------
        for t in 0..n_hold {
            if i >= n {
                break;
            }
            let x = t as f32 / n_hold.max(1) as f32;
            let settle = (peak - hold) * (-14.0 * x).exp();
            out[i] = hold * (1.0 - 0.12 * x) + settle;
            i += 1;
        }
        // --- decompression 1: exponential drop to ~0 ---------------------
        let p_start = out[i.saturating_sub(1)];
        for t in 0..n_dec1 {
            if i >= n {
                break;
            }
            let x = (t + 1) as f32 / n_dec1.max(1) as f32;
            out[i] = p_start * (-7.0 * x).exp();
            i += 1;
        }
        // --- plasticization: back-pressure plateau with screw ripple -----
        let plast_end = (i + n_plast).min(n);
        let mut t = 0usize;
        while i < plast_end {
            let ripple = 1.0 + 0.05 * ((t as f32) * 0.11).sin();
            out[i] = back * ripple;
            i += 1;
            t += 1;
        }
        // --- decompression 2 + idle rest of window -----------------------
        let mut pcur = back;
        while i < n {
            pcur *= 0.97;
            out[i] = pcur;
            i += 1;
        }

        // sensor noise
        for v in out.iter_mut() {
            *v += rng.normal() * s.noise;
        }
        out
    }

    /// Peak injection pressure of a synthesized curve (diagnostics).
    pub fn peak_of(curve: &[f32]) -> f32 {
        curve.iter().cloned().fold(f32::MIN, f32::max)
    }

    /// Plasticization duration estimate: samples above 40% of back
    /// pressure after the holding phase (diagnostics for Fig. 4 checks).
    pub fn plast_samples_of(&self, curve: &[f32], params: &CycleParams) -> usize {
        let s = &self.spec;
        let start = ((s.t_injection + s.t_holding + s.t_decomp1) * self.samples as f32) as usize;
        let thresh = 0.4 * s.back_pressure * params.back_factor;
        curve[start.min(curve.len())..]
            .iter()
            .filter(|&&v| v > thresh)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imm::parts::Part;

    fn model() -> MeltPressureModel {
        MeltPressureModel::new(Part::Plate.spec())
    }

    #[test]
    fn curve_has_expected_shape() {
        let m = model();
        let mut rng = Rng::new(1);
        let c = m.cycle(&CycleParams::default(), &mut rng);
        assert_eq!(c.len(), CYCLE_SAMPLES);
        let peak = MeltPressureModel::peak_of(&c);
        // peak during injection, close to spec
        assert!((peak - m.spec.peak_pressure).abs() < 0.15 * m.spec.peak_pressure);
        // end of window near zero
        assert!(c[CYCLE_SAMPLES - 1].abs() < 50.0);
        // holding plateau is below the peak and above back pressure
        let hold_idx = ((m.spec.t_injection + 0.5 * m.spec.t_holding) * CYCLE_SAMPLES as f32) as usize;
        assert!(c[hold_idx] < peak && c[hold_idx] > m.spec.back_pressure);
    }

    #[test]
    fn viscosity_raises_peak_and_stretches_plasticization() {
        // the two Fig. 4 effects
        let m = model();
        let mut rng = Rng::new(2);
        let lo = CycleParams { viscosity: 0.8, ..Default::default() };
        let hi = CycleParams { viscosity: 1.2, ..Default::default() };
        let c_lo = m.cycle(&lo, &mut rng);
        let c_hi = m.cycle(&hi, &mut rng);
        assert!(
            MeltPressureModel::peak_of(&c_hi) > MeltPressureModel::peak_of(&c_lo) + 50.0
        );
        assert!(m.plast_samples_of(&c_hi, &hi) > m.plast_samples_of(&c_lo, &lo));
    }

    #[test]
    fn injection_speed_raises_peak() {
        let m = model();
        let mut rng = Rng::new(3);
        let slow = m.cycle(&CycleParams { injection_speed: 0.8, ..Default::default() }, &mut rng);
        let fast = m.cycle(&CycleParams { injection_speed: 1.2, ..Default::default() }, &mut rng);
        assert!(MeltPressureModel::peak_of(&fast) > MeltPressureModel::peak_of(&slow));
    }

    #[test]
    fn noise_makes_cycles_distinct_but_close() {
        let m = model();
        let mut rng = Rng::new(4);
        let a = m.cycle(&CycleParams::default(), &mut rng);
        let b = m.cycle(&CycleParams::default(), &mut rng);
        let d2: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(d2 > 0.0);
        // nominal cycles stay close relative to a viscosity shift
        let shifted = m.cycle(&CycleParams { viscosity: 1.2, ..Default::default() }, &mut rng);
        let d2_shift: f32 = a.iter().zip(&shifted).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(d2_shift > 10.0 * d2);
    }
}
