//! Injection-molding machine (IMM) process simulator — the substrate for
//! the paper's §6 case study (Table 2, Fig. 4), standing in for the
//! proprietary Weppler production data (DESIGN.md §4).
//!
//! The simulator synthesizes **melt-pressure time series** for complete
//! molding cycles — the sensor the paper selects for its analysis — and
//! reproduces each induced process state's signature:
//!
//! * **start-up**: thermal non-equilibrium decaying toward steady state;
//! * **stable**: stationary noise around the operating point;
//! * **downtimes**: stop every 100 cycles, thermal re-approach afterwards;
//! * **regrind**: material fraction stepped 0→100 % every 200 cycles,
//!   shifting melt viscosity (peak injection pressure + plasticization
//!   time — the two effects visible in the paper's Fig. 4);
//! * **DOE**: a 5-factor central composite design (2⁵ + 2·5 + 1 = 43
//!   operating points, 20 cycles each = 860 cycles, as in the paper).

pub mod casestudy;
pub mod dataset;
pub mod doe;
pub mod parts;
pub mod simulator;
pub mod states;

pub use dataset::{generate_dataset, CaseDataset};
pub use dataset::generate_dataset_with;
pub use parts::{Part, PartSpec};
pub use simulator::{CycleParams, MeltPressureModel, CYCLE_SAMPLES};
pub use states::ProcessState;
