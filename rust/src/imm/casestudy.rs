//! Case-study driver (paper §6): compute the top-k representatives for
//! every (part, process state) campaign, render Table 2, validate the
//! paper's process-knowledge expectations, and export the Fig. 4 curves.

use crate::imm::dataset::{generate_dataset_with, CaseDataset};
use crate::imm::parts::Part;
use crate::imm::simulator::CYCLE_SAMPLES;
use crate::imm::states::ProcessState;
use crate::linalg::Matrix;
use crate::optim::{Optimizer, SummaryResult};
use crate::submodular::Oracle;
use crate::util::csv::Table;

/// Representatives of one campaign.
pub struct CaseResult {
    pub part: Part,
    pub state: ProcessState,
    pub reps: Vec<usize>,
    pub f_value: f32,
    pub wall_seconds: f64,
    pub dataset: CaseDataset,
}

/// Run the optimizer on one campaign.
pub fn summarize_case(
    dataset: CaseDataset,
    optimizer: &dyn Optimizer,
    oracle_factory: &dyn Fn(Matrix) -> Box<dyn Oracle>,
    k: usize,
) -> CaseResult {
    let mut oracle = oracle_factory(dataset.cycles.clone());
    let res: SummaryResult = optimizer.run(oracle.as_mut(), k);
    CaseResult {
        part: dataset.part,
        state: dataset.state,
        reps: res.indices.clone(),
        f_value: res.f_final,
        wall_seconds: res.wall_seconds,
        dataset,
    }
}

/// Run the full Table 2 grid: 2 parts × 5 states.
pub fn run_table2(
    optimizer: &dyn Optimizer,
    oracle_factory: &dyn Fn(Matrix) -> Box<dyn Oracle>,
    k: usize,
    samples: usize,
    seed: u64,
) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for part in Part::all() {
        for state in ProcessState::all() {
            let ds = generate_dataset_with(part, state, seed, samples);
            out.push(summarize_case(ds, optimizer, oracle_factory, k));
        }
    }
    out
}

/// Render the paper's Table 2 layout: rows = representative rank,
/// columns = (part × state).
pub fn table2_text(results: &[CaseResult], k: usize) -> String {
    let mut s = String::new();
    for part in Part::all() {
        s.push_str(&format!("\n[{}]\n", part.name()));
        let cols: Vec<&CaseResult> = results.iter().filter(|r| r.part == part).collect();
        s.push_str(&format!("{:>4}", "Rep."));
        for c in &cols {
            s.push_str(&format!(" {:>16}", c.state.name()));
        }
        s.push('\n');
        for rank in 0..k {
            s.push_str(&format!("{:>4}", rank + 1));
            for c in &cols {
                match c.reps.get(rank) {
                    Some(idx) => s.push_str(&format!(" {idx:>16}")),
                    None => s.push_str(&format!(" {:>16}", "-")),
                }
            }
            s.push('\n');
        }
    }
    s
}

/// The paper's qualitative validation of Table 2 (§6). Each check
/// returns Ok or a description of the violated expectation.
pub fn validate_expectations(r: &CaseResult) -> Result<(), String> {
    let n = r.dataset.n();
    let reps = &r.reps;
    if reps.is_empty() {
        return Err("no representatives".into());
    }
    match r.state {
        ProcessState::StartUp => {
            // "the first representative is in the second half of the dataset"
            if reps[0] < n / 2 {
                return Err(format!("start-up: first rep {} in first half", reps[0]));
            }
            // "the first cycle is among the top five" — allow the first
            // ~2.5% of the run (the extreme transient)
            let lead = n / 40;
            if !reps.iter().any(|&i| i <= lead) {
                return Err(format!("start-up: no early-transient rep in top-{}: {reps:?}", reps.len()));
            }
        }
        ProcessState::Stable => {
            // "randomly distributed over the complete dataset": with pure
            // noise the positions are arbitrary; flag only clear
            // clustering (all representatives inside one quarter of the
            // run), which would hint at a flaw in the experiment — the
            // paper's own reading of this state.
            let &min = reps.iter().min().unwrap();
            let &max = reps.iter().max().unwrap();
            if max - min < n / 4 {
                return Err(format!("stable: reps clustered [{min}, {max}]"));
            }
        }
        ProcessState::Downtimes => {
            // "the first chosen representative ... is not directly after a
            // downtime" (asymptotic recovery): within 5 cycles of a stop
            let after = |i: usize| (1..=5).any(|w| i >= w && r.dataset.after_downtime[i - w + 1 - 1]);
            if r.dataset.after_downtime[reps[0]] || after(reps[0]) {
                return Err(format!("downtimes: first rep {} directly after a stop", reps[0]));
            }
        }
        ProcessState::Regrind => {
            // "four different sections represented among the top five"
            let mut secs: Vec<usize> = reps.iter().map(|&i| r.dataset.section[i]).collect();
            secs.sort_unstable();
            secs.dedup();
            if secs.len() < 4 {
                return Err(format!("regrind: only {} sections covered: {secs:?}", secs.len()));
            }
        }
        ProcessState::Doe => {
            // "the first five representatives match five distinct
            // operation points"
            let mut secs: Vec<usize> = reps.iter().map(|&i| r.dataset.section[i]).collect();
            secs.sort_unstable();
            secs.dedup();
            if secs.len() < reps.len().min(5) {
                return Err(format!("DOE: sections not distinct: {secs:?}"));
            }
        }
    }
    Ok(())
}

/// Fig. 4: melt-pressure curves of the regrind representatives for one
/// part, as a CSV (sample index + one column per representative).
pub fn fig4_table(result: &CaseResult) -> Table {
    assert_eq!(result.state, ProcessState::Regrind);
    let mut header: Vec<String> = vec!["sample".into()];
    for &rep in &result.reps {
        header.push(format!(
            "cycle_{rep}_regrind_{}pct",
            result.dataset.section[rep] * 25
        ));
    }
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr_refs);
    let d = result.dataset.cycles.cols();
    for s in 0..d {
        let mut row = vec![s.to_string()];
        for &rep in &result.reps {
            row.push(format!("{:.2}", result.dataset.cycles.row(rep)[s]));
        }
        t.push(row);
    }
    t
}

/// Default sample count for the full-fidelity case study.
pub fn full_samples() -> usize {
    CYCLE_SAMPLES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Greedy;
    use crate::submodular::CpuOracle;

    fn cpu(m: Matrix) -> Box<dyn Oracle> {
        Box::new(CpuOracle::new(m))
    }

    #[test]
    fn table2_text_renders() {
        // tiny fidelity for speed
        let results = run_table2(&Greedy { batch: 2048 }, &cpu, 2, 64, 11);
        assert_eq!(results.len(), 10);
        let text = table2_text(&results, 2);
        assert!(text.contains("[cover]"));
        assert!(text.contains("[plate]"));
        assert!(text.contains("start-up"));
    }

    #[test]
    fn fig4_table_shape() {
        let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, 3, 128);
        let res = summarize_case(ds, &Greedy { batch: 2048 }, &cpu, 3);
        let t = fig4_table(&res);
        assert_eq!(t.header.len(), 4);
        assert_eq!(t.rows.len(), 128);
    }
}
