//! Dataset generation: one recorded campaign per (part, process state),
//! with the ground-truth section structure the case-study validation
//! (Table 2 expectations) keys on.

use crate::imm::doe::central_composite;
use crate::imm::parts::Part;
use crate::imm::simulator::{CycleParams, MeltPressureModel, CYCLE_SAMPLES};
use crate::imm::states::ProcessState;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A generated campaign with its ground truth.
pub struct CaseDataset {
    pub part: Part,
    pub state: ProcessState,
    /// (cycles x samples) melt-pressure matrix.
    pub cycles: Matrix,
    /// Section id per cycle (regrind: 0..5, DOE: 0..43, others: 0).
    pub section: Vec<usize>,
    /// Cycles that directly follow a downtime (downtime state only).
    pub after_downtime: Vec<bool>,
    /// Per-cycle thermal disequilibrium (1.0 = cold start, 0 = equilibrium).
    pub thermal: Vec<f32>,
}

impl CaseDataset {
    pub fn n(&self) -> usize {
        self.cycles.rows()
    }

    /// Number of distinct sections.
    pub fn num_sections(&self) -> usize {
        self.section.iter().copied().max().unwrap_or(0) + 1
    }
}

/// Generate the campaign for (part, state) at full d = 3524.
pub fn generate_dataset(part: Part, state: ProcessState, seed: u64) -> CaseDataset {
    generate_dataset_with(part, state, seed, CYCLE_SAMPLES)
}

/// Same, with an overridable samples-per-cycle (tests use smaller d).
pub fn generate_dataset_with(
    part: Part,
    state: ProcessState,
    seed: u64,
    samples: usize,
) -> CaseDataset {
    let mut rng = Rng::new(seed ^ (part as u64) << 32 ^ (state as u64) << 40);
    let mut model = MeltPressureModel::new(part.spec());
    model.samples = samples;
    let n = state.cycles();

    let mut data = Vec::with_capacity(n * samples);
    let mut section = vec![0usize; n];
    let mut after_downtime = vec![false; n];
    let mut thermal = vec![0f32; n];

    // Thermal disequilibrium has TWO time scales (the physically observed
    // behavior of real IMMs, and what reproduces the paper's Table-2
    // start-up signature):
    //  * melt/barrel heat-up — strong but fast (tau ≈ 16 cycles): the
    //    first cycles are extreme and mutually very different;
    //  * mold heat soak — a modest near-constant offset that persists for
    //    hundreds of cycles and settles through a knee around cycle ~620
    //    (thick mold plates, slow temperature controller).
    // With squared-Euclidean EBC the first representative is the cycle
    // nearest the dataset centroid, i.e. at theta ≈ mean(theta); the knee
    // past the half-way point is exactly what places it in the second
    // half of the campaign, as the paper's experts expect.
    let startup = state == ProcessState::StartUp;
    let mut theta_melt: f32 = if startup { 0.8 } else { 0.0 };
    const MELT_DECAY: f32 = 0.94; // tau ≈ 16 cycles
    const MOLD_SOAK: f32 = 0.2;
    const MOLD_KNEE: f32 = 620.0;
    const MOLD_WIDTH: f32 = 60.0;
    const THETA_VISC: f32 = 0.45; // fully cold machine -> +45% viscosity

    let doe_points = central_composite();

    for c in 0..n {
        // --- state-dependent parameter schedule -------------------------
        let mut params = CycleParams::default();
        match state {
            ProcessState::StartUp | ProcessState::Stable => {}
            ProcessState::Downtimes => {
                if c > 0 && c % 100 == 0 {
                    // stop for a production-typical random duration;
                    // longer stop -> bigger melt-side thermal disturbance
                    let duration = rng.range_f32(0.2, 1.0);
                    theta_melt = (theta_melt + 0.35 * duration).min(1.0);
                    after_downtime[c] = true;
                }
            }
            ProcessState::Regrind => {
                let sec = (c / 200).min(4);
                section[c] = sec;
                let fraction = sec as f32 / 4.0; // 0, 25, 50, 75, 100 %
                // regrind (shorter chains) thins the melt: lower peak,
                // shorter plasticization — the two Fig. 4 effects
                params.viscosity *= 1.0 - 0.22 * fraction;
            }
            ProcessState::Doe => {
                let sec = (c / 20).min(doe_points.len() - 1);
                section[c] = sec;
                params = doe_points[sec].params();
            }
        }

        // thermal disequilibrium acts on viscosity, then decays
        let theta_mold = if startup {
            MOLD_SOAK / (1.0 + ((c as f32 - MOLD_KNEE) / MOLD_WIDTH).exp())
        } else {
            0.0
        };
        let theta = (theta_melt + theta_mold).min(1.0);
        params.viscosity *= 1.0 + THETA_VISC * theta;
        thermal[c] = theta;
        theta_melt *= MELT_DECAY;

        // small cycle-to-cycle process jitter (batch fluctuations)
        params.viscosity *= 1.0 + 0.004 * rng.normal();
        params.injection_speed *= 1.0 + 0.002 * rng.normal();

        data.extend_from_slice(&model.cycle(&params, &mut rng));
    }

    CaseDataset {
        part,
        state,
        cycles: Matrix::from_vec(n, samples, data),
        section,
        after_downtime,
        thermal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imm::simulator::MeltPressureModel;

    const TEST_SAMPLES: usize = 256; // keep unit tests fast

    #[test]
    fn shapes_per_state() {
        for st in ProcessState::all() {
            let ds = generate_dataset_with(Part::Cover, st, 1, TEST_SAMPLES);
            assert_eq!(ds.n(), st.cycles(), "{}", st.name());
            assert_eq!(ds.cycles.cols(), TEST_SAMPLES);
        }
    }

    #[test]
    fn startup_decays_to_equilibrium() {
        let ds = generate_dataset_with(Part::Plate, ProcessState::StartUp, 2, TEST_SAMPLES);
        assert!(ds.thermal[0] > 0.9);
        assert!(ds.thermal[500] < 0.25);
        assert!(ds.thermal[999] < 0.05);
        assert!(ds.thermal[500] < ds.thermal[100]);
        // early cycles have higher peak pressure than late ones
        let early = MeltPressureModel::peak_of(ds.cycles.row(0));
        let late = MeltPressureModel::peak_of(ds.cycles.row(900));
        assert!(early > late + 50.0, "early {early} late {late}");
    }

    #[test]
    fn downtimes_marked_and_disturb() {
        let ds = generate_dataset_with(Part::Cover, ProcessState::Downtimes, 3, TEST_SAMPLES);
        let marks: Vec<usize> = (0..ds.n()).filter(|&c| ds.after_downtime[c]).collect();
        assert_eq!(marks, vec![100, 200, 300, 400, 500, 600, 700, 800, 900]);
        // cycle right after a stop is thermally disturbed vs. right before
        assert!(ds.thermal[100] > ds.thermal[99] + 0.05);
    }

    #[test]
    fn regrind_sections_and_effects() {
        let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, 4, TEST_SAMPLES);
        assert_eq!(ds.num_sections(), 5);
        assert_eq!(ds.section[0], 0);
        assert_eq!(ds.section[999], 4);
        // 100% regrind -> visibly lower peak than virgin material
        let p0 = MeltPressureModel::peak_of(ds.cycles.row(100));
        let p4 = MeltPressureModel::peak_of(ds.cycles.row(900));
        assert!(p0 > p4 + 50.0, "virgin {p0} vs full regrind {p4}");
    }

    #[test]
    fn doe_sections_43x20() {
        let ds = generate_dataset_with(Part::Cover, ProcessState::Doe, 5, TEST_SAMPLES);
        assert_eq!(ds.n(), 860);
        assert_eq!(ds.num_sections(), 43);
        assert_eq!(ds.section[0], 0);
        assert_eq!(ds.section[20], 1);
        assert_eq!(ds.section[859], 42);
    }

    #[test]
    fn stable_is_stationary() {
        let ds = generate_dataset_with(Part::Plate, ProcessState::Stable, 6, TEST_SAMPLES);
        let p_early = MeltPressureModel::peak_of(ds.cycles.row(10));
        let p_late = MeltPressureModel::peak_of(ds.cycles.row(990));
        assert!((p_early - p_late).abs() < 60.0);
    }

    #[test]
    fn reproducible() {
        let a = generate_dataset_with(Part::Cover, ProcessState::Stable, 7, 64);
        let b = generate_dataset_with(Part::Cover, ProcessState::Stable, 7, 64);
        assert_eq!(a.cycles, b.cycles);
    }
}
