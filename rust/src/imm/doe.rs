//! Central composite design (CCD) for the case study's DOE state.
//!
//! The paper: *"The DOE is a central composite design with star points
//! and central point, yielding a total of 43 different machine
//! settings"* — that is the 5-factor CCD: 2⁵ = 32 factorial corners +
//! 2·5 = 10 star points + 1 center = 43.
//!
//! Factors (coded −1..+1, star at ±α): melt temperature, injection
//! speed, holding pressure, back pressure, cooling time. Each maps onto
//! [`CycleParams`] through first-order process physics.

use crate::imm::simulator::CycleParams;

/// Number of process factors.
pub const FACTORS: usize = 5;

/// One DOE operating point in coded units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    /// coded levels: [melt_temp, inj_speed, hold_press, back_press, cool_time]
    pub coded: [f32; FACTORS],
}

/// Full 5-factor CCD: 32 corners, 10 star points (α = 2.0), 1 center.
pub fn central_composite() -> Vec<DesignPoint> {
    let mut pts = Vec::with_capacity(43);
    // factorial corners
    for mask in 0..(1u32 << FACTORS) {
        let mut coded = [0f32; FACTORS];
        for (f, c) in coded.iter_mut().enumerate() {
            *c = if mask & (1 << f) != 0 { 1.0 } else { -1.0 };
        }
        pts.push(DesignPoint { coded });
    }
    // star points
    const ALPHA: f32 = 2.0;
    for f in 0..FACTORS {
        for sign in [-1.0f32, 1.0] {
            let mut coded = [0f32; FACTORS];
            coded[f] = sign * ALPHA;
            pts.push(DesignPoint { coded });
        }
    }
    // center
    pts.push(DesignPoint { coded: [0.0; FACTORS] });
    pts
}

impl DesignPoint {
    /// Map coded levels to cycle parameters.
    ///
    /// Opposing effects are deliberate (the paper explains why fewer
    /// than 43 sections surface among the representatives): higher melt
    /// temperature *lowers* viscosity/pressure while higher injection
    /// speed *raises* pressure, so some corners nearly cancel.
    pub fn params(&self) -> CycleParams {
        let [temp, speed, hold, back, _cool] = self.coded;
        CycleParams {
            // Arrhenius-ish: hot melt -> thinner
            viscosity: (1.0 - 0.06 * temp).clamp(0.6, 1.4),
            injection_speed: (1.0 + 0.08 * speed).clamp(0.6, 1.4),
            holding_factor: (1.0 + 0.07 * hold).clamp(0.6, 1.4),
            back_factor: (1.0 + 0.10 * back).clamp(0.6, 1.4),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ccd_has_43_points() {
        let pts = central_composite();
        assert_eq!(pts.len(), 43);
        // all distinct
        for i in 0..pts.len() {
            for j in i + 1..pts.len() {
                assert_ne!(pts[i], pts[j], "duplicate design points {i},{j}");
            }
        }
    }

    #[test]
    fn structure_counts() {
        let pts = central_composite();
        let corners = pts.iter().filter(|p| p.coded.iter().all(|c| c.abs() == 1.0)).count();
        let stars = pts
            .iter()
            .filter(|p| p.coded.iter().filter(|c| c.abs() > 1.5).count() == 1
                && p.coded.iter().filter(|c| **c == 0.0).count() == FACTORS - 1)
            .count();
        let center = pts.iter().filter(|p| p.coded.iter().all(|c| *c == 0.0)).count();
        assert_eq!((corners, stars, center), (32, 10, 1));
    }

    #[test]
    fn opposing_factors_can_cancel() {
        // hot melt + fast injection ≈ nominal peak (the paper's explanation)
        let both = DesignPoint { coded: [1.0, 1.0, 0.0, 0.0, 0.0] }.params();
        let peak_proxy = both.viscosity.powf(0.8) * both.injection_speed.powf(0.6);
        assert!((peak_proxy - 1.0).abs() < 0.05, "{peak_proxy}");
    }

    #[test]
    fn params_in_valid_range() {
        for p in central_composite() {
            let cp = p.params();
            assert!(cp.viscosity >= 0.6 && cp.viscosity <= 1.4);
            assert!(cp.injection_speed >= 0.6 && cp.injection_speed <= 1.4);
        }
    }
}
