//! The five induced process states of the case study (paper §6).

/// Induced process condition of one recorded dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProcessState {
    /// Machine started at minimal temperature, far from equilibrium.
    StartUp,
    /// Thermal equilibrium, no external influences.
    Stable,
    /// Stopped every 100 cycles for varying durations.
    Downtimes,
    /// Regrind fraction stepped 0 → 100 % in five 200-cycle sections.
    Regrind,
    /// 43-point central composite design, 20 cycles per point.
    Doe,
}

impl ProcessState {
    pub fn name(&self) -> &'static str {
        match self {
            ProcessState::StartUp => "start-up",
            ProcessState::Stable => "stable process",
            ProcessState::Downtimes => "downtimes",
            ProcessState::Regrind => "regrind material",
            ProcessState::Doe => "DOE",
        }
    }

    pub fn all() -> [ProcessState; 5] {
        [
            ProcessState::StartUp,
            ProcessState::Stable,
            ProcessState::Downtimes,
            ProcessState::Regrind,
            ProcessState::Doe,
        ]
    }

    /// Cycles recorded per dataset — 1000 everywhere except the DOE's
    /// 43 × 20 = 860 (paper §6).
    pub fn cycles(&self) -> usize {
        match self {
            ProcessState::Doe => 860,
            _ => 1000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_paper() {
        assert_eq!(ProcessState::Doe.cycles(), 860);
        assert_eq!(ProcessState::Stable.cycles(), 1000);
        assert_eq!(ProcessState::all().len(), 5);
    }
}
