//! The two molded parts of the case study. Geometry drives the nominal
//! process parameters: the *plate* is thin-walled and long-flow (high
//! injection pressure, long holding), the *cover* is boxier (lower peak,
//! more plasticization volume).

/// Which part is being molded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Part {
    Cover,
    Plate,
}

impl Part {
    pub fn name(&self) -> &'static str {
        match self {
            Part::Cover => "cover",
            Part::Plate => "plate",
        }
    }
    pub fn all() -> [Part; 2] {
        [Part::Cover, Part::Plate]
    }
}

/// Nominal process parameters of a part (operating point).
#[derive(Debug, Clone, Copy)]
pub struct PartSpec {
    /// Peak melt pressure during injection at nominal viscosity [bar].
    pub peak_pressure: f32,
    /// Holding-phase pressure [bar].
    pub holding_pressure: f32,
    /// Plasticization back-pressure [bar].
    pub back_pressure: f32,
    /// Injection phase duration, fraction of the recorded window.
    pub t_injection: f32,
    /// Holding phase duration fraction.
    pub t_holding: f32,
    /// Decompression-1 duration fraction.
    pub t_decomp1: f32,
    /// Nominal plasticization duration fraction (viscosity shifts it).
    pub t_plast: f32,
    /// Sensor noise std [bar].
    pub noise: f32,
}

impl Part {
    pub fn spec(&self) -> PartSpec {
        match self {
            // thin plate: long flow path -> high peak, long holding
            Part::Plate => PartSpec {
                peak_pressure: 1150.0,
                holding_pressure: 520.0,
                back_pressure: 95.0,
                t_injection: 0.12,
                t_holding: 0.34,
                t_decomp1: 0.05,
                t_plast: 0.30,
                noise: 4.0,
            },
            // cover: larger volume, lower peak, longer plasticization
            Part::Cover => PartSpec {
                peak_pressure: 870.0,
                holding_pressure: 430.0,
                back_pressure: 120.0,
                t_injection: 0.15,
                t_holding: 0.28,
                t_decomp1: 0.05,
                t_plast: 0.36,
                noise: 4.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_physical() {
        for p in Part::all() {
            let s = p.spec();
            assert!(s.peak_pressure > s.holding_pressure);
            assert!(s.holding_pressure > s.back_pressure);
            let total = s.t_injection + s.t_holding + s.t_decomp1 + s.t_plast;
            assert!(total < 1.0, "{}: phases exceed window", p.name());
        }
    }

    #[test]
    fn parts_differ() {
        assert!(Part::Plate.spec().peak_pressure > Part::Cover.spec().peak_pressure);
    }
}
