//! Configuration system: a TOML-subset parser (`parse`) + the typed
//! schema (`schema`) the launcher and the coordinator consume.
//!
//! Supported TOML subset (sufficient for service configs): `[section]`
//! and `[section.sub]` headers, `key = value` with string / integer /
//! float / boolean / string-array values, `#` comments.

pub mod parse;
pub mod schema;

pub use parse::ConfigDoc;
pub use schema::{CoordinatorConfig, EngineSection, ServiceConfig, SummarySection};
