//! TOML-subset document parser.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    StrArray(Vec<String>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path keys ("section.key") → values.
#[derive(Debug, Clone, Default)]
pub struct ConfigDoc {
    map: BTreeMap<String, Value>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let val = parse_value(v.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            map.insert(full, val);
        }
        Ok(ConfigDoc { map })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ConfigDoc> {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            match parse_value(p)? {
                Value::Str(v) => items.push(v),
                other => bail!("only string arrays supported, got {other:?}"),
            }
        }
        return Ok(Value::StrArray(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# service config
name = "fleet-a"

[engine]
precision = "bf16"   # half precision
batch = 1024
cpu_fallback = true

[summary]
k = 5
algorithm = "greedy"
refresh_every = 100
machines = ["imm-1", "imm-2"]

[summary.quality]
min_gain = 0.001
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "fleet-a");
        assert_eq!(c.str("engine.precision", "f32"), "bf16");
        assert_eq!(c.int("engine.batch", 0), 1024);
        assert!(c.bool("engine.cpu_fallback", false));
        assert_eq!(c.int("summary.k", 0), 5);
        assert!((c.float("summary.quality.min_gain", 0.0) - 0.001).abs() < 1e-12);
        match c.get("summary.machines") {
            Some(Value::StrArray(a)) => assert_eq!(a, &["imm-1", "imm-2"]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = ConfigDoc::parse("").unwrap();
        assert_eq!(c.int("nope", 7), 7);
        assert_eq!(c.str("nope", "d"), "d");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(ConfigDoc::parse("[unterminated").is_err());
        assert!(ConfigDoc::parse("novalue").is_err());
        assert!(ConfigDoc::parse("x = ").is_err());
        assert!(ConfigDoc::parse("x = \"open").is_err());
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let c = ConfigDoc::parse("x = \"a#b\" # trailing\n").unwrap();
        assert_eq!(c.str("x", ""), "a#b");
    }

    #[test]
    fn int_vs_float() {
        let c = ConfigDoc::parse("a = 3\nb = 3.5\nc = -2\n").unwrap();
        assert_eq!(c.int("a", 0), 3);
        assert_eq!(c.float("b", 0.0), 3.5);
        assert_eq!(c.int("c", 0), -2);
        assert_eq!(c.float("a", 0.0), 3.0); // int coerces to float
    }
}
