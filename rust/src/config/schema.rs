//! Typed configuration schema over [`super::parse::ConfigDoc`].

use super::parse::{ConfigDoc, Value};
use crate::linalg::gemm::CpuKernel;
use crate::runtime::artifact::Precision;
use anyhow::{bail, Result};

/// `[engine]` section.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSection {
    pub precision: Precision,
    pub cpu_fallback: bool,
    pub batch: usize,
    /// CPU oracle kernel backend: one of [`crate::linalg::CPU_KERNELS`]
    /// (`scalar` = paper baseline loops, `blocked` = tiled Gram-matrix,
    /// `simd` = the same tiling with runtime-detected AVX2/NEON
    /// micro-kernels and a bit-identical scalar fallback).
    pub cpu_kernel: CpuKernel,
    /// Ground-parallel worker threads for the gemm-family CPU kernels
    /// (0 = auto via `default_threads()`).
    pub cpu_threads: usize,
}

impl Default for EngineSection {
    fn default() -> Self {
        EngineSection {
            precision: Precision::F32,
            cpu_fallback: true,
            batch: 1024,
            cpu_kernel: CpuKernel::Blocked,
            cpu_threads: 0,
        }
    }
}

/// `[summary]` section: what the coordinator maintains per machine.
#[derive(Debug, Clone, PartialEq)]
pub struct SummarySection {
    pub k: usize,
    pub algorithm: String,
    /// Recompute the summary after this many new cycles.
    pub refresh_every: usize,
    /// Sliding window of cycles the summary covers (0 = unbounded).
    pub window: usize,
}

impl Default for SummarySection {
    fn default() -> Self {
        SummarySection {
            k: 5,
            algorithm: "greedy".into(),
            refresh_every: 50,
            window: 1000,
        }
    }
}

/// `[shard]` section: the sharded two-stage summarizer used by
/// fleet-level queries (and tunable for `shard-bench`).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSection {
    /// Number of shards P the ground set is split into.
    pub shards: usize,
    /// Partition strategy: one of [`crate::shard::PARTITIONERS`].
    pub partitioner: String,
    /// Worker threads for the per-shard stage (0 = auto).
    pub threads: usize,
    /// Exemplars each shard contributes in stage 1 (0 = final k).
    pub per_shard_k: usize,
    /// Seed for hash mixing / the locality projection.
    pub seed: u64,
    /// Pre-plan fleet queries (one engine bucket shape + a
    /// P-worker × T-thread CPU split per window shape —
    /// [`crate::engine::plan`]). `false` = legacy per-shard planning.
    pub plan: bool,
    /// Core budget for planned fleet runs (0 = auto).
    pub cores: usize,
    /// Shard-stage transport: one of [`crate::shard::TRANSPORTS`]
    /// (`inproc` = threadpool workers, `loopback` = the replica
    /// registry, `tcp` = a real replica fleet over sockets). Either way
    /// shards travel as wire-format frames.
    pub transport: String,
    /// Replica count for the `loopback` transport.
    pub replicas: usize,
    /// Replica endpoints (`host:port`) for the `tcp` transport —
    /// required (non-empty) when `transport = "tcp"`.
    pub addrs: Vec<String>,
    /// TCP connect deadline per attempt (ms).
    pub connect_timeout_ms: u64,
    /// Socket read/write deadline per operation (ms); must cover one
    /// shard's execution on the replica.
    pub io_timeout_ms: u64,
    /// Transient-failure retries per replica before it is declared dead
    /// and its shards re-queue.
    pub retries: u64,
    /// Base retry backoff (ms), doubled per attempt with jitter.
    pub backoff_ms: u64,
    /// Largest frame accepted off the wire (MiB).
    pub max_frame_mb: u64,
    /// Heartbeat age (rounds) past which a silent replica expires.
    pub heartbeat_max_age: u64,
    /// Fault-injection seed for chaos testing (0 = off) — see
    /// [`crate::shard::fault`].
    pub chaos: u64,
    /// Fraction of each shard's ground sieved away before stage 1
    /// ([`crate::prune`]); must lie in [0, 1). 0 = off.
    pub prune: f64,
    /// Merge-tree fanout (children per merge node); 0 = single root.
    pub fanout: usize,
    /// Ground-row cap per merge node; 0 = unlimited.
    pub max_merge_n: usize,
    /// Registry optimizer for the merge stage(s); `"greedy"` keeps the
    /// exact candidate-greedy merge.
    pub merge_optimizer: String,
}

impl ShardSection {
    /// The [`crate::shard::NetOptions`] this section describes.
    pub fn net_options(&self) -> crate::shard::NetOptions {
        crate::shard::NetOptions {
            addrs: self.addrs.clone(),
            connect_timeout_ms: self.connect_timeout_ms,
            io_timeout_ms: self.io_timeout_ms,
            retries: self.retries as u32,
            backoff_ms: self.backoff_ms,
            max_frame_mb: self.max_frame_mb as u32,
            heartbeat_max_age: self.heartbeat_max_age,
            chaos: self.chaos,
        }
    }
}

impl Default for ShardSection {
    fn default() -> Self {
        let net = crate::shard::NetOptions::default();
        ShardSection {
            shards: 2,
            partitioner: "round_robin".into(),
            threads: 0,
            per_shard_k: 0,
            seed: 0xEBC,
            plan: true,
            cores: 0,
            transport: "inproc".into(),
            replicas: 2,
            addrs: net.addrs,
            connect_timeout_ms: net.connect_timeout_ms,
            io_timeout_ms: net.io_timeout_ms,
            retries: net.retries as u64,
            backoff_ms: net.backoff_ms,
            max_frame_mb: net.max_frame_mb as u64,
            heartbeat_max_age: net.heartbeat_max_age,
            chaos: net.chaos,
            prune: 0.0,
            fanout: 0,
            max_merge_n: 0,
            merge_optimizer: "greedy".into(),
        }
    }
}

/// `[coordinator]` section: service-level knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Ingestion queue capacity per machine before backpressure engages.
    pub queue_capacity: usize,
    /// Max cycles batched into one ingest tick.
    pub ingest_batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 2, queue_capacity: 256, ingest_batch: 32 }
    }
}

/// `[obs]` section: the process-wide observability layer
/// ([`crate::obs`]): span recording + global registry shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsSection {
    /// Record spans into the flight recorder (metrics are unaffected).
    pub enabled: bool,
    /// Flight-recorder ring capacity (completed spans held before the
    /// oldest is evicted). Applied only on the first global touch.
    pub recorder_capacity: usize,
    /// Log-spaced latency buckets per global-registry histogram.
    /// Applied only on the first global touch.
    pub hist_buckets: usize,
}

impl Default for ObsSection {
    fn default() -> Self {
        let d = crate::obs::ObsConfig::default();
        ObsSection {
            enabled: d.enabled,
            recorder_capacity: d.recorder_capacity,
            hist_buckets: d.hist_buckets,
        }
    }
}

impl ObsSection {
    /// The [`crate::obs::configure`] argument this section describes.
    pub fn obs_config(&self) -> crate::obs::ObsConfig {
        crate::obs::ObsConfig {
            enabled: self.enabled,
            recorder_capacity: self.recorder_capacity,
            hist_buckets: self.hist_buckets,
        }
    }
}

/// `[daemon]` section: the production daemon ([`crate::daemon`]) built
/// over the coordinator — worker pool, scheduler cadence, retry policy,
/// status endpoint and drain behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct DaemonSection {
    /// Job worker threads executing refresh / fleet / ingest jobs.
    pub workers: usize,
    /// Pending-job queue capacity before new jobs are shed.
    pub job_capacity: usize,
    /// Scheduler tick period (ms) — the daemon's heartbeat.
    pub tick_ms: u64,
    /// Enqueue due summary refreshes every this many ticks.
    pub refresh_ticks: u64,
    /// Recompute the cached `@fleet` summary every this many ticks
    /// (0 = only on demand via [`crate::coordinator::FLEET_QUERY`]).
    pub fleet_ticks: u64,
    /// `host:port` for the HTTP status endpoint ("" = disabled).
    pub status_addr: String,
    /// Graceful-drain deadline (ms): how long shutdown waits for queued
    /// records and in-flight jobs before giving up.
    pub drain_timeout_ms: u64,
    /// Failed-job retries before the failure is surfaced.
    pub retries: u32,
    /// Base retry backoff (ms), doubled per attempt with jitter
    /// (the PR 7 net shape: `backoff_ms * 2^attempt * U[0.5, 1.5)`).
    pub backoff_ms: u64,
    /// Write a final coordinator snapshot here on graceful shutdown
    /// ("" = disabled).
    pub snapshot_path: String,
}

impl Default for DaemonSection {
    fn default() -> Self {
        DaemonSection {
            workers: 2,
            job_capacity: 64,
            tick_ms: 20,
            refresh_ticks: 25,
            fleet_ticks: 100,
            status_addr: String::new(),
            drain_timeout_ms: 5000,
            retries: 2,
            backoff_ms: 50,
            snapshot_path: String::new(),
        }
    }
}

/// Full service config.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    pub name: String,
    pub engine: EngineSection,
    pub summary: SummarySection,
    pub coordinator: CoordinatorConfig,
    pub shard: ShardSection,
    pub obs: ObsSection,
    pub daemon: DaemonSection,
    pub machines: Vec<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            name: "ebc-service".into(),
            engine: EngineSection::default(),
            summary: SummarySection::default(),
            coordinator: CoordinatorConfig::default(),
            shard: ShardSection::default(),
            obs: ObsSection::default(),
            daemon: DaemonSection::default(),
            machines: vec![],
        }
    }
}

impl ServiceConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Result<ServiceConfig> {
        let precision = match doc.str("engine.precision", "f32").as_str() {
            "f32" => Precision::F32,
            "bf16" | "fp16" | "half" => Precision::Bf16,
            other => bail!("engine.precision: unknown '{other}'"),
        };
        let cpu_kernel = CpuKernel::parse(&doc.str("engine.cpu_kernel", "blocked"))
            .map_err(|e| e.context("engine.cpu_kernel"))?;
        let algorithm = doc.str("summary.algorithm", "greedy");
        if !crate::optim::ALGORITHMS.contains(&algorithm.as_str()) {
            bail!(
                "summary.algorithm: unknown '{algorithm}' (expected one of {:?})",
                crate::optim::ALGORITHMS
            );
        }
        let partitioner = doc.str("shard.partitioner", "round_robin");
        if !crate::shard::PARTITIONERS.contains(&partitioner.as_str()) {
            bail!(
                "shard.partitioner: unknown '{partitioner}' (expected one of {:?})",
                crate::shard::PARTITIONERS
            );
        }
        let transport = doc.str("shard.transport", "inproc");
        if !crate::shard::TRANSPORTS.contains(&transport.as_str()) {
            bail!(
                "shard.transport: unknown '{transport}' (expected one of {:?})",
                crate::shard::TRANSPORTS
            );
        }
        let merge_optimizer = doc.str("shard.merge_optimizer", "greedy");
        if !crate::optim::ALGORITHMS.contains(&merge_optimizer.as_str()) {
            bail!(
                "shard.merge_optimizer: unknown '{merge_optimizer}' (expected one of {:?})",
                crate::optim::ALGORITHMS
            );
        }
        let prune = doc.float("shard.prune", 0.0);
        if !(0.0..1.0).contains(&prune) {
            bail!("shard.prune: rate {prune} outside [0, 1)");
        }
        let addrs = match doc.get("shard.addrs") {
            Some(Value::StrArray(a)) => a.clone(),
            _ => vec![],
        };
        if transport == "tcp" && addrs.is_empty() {
            bail!("shard.addrs: transport = \"tcp\" needs at least one replica endpoint");
        }
        let machines = match doc.get("coordinator.machines") {
            Some(Value::StrArray(a)) => a.clone(),
            _ => vec![],
        };
        let pos = |key: &str, default: i64| -> Result<usize> {
            let v = doc.int(key, default);
            if v < 0 {
                bail!("{key} must be >= 0, got {v}");
            }
            Ok(v as usize)
        };
        Ok(ServiceConfig {
            name: doc.str("name", "ebc-service"),
            engine: EngineSection {
                precision,
                cpu_fallback: doc.bool("engine.cpu_fallback", true),
                batch: pos("engine.batch", 1024)?,
                cpu_kernel,
                cpu_threads: pos("engine.cpu_threads", 0)?,
            },
            summary: SummarySection {
                k: pos("summary.k", 5)?,
                algorithm,
                refresh_every: pos("summary.refresh_every", 50)?,
                window: pos("summary.window", 1000)?,
            },
            coordinator: CoordinatorConfig {
                workers: pos("coordinator.workers", 2)?.max(1),
                queue_capacity: pos("coordinator.queue_capacity", 256)?.max(1),
                ingest_batch: pos("coordinator.ingest_batch", 32)?.max(1),
            },
            shard: ShardSection {
                shards: pos("shard.shards", 2)?.max(1),
                partitioner,
                threads: pos("shard.threads", 0)?,
                per_shard_k: pos("shard.per_shard_k", 0)?,
                seed: pos("shard.seed", 0xEBC)? as u64,
                plan: doc.bool("shard.plan", true),
                cores: pos("shard.cores", 0)?,
                transport,
                replicas: pos("shard.replicas", 2)?.max(1),
                addrs,
                connect_timeout_ms: pos("shard.connect_timeout_ms", 1000)?.max(1) as u64,
                io_timeout_ms: pos("shard.io_timeout_ms", 5000)?.max(1) as u64,
                retries: pos("shard.retries", 2)? as u64,
                backoff_ms: pos("shard.backoff_ms", 50)?.max(1) as u64,
                max_frame_mb: pos("shard.max_frame_mb", 64)?.max(1) as u64,
                heartbeat_max_age: pos("shard.heartbeat_max_age", 3)?.max(1) as u64,
                chaos: pos("shard.chaos", 0)? as u64,
                prune,
                fanout: pos("shard.fanout", 0)?,
                max_merge_n: pos("shard.max_merge_n", 0)?,
                merge_optimizer,
            },
            obs: ObsSection {
                enabled: doc.bool("obs.enabled", true),
                recorder_capacity: pos("obs.recorder_capacity", 4096)?.max(1),
                hist_buckets: pos("obs.hist_buckets", 40)?.max(1),
            },
            daemon: DaemonSection {
                workers: pos("daemon.workers", 2)?.max(1),
                job_capacity: pos("daemon.job_capacity", 64)?.max(1),
                tick_ms: pos("daemon.tick_ms", 20)?.max(1) as u64,
                refresh_ticks: pos("daemon.refresh_ticks", 25)?.max(1) as u64,
                fleet_ticks: pos("daemon.fleet_ticks", 100)? as u64,
                status_addr: doc.str("daemon.status_addr", ""),
                drain_timeout_ms: pos("daemon.drain_timeout_ms", 5000)?.max(1) as u64,
                retries: pos("daemon.retries", 2)? as u32,
                backoff_ms: pos("daemon.backoff_ms", 50)?.max(1) as u64,
                snapshot_path: doc.str("daemon.snapshot_path", ""),
            },
            machines,
        })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ServiceConfig> {
        Self::from_doc(&ConfigDoc::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_roundtrip() {
        let doc = ConfigDoc::parse(
            r#"
name = "plant-7"
[engine]
precision = "bf16"
batch = 256
cpu_kernel = "scalar"
cpu_threads = 4
[summary]
k = 10
algorithm = "three_sieves"
refresh_every = 25
window = 500
[coordinator]
workers = 4
queue_capacity = 128
ingest_batch = 16
machines = ["cover-line", "plate-line"]
[shard]
shards = 8
partitioner = "locality"
threads = 2
per_shard_k = 12
seed = 99
plan = false
cores = 6
transport = "loopback"
replicas = 5
prune = 0.4
fanout = 4
max_merge_n = 300
merge_optimizer = "stochastic_greedy"
[obs]
enabled = false
recorder_capacity = 512
hist_buckets = 24
"#,
        )
        .unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.name, "plant-7");
        assert_eq!(c.engine.precision, Precision::Bf16);
        assert_eq!(c.engine.batch, 256);
        assert_eq!(c.engine.cpu_kernel, CpuKernel::Scalar);
        assert_eq!(c.engine.cpu_threads, 4);
        assert_eq!(c.summary.k, 10);
        assert_eq!(c.summary.algorithm, "three_sieves");
        assert_eq!(c.coordinator.workers, 4);
        assert_eq!(c.shard.shards, 8);
        assert_eq!(c.shard.partitioner, "locality");
        assert_eq!(c.shard.threads, 2);
        assert_eq!(c.shard.per_shard_k, 12);
        assert_eq!(c.shard.seed, 99);
        assert!(!c.shard.plan);
        assert_eq!(c.shard.cores, 6);
        assert_eq!(c.shard.transport, "loopback");
        assert_eq!(c.shard.replicas, 5);
        assert_eq!(c.shard.prune, 0.4);
        assert_eq!(c.shard.fanout, 4);
        assert_eq!(c.shard.max_merge_n, 300);
        assert_eq!(c.shard.merge_optimizer, "stochastic_greedy");
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.recorder_capacity, 512);
        assert_eq!(c.obs.hist_buckets, 24);
        assert_eq!(c.machines, vec!["cover-line", "plate-line"]);
    }

    #[test]
    fn defaults_without_sections() {
        let c = ServiceConfig::from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert_eq!(c.summary.k, 5);
        assert_eq!(c.engine.precision, Precision::F32);
        assert_eq!(c.engine.cpu_kernel, CpuKernel::Blocked);
        assert_eq!(c.engine.cpu_threads, 0);
        assert_eq!(c.coordinator.workers, 2);
        assert_eq!(c.shard.shards, 2);
        assert_eq!(c.shard.partitioner, "round_robin");
        assert_eq!(c.shard.threads, 0);
        assert!(c.shard.plan);
        assert_eq!(c.shard.cores, 0);
        assert_eq!(c.shard.transport, "inproc");
        assert_eq!(c.shard.replicas, 2);
        assert_eq!(c.shard.prune, 0.0);
        assert_eq!(c.shard.fanout, 0);
        assert_eq!(c.shard.max_merge_n, 0);
        assert_eq!(c.shard.merge_optimizer, "greedy");
        assert!(c.obs.enabled);
        assert_eq!(c.obs.recorder_capacity, 4096);
        assert_eq!(c.obs.hist_buckets, 40);
    }

    #[test]
    fn prune_knobs_validate() {
        let bad = ConfigDoc::parse("[shard]\nprune = 1.5\n").unwrap();
        assert!(ServiceConfig::from_doc(&bad).is_err());
        let neg = ConfigDoc::parse("[shard]\nprune = -0.2\n").unwrap();
        assert!(ServiceConfig::from_doc(&neg).is_err());
        let unk = ConfigDoc::parse("[shard]\nmerge_optimizer = \"psychic\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&unk).is_err());
        let ok = ConfigDoc::parse("[shard]\nprune = 0.25\nfanout = 2\n").unwrap();
        let c = ServiceConfig::from_doc(&ok).unwrap();
        assert_eq!(c.shard.prune, 0.25);
        assert_eq!(c.shard.fanout, 2);
    }

    #[test]
    fn obs_section_converts_and_clamps() {
        let doc = ConfigDoc::parse("[obs]\nrecorder_capacity = 0\nhist_buckets = 0\n").unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.obs.recorder_capacity, 1);
        assert_eq!(c.obs.hist_buckets, 1);
        let oc = c.obs.obs_config();
        assert!(oc.enabled);
        assert_eq!(oc.recorder_capacity, 1);
    }

    #[test]
    fn daemon_section_parses_and_defaults() {
        let doc = ConfigDoc::parse(
            r#"
[daemon]
workers = 6
job_capacity = 32
tick_ms = 5
refresh_ticks = 10
fleet_ticks = 0
status_addr = "127.0.0.1:9180"
drain_timeout_ms = 750
retries = 4
backoff_ms = 25
snapshot_path = "/tmp/ebc-final.json"
"#,
        )
        .unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.daemon.workers, 6);
        assert_eq!(c.daemon.job_capacity, 32);
        assert_eq!(c.daemon.tick_ms, 5);
        assert_eq!(c.daemon.refresh_ticks, 10);
        assert_eq!(c.daemon.fleet_ticks, 0); // 0 = on-demand only
        assert_eq!(c.daemon.status_addr, "127.0.0.1:9180");
        assert_eq!(c.daemon.drain_timeout_ms, 750);
        assert_eq!(c.daemon.retries, 4);
        assert_eq!(c.daemon.backoff_ms, 25);
        assert_eq!(c.daemon.snapshot_path, "/tmp/ebc-final.json");

        let d = ServiceConfig::from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert_eq!(d.daemon, DaemonSection::default());
        assert_eq!(d.daemon.workers, 2);
        assert!(d.daemon.status_addr.is_empty());
    }

    #[test]
    fn daemon_knobs_clamp_to_sane_floors() {
        let doc =
            ConfigDoc::parse("[daemon]\nworkers = 0\ntick_ms = 0\njob_capacity = 0\n").unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.daemon.workers, 1);
        assert_eq!(c.daemon.tick_ms, 1);
        assert_eq!(c.daemon.job_capacity, 1);
    }

    #[test]
    fn service_config_equality_detects_section_changes() {
        let a = ServiceConfig::default();
        let mut b = a.clone();
        assert_eq!(a, b);
        b.daemon.workers = 9;
        assert_ne!(a, b);
    }

    #[test]
    fn rejects_unknown_transport() {
        let doc = ConfigDoc::parse("[shard]\ntransport = \"telepathy\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn tcp_transport_requires_addrs() {
        let doc = ConfigDoc::parse("[shard]\ntransport = \"tcp\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse(
            "[shard]\ntransport = \"tcp\"\naddrs = [\"10.0.0.7:7700\", \"10.0.0.8:7700\"]\n",
        )
        .unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.shard.transport, "tcp");
        assert_eq!(c.shard.addrs, vec!["10.0.0.7:7700", "10.0.0.8:7700"]);
    }

    #[test]
    fn net_knobs_parse_and_convert() {
        let doc = ConfigDoc::parse(
            r#"
[shard]
transport = "tcp"
addrs = ["127.0.0.1:7700"]
connect_timeout_ms = 250
io_timeout_ms = 9000
retries = 4
backoff_ms = 10
max_frame_mb = 8
heartbeat_max_age = 5
chaos = 77
"#,
        )
        .unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        let net = c.shard.net_options();
        assert_eq!(net.addrs, vec!["127.0.0.1:7700"]);
        assert_eq!(net.connect_timeout_ms, 250);
        assert_eq!(net.io_timeout_ms, 9000);
        assert_eq!(net.retries, 4);
        assert_eq!(net.backoff_ms, 10);
        assert_eq!(net.max_frame_mb, 8);
        assert_eq!(net.heartbeat_max_age, 5);
        assert_eq!(net.chaos, 77);
    }

    #[test]
    fn net_defaults_match_net_options() {
        let c = ServiceConfig::from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert_eq!(c.shard.net_options(), crate::shard::NetOptions::default());
    }

    #[test]
    fn replicas_clamped_to_at_least_one() {
        let doc = ConfigDoc::parse("[shard]\ntransport = \"loopback\"\nreplicas = 0\n").unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.shard.replicas, 1);
    }

    #[test]
    fn rejects_unknown_partitioner() {
        let doc = ConfigDoc::parse("[shard]\npartitioner = \"psychic\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn shards_clamped_to_at_least_one() {
        let doc = ConfigDoc::parse("[shard]\nshards = 0\n").unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.shard.shards, 1);
    }

    #[test]
    fn rejects_unknown_algorithm() {
        let doc = ConfigDoc::parse("[summary]\nalgorithm = \"magic\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn rejects_unknown_cpu_kernel() {
        let doc = ConfigDoc::parse("[engine]\ncpu_kernel = \"quantum\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn accepts_simd_cpu_kernel() {
        let doc = ConfigDoc::parse("[engine]\ncpu_kernel = \"simd\"\n").unwrap();
        let c = ServiceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.engine.cpu_kernel, CpuKernel::Simd);
    }

    #[test]
    fn rejects_bad_precision() {
        let doc = ConfigDoc::parse("[engine]\nprecision = \"fp8\"\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn rejects_negative() {
        let doc = ConfigDoc::parse("[summary]\nk = -3\n").unwrap();
        assert!(ServiceConfig::from_doc(&doc).is_err());
    }
}
