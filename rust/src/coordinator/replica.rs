//! Replica registry for remote shard execution: the coordinator-side
//! bookkeeping of which worker replicas exist, whether they are healthy
//! (logical-clock heartbeats), whether they accept new shards
//! (drain state), and how shards are dealt across them (capacity-
//! weighted, deterministic).
//!
//! The registry is transport-agnostic plain state: the loopback
//! transport ([`crate::shard::transport::LoopbackReplicaTransport`])
//! drives it in-process today; a future socket transport reuses it
//! unchanged — register on connect, heartbeat on keepalive, drain on
//! graceful shutdown, [`ReplicaRegistry::expire`] on missed heartbeats.

use std::collections::BTreeMap;

/// Lifecycle of one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Healthy: accepts new shard assignments.
    Alive,
    /// Graceful shutdown: finishes nothing new, receives no new shards.
    Draining,
    /// Failed or expired: its in-flight shards are re-queued.
    Dead,
}

/// One registered worker replica.
#[derive(Debug, Clone)]
pub struct Replica {
    pub id: String,
    /// Relative share of the shard deal (≥ 1).
    pub capacity: usize,
    pub state: ReplicaState,
    /// Logical-clock time of the last heartbeat.
    pub last_heartbeat: u64,
    /// Shards this replica completed successfully.
    pub jobs_done: u64,
    /// Failure injection for tests/chaos runs: the replica dies after
    /// completing this many further jobs.
    pub fail_after: Option<u64>,
}

impl Replica {
    /// May this replica receive new shards?
    pub fn assignable(&self) -> bool {
        self.state == ReplicaState::Alive
    }
}

/// Registry of worker replicas keyed by id (sorted, so every walk is
/// deterministic).
#[derive(Debug, Default)]
pub struct ReplicaRegistry {
    replicas: BTreeMap<String, Replica>,
    /// Logical clock: advanced by [`Self::tick`], read by heartbeats.
    clock: u64,
}

impl ReplicaRegistry {
    pub fn new() -> ReplicaRegistry {
        ReplicaRegistry::default()
    }

    /// Register (or revive) a replica. Re-registering an existing id
    /// resets it to `Alive` with a fresh heartbeat — the crash-restart
    /// path — but keeps its completed-job count.
    pub fn register(&mut self, id: &str, capacity: usize) {
        let clock = self.clock;
        self.replicas
            .entry(id.to_string())
            .and_modify(|r| {
                r.capacity = capacity.max(1);
                r.state = ReplicaState::Alive;
                r.last_heartbeat = clock;
                r.fail_after = None;
            })
            .or_insert_with(|| Replica {
                id: id.to_string(),
                capacity: capacity.max(1),
                state: ReplicaState::Alive,
                last_heartbeat: clock,
                jobs_done: 0,
                fail_after: None,
            });
    }

    /// Advance the logical clock (one scheduler round / keepalive period).
    pub fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Record a heartbeat. Returns `false` for unknown or dead replicas
    /// (a dead replica must re-register, not just ping).
    pub fn heartbeat(&mut self, id: &str) -> bool {
        let clock = self.clock;
        match self.replicas.get_mut(id) {
            Some(r) if r.state != ReplicaState::Dead => {
                r.last_heartbeat = clock;
                true
            }
            _ => false,
        }
    }

    /// Mark every non-dead replica whose last heartbeat is older than
    /// `max_age` ticks as dead; returns the expired ids.
    pub fn expire(&mut self, max_age: u64) -> Vec<String> {
        let clock = self.clock;
        let mut expired = Vec::new();
        for r in self.replicas.values_mut() {
            if r.state != ReplicaState::Dead && clock.saturating_sub(r.last_heartbeat) > max_age {
                r.state = ReplicaState::Dead;
                expired.push(r.id.clone());
            }
        }
        expired
    }

    /// Graceful shutdown: the replica stops receiving new shards.
    pub fn drain(&mut self, id: &str) -> bool {
        match self.replicas.get_mut(id) {
            Some(r) if r.state == ReplicaState::Alive => {
                r.state = ReplicaState::Draining;
                true
            }
            _ => false,
        }
    }

    /// Hard failure: the replica is dead; its shards get re-queued.
    pub fn kill(&mut self, id: &str) -> bool {
        match self.replicas.get_mut(id) {
            Some(r) if r.state != ReplicaState::Dead => {
                r.state = ReplicaState::Dead;
                true
            }
            _ => false,
        }
    }

    /// Forget a replica entirely.
    pub fn remove(&mut self, id: &str) -> bool {
        self.replicas.remove(id).is_some()
    }

    pub fn get(&self, id: &str) -> Option<&Replica> {
        self.replicas.get(id)
    }

    pub fn get_mut(&mut self, id: &str) -> Option<&mut Replica> {
        self.replicas.get_mut(id)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Replica> {
        self.replicas.values()
    }

    /// Registered replicas (any state).
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Replicas currently accepting shards.
    pub fn alive(&self) -> usize {
        self.replicas.values().filter(|r| r.assignable()).count()
    }

    /// Deal `items` across the assignable replicas, capacity-weighted
    /// and deterministic: each replica contributes `capacity` slots
    /// (sorted by id), items go round-robin over the slot ring. Returns
    /// `(replica id, its items)` pairs; empty when no replica is
    /// assignable.
    pub fn assign<T: Copy>(&self, items: &[T]) -> Vec<(String, Vec<T>)> {
        let workers: Vec<&Replica> = self.replicas.values().filter(|r| r.assignable()).collect();
        if workers.is_empty() || items.is_empty() {
            return Vec::new();
        }
        let mut slots: Vec<usize> = Vec::new();
        for (w, r) in workers.iter().enumerate() {
            slots.extend(std::iter::repeat_n(w, r.capacity.max(1)));
        }
        let mut per_worker: Vec<Vec<T>> = vec![Vec::new(); workers.len()];
        for (i, &item) in items.iter().enumerate() {
            per_worker[slots[i % slots.len()]].push(item);
        }
        workers
            .iter()
            .zip(per_worker)
            .filter(|(_, items)| !items.is_empty())
            .map(|(r, items)| (r.id.clone(), items))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(n: usize) -> ReplicaRegistry {
        let mut reg = ReplicaRegistry::new();
        for i in 0..n {
            reg.register(&format!("replica-{i}"), 1);
        }
        reg
    }

    #[test]
    fn register_heartbeat_expire_lifecycle() {
        let mut reg = registry(2);
        assert_eq!(reg.alive(), 2);
        // replica-1 keeps pinging, replica-0 goes silent
        for _ in 0..5 {
            reg.tick();
            assert!(reg.heartbeat("replica-1"));
        }
        let expired = reg.expire(3);
        assert_eq!(expired, vec!["replica-0".to_string()]);
        assert_eq!(reg.alive(), 1);
        assert_eq!(reg.get("replica-0").unwrap().state, ReplicaState::Dead);
        // dead replicas cannot heartbeat back to life...
        assert!(!reg.heartbeat("replica-0"));
        // ...but can re-register (crash-restart)
        reg.register("replica-0", 2);
        assert_eq!(reg.alive(), 2);
        assert_eq!(reg.get("replica-0").unwrap().capacity, 2);
        // unknown ids are rejected
        assert!(!reg.heartbeat("ghost"));
    }

    #[test]
    fn drain_excludes_from_assignment_but_is_not_dead() {
        let mut reg = registry(3);
        assert!(reg.drain("replica-1"));
        assert_eq!(reg.alive(), 2);
        assert_eq!(reg.get("replica-1").unwrap().state, ReplicaState::Draining);
        let jobs: Vec<usize> = (0..6).collect();
        for (id, _) in reg.assign(&jobs) {
            assert_ne!(id, "replica-1");
        }
        // draining twice is a no-op; draining a dead replica fails
        assert!(!reg.drain("replica-1"));
        reg.kill("replica-2");
        assert!(!reg.drain("replica-2"));
    }

    #[test]
    fn assignment_is_deterministic_and_capacity_weighted() {
        let mut reg = ReplicaRegistry::new();
        reg.register("big", 3);
        reg.register("small", 1);
        let jobs: Vec<usize> = (0..8).collect();
        let a = reg.assign(&jobs);
        assert_eq!(a, reg.assign(&jobs), "same state must deal identically");
        let total: usize = a.iter().map(|(_, j)| j.len()).sum();
        assert_eq!(total, 8);
        let big = a.iter().find(|(id, _)| id == "big").unwrap().1.len();
        let small = a.iter().find(|(id, _)| id == "small").unwrap().1.len();
        assert_eq!(big, 6);
        assert_eq!(small, 2);
        // all jobs accounted for exactly once
        let mut seen: Vec<usize> = a.iter().flat_map(|(_, j)| j.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, jobs);
    }

    #[test]
    fn assign_with_no_replicas_is_empty() {
        let reg = ReplicaRegistry::new();
        assert!(reg.assign(&[1usize, 2]).is_empty());
        let mut reg = registry(1);
        reg.kill("replica-0");
        assert!(reg.assign(&[1usize]).is_empty());
        assert!(reg.assign::<usize>(&[]).is_empty());
    }

    #[test]
    fn kill_then_assign_skips_dead() {
        let mut reg = registry(3);
        assert!(reg.kill("replica-0"));
        assert!(!reg.kill("replica-0"), "double kill is a no-op");
        let jobs: Vec<usize> = (0..4).collect();
        let a = reg.assign(&jobs);
        assert!(a.iter().all(|(id, _)| id != "replica-0"));
        assert_eq!(a.iter().map(|(_, j)| j.len()).sum::<usize>(), 4);
        assert!(reg.remove("replica-0"));
        assert_eq!(reg.len(), 2);
    }
}
