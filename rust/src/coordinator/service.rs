//! The coordinator service: ties queue → batcher → machines → optimizer.
//!
//! Since the daemon refactor the coordinator is a **shareable state
//! core**: every method takes `&self` behind fine-grained interior
//! locks, so the actor-style workers of [`crate::daemon`] (ingest
//! folding, summary refreshes, fleet merges) operate on one
//! `Arc<Coordinator>` concurrently. The locking discipline keeps the
//! admission path independent of summarization:
//!
//! * [`Coordinator::offer`] takes only the ingest-queue mutex — never
//!   blocked by a refresh or fleet merge;
//! * [`Coordinator::refresh`] / [`Coordinator::fleet_summary`] copy
//!   window matrices out under a short machines lock and run the
//!   optimizer with **no lock held**;
//! * the shard transport has its own mutex, so fleet merges serialize
//!   against each other (replica state is shared) but against nothing
//!   else.
//!
//! Lock order (outer → inner, never reversed): config → ingest queue →
//! machines → plan cache → transport.

use crate::api::{self, ApiError, DatasetRef, ShardSpec, SummarizeRequest, SummarizeResponse};
use crate::config::schema::ServiceConfig;
use crate::coordinator::backpressure::{Admission, BoundedQueue, QueueStats};
use crate::coordinator::batcher::{adaptive_drain, group_by_machine};
use crate::coordinator::machine::{MachineState, Summary};
use crate::coordinator::router::{FleetSummary, RouteResult, Router, FLEET_QUERY};
use crate::coordinator::stream::{CycleRecord, StreamSource};
use crate::engine::{KernelImpl, OracleSpec, PlanRequest, PlanSource, ShardPlan};
use crate::linalg::{Matrix, SharedMatrix};
use crate::obs;
use crate::optim::{build_optimizer, Optimizer};
use crate::shard::ShardTransport;
use crate::submodular::Oracle;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Produces an oracle for a window matrix — the seam between the
/// coordinator and the evaluation backend (CPU baseline or XLA engine).
/// `Send + Sync` so fleet-level queries can build shard oracles from
/// pool workers concurrently (see [`crate::shard`]). The window travels
/// as a [`SharedMatrix`] (fleet merge + baseline oracles alias one
/// allocation) and the [`OracleSpec`] carries the fleet-plan handle and
/// per-oracle thread width of planned runs.
pub type OracleFactory = Box<dyn Fn(SharedMatrix, &OracleSpec) -> Box<dyn Oracle> + Send + Sync>;

/// Service-level counters, backed by a per-coordinator
/// [`obs::Registry`] so each instance counts independently (tests
/// assert exact values; a process-global registry would bleed across
/// coordinators). The handles are cheap clones of shared atomics —
/// read with `.get()`, bump with `.inc()`/`.add()`. The snapshot JSON
/// shape is unchanged (see [`crate::coordinator::snapshot`]); the full
/// registry — including latency histograms — is additionally exposed
/// via [`CoordinatorMetrics::registry`] for Prometheus-style
/// exposition.
pub struct CoordinatorMetrics {
    registry: obs::Registry,
    pub ingested: obs::Counter,
    pub malformed: obs::Counter,
    pub evicted: obs::Counter,
    pub throttle_signals: obs::Counter,
    pub refreshes: obs::Counter,
    pub refresh_seconds_total: obs::FCounter,
    pub queries: obs::Counter,
    /// Fleet-wide (`@fleet`) summary queries served.
    pub fleet_queries: obs::Counter,
    /// Non-empty shards executed by fleet queries (first stage).
    pub shard_runs: obs::Counter,
    /// Cumulative wall-clock of fleet-query merge stages.
    pub shard_merge_seconds_total: obs::FCounter,
    /// Worker replicas currently accepting shards (0 for the in-process
    /// transport; refreshed on every fleet query).
    pub replica_count: obs::Gauge,
    /// Shards re-queued after replica failures (cumulative).
    pub shard_retries: obs::Counter,
    /// Fleet queries whose shard transport failed outright and degraded
    /// to the in-process fallback (the answer was computed locally, not
    /// by the fleet).
    pub fleet_degraded: obs::Counter,
    /// Bytes moved over the shard transport (job + result frames).
    pub wire_bytes_total: obs::Counter,
    /// Latency distribution of summary refreshes (optimizer runs).
    pub refresh_latency: obs::Histogram,
    /// Latency distribution of ingest-batch grouping.
    pub batch_latency: obs::Histogram,
    /// End-to-end latency distribution of fleet queries.
    pub fleet_latency: obs::Histogram,
}

impl Default for CoordinatorMetrics {
    fn default() -> CoordinatorMetrics {
        let r = obs::Registry::new();
        CoordinatorMetrics {
            ingested: r.counter("coord_ingested_total", "records folded into machine windows"),
            malformed: r.counter("coord_malformed_total", "records rejected at ingest"),
            evicted: r.counter("coord_evicted_total", "queue evictions under backpressure"),
            throttle_signals: r
                .counter("coord_throttle_signals_total", "throttle advisories issued"),
            refreshes: r.counter("coord_refreshes_total", "per-machine summary refreshes"),
            refresh_seconds_total: r
                .fcounter("coord_refresh_seconds_total", "cumulative refresh wall-clock"),
            queries: r.counter("coord_queries_total", "operator queries served"),
            fleet_queries: r.counter("coord_fleet_queries_total", "fleet-wide queries served"),
            shard_runs: r
                .counter("coord_shard_runs_total", "non-empty shards executed by fleet queries"),
            shard_merge_seconds_total: r.fcounter(
                "coord_shard_merge_seconds_total",
                "cumulative fleet-query merge wall-clock",
            ),
            replica_count: r
                .gauge("coord_replica_count", "worker replicas currently accepting shards"),
            shard_retries: r
                .counter("coord_shard_retries_total", "shards re-queued after replica failures"),
            fleet_degraded: r.counter(
                "coord_fleet_degraded_total",
                "fleet queries degraded to the in-process transport",
            ),
            wire_bytes_total: r
                .counter("coord_wire_bytes_total", "bytes moved over the shard transport"),
            refresh_latency: r
                .histogram("coord_refresh_seconds", "summary refresh latency (seconds)"),
            batch_latency: r
                .histogram("coord_batch_seconds", "ingest-batch grouping latency (seconds)"),
            fleet_latency: r
                .histogram("coord_fleet_seconds", "fleet-query end-to-end latency (seconds)"),
            registry: r,
        }
    }
}

impl CoordinatorMetrics {
    /// The backing registry (for exposition / snapshots).
    pub fn registry(&self) -> &obs::Registry {
        &self.registry
    }
}

impl std::fmt::Debug for CoordinatorMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CoordinatorMetrics")
            .field("ingested", &self.ingested.get())
            .field("malformed", &self.malformed.get())
            .field("evicted", &self.evicted.get())
            .field("throttle_signals", &self.throttle_signals.get())
            .field("refreshes", &self.refreshes.get())
            .field("refresh_seconds_total", &self.refresh_seconds_total.get())
            .field("queries", &self.queries.get())
            .field("fleet_queries", &self.fleet_queries.get())
            .field("shard_runs", &self.shard_runs.get())
            .field("shard_merge_seconds_total", &self.shard_merge_seconds_total.get())
            .field("replica_count", &self.replica_count.get())
            .field("shard_retries", &self.shard_retries.get())
            .field("fleet_degraded", &self.fleet_degraded.get())
            .field("wire_bytes_total", &self.wire_bytes_total.get())
            .finish()
    }
}

/// The streaming summarization coordinator (shareable state core —
/// see the module docs for the locking discipline).
pub struct Coordinator {
    cfg: RwLock<ServiceConfig>,
    queue: Mutex<BoundedQueue<CycleRecord>>,
    machines: RwLock<BTreeMap<String, MachineState>>,
    oracle_factory: OracleFactory,
    /// Backend-aware plan builder (the XLA variant consults the artifact
    /// manifest); `None` plans the CPU split only.
    planner: Option<PlanSource>,
    /// One fleet plan per (window rows, dim, shards, k, batch, cores)
    /// request shape — repeated fleet queries over a stable fleet reuse
    /// the plan (and therefore the engine's loaded executables) instead
    /// of re-planning. Precision/kernel need no key slot: requests that
    /// disagree with the config's engine knobs are rejected up front
    /// (see [`Self::summarize`]).
    #[allow(clippy::type_complexity)]
    plan_cache: Mutex<BTreeMap<(usize, usize, usize, usize, usize, usize), Arc<ShardPlan>>>,
    /// Shard transport fleet queries dispatch stage 1 over (built from
    /// `[shard] transport`, swappable via [`Self::with_transport`]).
    /// Persistent across queries so replica state survives; its mutex
    /// serializes concurrent fleet merges.
    transport: Mutex<Box<dyn ShardTransport>>,
    /// Backend label for response provenance (set by
    /// [`crate::api::Service::coordinator`]).
    backend_label: String,
    pub metrics: CoordinatorMetrics,
    version: AtomicU64,
}

impl Coordinator {
    pub fn new(cfg: ServiceConfig, oracle_factory: OracleFactory) -> Coordinator {
        let queue = BoundedQueue::new(cfg.coordinator.queue_capacity);
        let mut machines = BTreeMap::new();
        for name in &cfg.machines {
            if name.starts_with('@') {
                log::warn!("ignoring machine '{name}': '@' names are reserved for routes");
                continue;
            }
            machines.insert(name.clone(), MachineState::new(name, cfg.summary.window.max(1)));
        }
        let transport = crate::shard::build_transport_with(
            &cfg.shard.transport,
            cfg.shard.replicas,
            &cfg.shard.net_options(),
        )
        .unwrap_or_else(|| unreachable!("schema validated transport '{}'", cfg.shard.transport));
        Coordinator {
            cfg: RwLock::new(cfg),
            queue: Mutex::new(queue),
            machines: RwLock::new(machines),
            oracle_factory,
            planner: None,
            plan_cache: Mutex::new(BTreeMap::new()),
            transport: Mutex::new(transport),
            backend_label: "custom".into(),
            metrics: CoordinatorMetrics::default(),
            version: AtomicU64::new(0),
        }
    }

    /// Label the evaluation backend for response provenance
    /// (`cpu` | `xla` when wired through [`crate::api::Service`]).
    pub fn with_backend_label(mut self, label: &str) -> Coordinator {
        self.backend_label = label.to_string();
        self
    }

    /// Attach a backend-aware plan builder for fleet queries (built by
    /// the launcher next to the oracle factory, so the coordinator never
    /// sees manifests or runtimes directly).
    pub fn with_planner(mut self, planner: PlanSource) -> Coordinator {
        self.planner = Some(planner);
        self
    }

    /// Replace the shard transport (e.g. a pre-populated replica fleet
    /// the caller keeps a handle to — see `examples/replica_fleet.rs`).
    pub fn with_transport(mut self, transport: Box<dyn ShardTransport>) -> Coordinator {
        self.transport = Mutex::new(transport);
        self
    }

    /// Run `f` against the shard transport fleet queries run over
    /// (holds the transport mutex for the duration of `f`).
    pub fn with_transport_ref<R>(&self, f: impl FnOnce(&dyn ShardTransport) -> R) -> R {
        f(self.transport.lock().unwrap().as_ref())
    }

    /// Replicas currently accepting shards on the fleet transport.
    pub fn transport_replica_count(&self) -> usize {
        self.transport.lock().unwrap().replica_count()
    }

    /// Get (building + caching on first use) the fleet plan for a
    /// request's window shape. `None` for unsharded or unplanned
    /// requests.
    fn fleet_plan(&self, n: usize, d: usize, req: &SummarizeRequest) -> Option<Arc<ShardPlan>> {
        let spec = req.shard.as_ref()?;
        if !spec.plan || n == 0 {
            return None;
        }
        let key = (n, d, spec.partitions, req.k, req.batch, spec.cores);
        let mut cache = self.plan_cache.lock().unwrap();
        if let Some(p) = cache.get(&key) {
            return Some(Arc::clone(p));
        }
        let preq = PlanRequest {
            n,
            d,
            shards: spec.partitions,
            k: req.k,
            batch: req.batch,
            precision: req.precision,
            kernel: KernelImpl::Jnp,
            cpu_kernel: req.cpu_kernel,
            cores: spec.cores,
        };
        let plan = match &self.planner {
            Some(build) => build(&preq),
            None => Arc::new(ShardPlan::plan(None, &preq)),
        };
        log::info!("fleet plan: {}", plan.describe());
        cache.insert(key, Arc::clone(&plan));
        Some(plan)
    }

    /// Answer one api request over this coordinator's backend: its
    /// long-lived oracle factory, its per-shape fleet-plan cache and
    /// its persistent shard transport (which always wins over the
    /// request's transport field — replica state must survive across
    /// queries). This is the api-typed entry the `@fleet` route goes
    /// through; external callers can hand it arbitrary requests, but
    /// the engine knobs (precision / cpu_kernel / threads) must match
    /// the coordinator's `[engine]` config — the factory is baked at
    /// construction, so mismatched knobs are rejected rather than
    /// silently substituted (use [`crate::api::Service`] for
    /// per-request knobs).
    pub fn summarize(&self, req: &SummarizeRequest) -> Result<SummarizeResponse, ApiError> {
        req.validate()?;
        // the coordinator's oracle factory is baked from `[engine]` at
        // construction; a request asking for different engine knobs
        // cannot be honored here (and must not be misreported in
        // provenance) — reject it instead of silently substituting
        let eng = self.cfg.read().unwrap().engine.clone();
        if req.precision != eng.precision {
            return Err(ApiError::invalid(
                "precision",
                format!(
                    "coordinator backend runs {} (request asked for {}); \
                     use api::Service for per-request knobs",
                    eng.precision.as_str(),
                    req.precision.as_str()
                ),
            ));
        }
        if req.cpu_kernel != eng.cpu_kernel {
            return Err(ApiError::invalid(
                "cpu_kernel",
                format!(
                    "coordinator backend runs the {} kernel (request asked for {}); \
                     use api::Service for per-request knobs",
                    eng.cpu_kernel.name(),
                    req.cpu_kernel.name()
                ),
            ));
        }
        if req.threads != 0 && req.threads != eng.cpu_threads {
            return Err(ApiError::invalid(
                "threads",
                format!(
                    "coordinator backend runs {} oracle thread(s) (request asked for {}); \
                     use api::Service for per-request knobs",
                    eng.cpu_threads, req.threads
                ),
            ));
        }
        let data = req.dataset.materialize()?;
        let plan = self.fleet_plan(data.rows(), data.cols(), req);
        let factory = |m: SharedMatrix, spec: &OracleSpec| (self.oracle_factory)(m, spec);
        // unsharded requests never touch the transport — don't serialize
        // them behind a fleet merge that may be mid-flight
        let guard = if req.shard.is_some() {
            Some(self.transport.lock().unwrap())
        } else {
            None
        };
        let env = api::ExecEnv {
            factory: &factory,
            backend: &self.backend_label,
            plan,
            planner: None,
            transport: guard.as_deref().map(|b| b.as_ref()),
        };
        api::execute(req, &data, &env)
    }

    /// The api request a fleet query executes: pooled window as an
    /// inline dataset, everything else from the `[summary]` / `[engine]`
    /// / `[shard]` config sections.
    fn fleet_request(&self, fleet_matrix: SharedMatrix, k: usize) -> SummarizeRequest {
        let cfg = self.cfg.read().unwrap();
        let sc = &cfg.shard;
        SummarizeRequest::new(DatasetRef::Inline(fleet_matrix), k)
            .optimizer(&cfg.summary.algorithm)
            .batch(cfg.engine.batch)
            .precision(cfg.engine.precision)
            .cpu_kernel(cfg.engine.cpu_kernel)
            .seed(sc.seed)
            .sharded(
                ShardSpec::new(sc.shards)
                    .partitioner(&sc.partitioner)
                    .per_shard_k(sc.per_shard_k)
                    .threads(sc.threads)
                    .transport(&sc.transport)
                    .replicas(sc.replicas)
                    .plan(sc.plan)
                    .cores(sc.cores)
                    .prune(sc.prune)
                    .fanout(sc.fanout)
                    .max_merge_n(sc.max_merge_n)
                    .merge_optimizer(&sc.merge_optimizer),
            )
    }

    fn build_optimizer(&self) -> Box<dyn Optimizer> {
        let (algorithm, batch) = {
            let cfg = self.cfg.read().unwrap();
            (cfg.summary.algorithm.clone(), cfg.engine.batch)
        };
        build_optimizer(&algorithm, batch)
            .unwrap_or_else(|| unreachable!("schema validated algorithm '{algorithm}'"))
    }

    /// Offer one record (sensor push path). Returns the admission
    /// advice. Takes only the ingest-queue mutex — admission is never
    /// blocked by a refresh or fleet merge in flight.
    pub fn offer(&self, rec: CycleRecord) -> Admission {
        let adm = self.queue.lock().unwrap().push(rec);
        match adm {
            Admission::AcceptedEvicted => self.metrics.evicted.inc(),
            Admission::AcceptedThrottle => self.metrics.throttle_signals.inc(),
            Admission::Accepted => {}
        }
        adm
    }

    /// One event-loop tick: drain a batch, fold into machines, refresh
    /// summaries that are due. Returns the number of records processed.
    ///
    /// This is the *synchronous* path (`run_stream`, tests, examples);
    /// the daemon splits it into [`Self::fold`] + queued refresh jobs so
    /// summarization runs off the ingest path.
    pub fn tick(&self) -> usize {
        let (count, due) = self.fold();
        for name in due {
            self.refresh(&name);
        }
        count
    }

    /// Drain one adaptive batch from the ingest queue and fold it into
    /// the machine windows *without* refreshing any summary. Returns
    /// the number of records folded and the machines whose refresh
    /// policy now triggers (for the caller to refresh inline — see
    /// [`Self::tick`] — or to enqueue as daemon jobs).
    ///
    /// Callers that fold concurrently must serialize their calls per
    /// ingest stream (the daemon runs ingest jobs single-flight) —
    /// otherwise batches can interleave out of arrival order.
    pub fn fold(&self) -> (usize, Vec<String>) {
        let (ingest_batch, window_cap, refresh_every) = {
            let cfg = self.cfg.read().unwrap();
            (cfg.coordinator.ingest_batch, cfg.summary.window.max(1), cfg.summary.refresh_every)
        };
        let records = {
            let mut q = self.queue.lock().unwrap();
            let drain = adaptive_drain(q.len(), ingest_batch, q.capacity());
            q.drain(drain)
        };
        let count = records.len();
        let grouped = self.metrics.batch_latency.time(|| group_by_machine(records));
        let mut machines = self.machines.write().unwrap();
        for (name, recs) in grouped {
            if name.starts_with('@') {
                // '@' prefixes are reserved for query routes (FLEET_QUERY);
                // a machine by such a name would be unqueryable
                log::warn!("dropping {} frame(s) from reserved name '{name}'", recs.len());
                self.metrics.malformed.add(recs.len() as u64);
                continue;
            }
            let m = machines
                .entry(name.clone())
                .or_insert_with(|| MachineState::new(&name, window_cap));
            for r in &recs {
                if m.ingest(r) {
                    self.metrics.ingested.inc();
                } else {
                    self.metrics.malformed.inc();
                }
            }
        }
        let due: Vec<String> = machines
            .iter()
            .filter(|(_, m)| m.needs_refresh(refresh_every))
            .map(|(n, _)| n.clone())
            .collect();
        (count, due)
    }

    /// Recompute the summary of one machine now. The optimizer runs
    /// with no lock held (the window is copied out under a short read
    /// lock). Returns false when the machine is unknown or its window
    /// is empty.
    pub fn refresh(&self, name: &str) -> bool {
        let window = {
            let machines = self.machines.read().unwrap();
            match machines.get(name) {
                Some(m) => m.window_matrix(),
                None => return false,
            }
        };
        let Some((window, seqs)) = window else { return false };
        let k = { self.cfg.read().unwrap().summary.k }.min(window.rows());
        let optimizer = self.build_optimizer();
        let t0 = Instant::now();
        let mut oracle = (self.oracle_factory)(Arc::new(window), &OracleSpec::unplanned());
        let res = {
            let _span = obs::span("coord.refresh");
            self.metrics.refresh_latency.time(|| optimizer.run(oracle.as_mut(), k))
        };
        let dt = t0.elapsed().as_secs_f64();
        let version = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let summary = Summary {
            representative_seqs: res.indices.iter().map(|&i| seqs[i]).collect(),
            representative_idx: res.indices.clone(),
            f_value: res.f_final,
            window_len: seqs.len(),
            refresh_seconds: dt,
            version,
        };
        self.metrics.refreshes.inc();
        self.metrics.refresh_seconds_total.add(dt);
        if let Some(m) = self.machines.write().unwrap().get_mut(name) {
            m.set_summary(summary);
        }
        true
    }

    /// Operator query: cached summary for `machine`, or — for the
    /// reserved [`FLEET_QUERY`] name — an on-demand fleet-wide summary.
    pub fn query(&self, machine: &str) -> RouteResult {
        self.metrics.queries.inc();
        if machine == FLEET_QUERY {
            return self.fleet_summary();
        }
        Router::query(&self.machines.read().unwrap(), machine)
    }

    /// Cached-state-only query: per-machine summaries from the router,
    /// never computing anything inline. The daemon serves operator
    /// queries through this (its scheduler refreshes the fleet summary
    /// as a background job, so [`FLEET_QUERY`] never runs a merge on
    /// the query path).
    pub fn query_cached(&self, machine: &str) -> RouteResult {
        self.metrics.queries.inc();
        Router::query(&self.machines.read().unwrap(), machine)
    }

    /// Answer "summarize the whole fleet": pool every machine's current
    /// window into one ground set and run the sharded two-stage
    /// summarizer over it with the `[shard]` config. Machines whose
    /// window is empty or whose sensor dimensionality differs from the
    /// fleet majority (the dimension carrying the most pooled rows)
    /// are skipped.
    pub fn fleet_summary(&self) -> RouteResult {
        self.metrics.fleet_queries.inc();
        // root of the fleet trace: api/shard/transport/wire/kernel spans
        // opened below (api::execute nests under the current span) hang
        // off this guard, so `obs-dump` shows one tree per fleet query.
        // Under the daemon this nests below the worker's daemon.job root.
        let _fleet_span = if obs::current_span() == 0 {
            obs::root_span("coord.fleet")
        } else {
            obs::span("coord.fleet")
        };

        // pool windows; rows[i] = (machine, seq) for fleet matrix row i.
        // Collect everything under a short read lock: the fleet
        // dimensionality is the one carrying the most pooled rows (a
        // lone rogue sensor must not hijack the fleet), and one up-front
        // allocation avoids the quadratic cost of repeated vstack.
        let (windows, skipped_empty, total_ingested) = {
            let machines = self.machines.read().unwrap();
            let mut windows: Vec<(String, Matrix, Vec<u64>)> = Vec::new();
            let mut skipped = 0usize;
            for (name, m) in machines.iter() {
                match m.window_matrix() {
                    Some((window, seqs)) => windows.push((name.clone(), window, seqs)),
                    None => skipped += 1,
                }
            }
            let total: u64 = machines.values().map(|m| m.total_ingested).sum();
            (windows, skipped, total)
        };
        let mut skipped = skipped_empty;
        // majority dimension by pooled row count (ties: larger dim)
        let mut rows_per_dim: BTreeMap<usize, usize> = BTreeMap::new();
        for (_, w, _) in &windows {
            *rows_per_dim.entry(w.cols()).or_default() += w.rows();
        }
        let Some((&d, _)) = rows_per_dim.iter().max_by_key(|(_, &r)| r) else {
            // nothing to pool yet: report aggregate ingestion progress
            return RouteResult::NotReady { ingested: total_ingested };
        };
        let mut machines_used = 0usize;
        let total_rows = rows_per_dim[&d];
        let mut data = Vec::with_capacity(total_rows * d);
        let mut rows: Vec<(String, u64)> = Vec::with_capacity(total_rows);
        for (name, window, seqs) in windows {
            if window.cols() != d {
                log::warn!(
                    "fleet query: skipping {name} (dim {} != fleet majority dim {d})",
                    window.cols()
                );
                skipped += 1;
                continue;
            }
            data.extend_from_slice(window.data());
            rows.extend(seqs.into_iter().map(|s| (name.clone(), s)));
            machines_used += 1;
        }
        let fleet_matrix: SharedMatrix = Arc::new(Matrix::from_vec(total_rows, d, data));
        let k = { self.cfg.read().unwrap().summary.k }.min(fleet_matrix.rows());
        if k == 0 {
            // a k = 0 config asks for an empty summary — not an error
            return RouteResult::Fleet(FleetSummary {
                representatives: vec![],
                f_value: 0.0,
                window_total: rows.len(),
                machines: machines_used,
                machines_skipped: skipped,
                shards: 0,
                shard_seconds: 0.0,
                merge_seconds: 0.0,
            });
        }

        let req = self.fleet_request(fleet_matrix, k);
        let t0 = Instant::now();
        let resp = match self.summarize(&req) {
            Ok(resp) => resp,
            // the config was schema-validated, so a failure here is an
            // execution-time one (backend death); answer NotReady
            // rather than killing the operator's query path
            Err(e) => {
                log::error!("fleet query failed: {e}");
                return RouteResult::NotReady { ingested: total_ingested };
            }
        };
        self.metrics.fleet_latency.observe(t0.elapsed().as_secs_f64());

        self.metrics.shard_runs.add(resp.provenance.shards_used as u64);
        self.metrics.shard_merge_seconds_total.add(resp.timings.merge_seconds);
        self.metrics.shard_retries.add(resp.provenance.shard_retries);
        self.metrics.wire_bytes_total.add(resp.provenance.wire_bytes);
        self.metrics.replica_count.set(self.transport_replica_count() as i64);
        if resp.provenance.degraded {
            self.metrics.fleet_degraded.inc();
        }

        RouteResult::Fleet(FleetSummary {
            representatives: resp
                .exemplars
                .iter()
                .map(|&i| rows[i as usize].clone())
                .collect(),
            f_value: resp.f_final,
            window_total: rows.len(),
            machines: machines_used,
            machines_skipped: skipped,
            shards: resp.provenance.shards_used,
            shard_seconds: resp.timings.shard_seconds,
            merge_seconds: resp.timings.merge_seconds,
        })
    }

    /// Drive a whole stream to exhaustion (utility for examples/tests).
    pub fn run_stream(&self, source: &mut dyn StreamSource) -> usize {
        let ingest_batch = self.cfg.read().unwrap().coordinator.ingest_batch;
        let mut total = 0;
        loop {
            let mut pushed = 0;
            // fill up to the ingest batch, then tick
            for _ in 0..ingest_batch {
                match source.next_record() {
                    Some(rec) => {
                        self.offer(rec);
                        pushed += 1;
                    }
                    None => break,
                }
            }
            if pushed == 0 && self.queue_len() == 0 {
                break;
            }
            total += self.tick();
        }
        // final flush
        while self.queue_len() > 0 {
            total += self.tick();
        }
        total
    }

    /// Run `f` over the per-machine state map (holds the machines read
    /// lock for the duration of `f` — keep it short).
    pub fn with_machines<R>(&self, f: impl FnOnce(&BTreeMap<String, MachineState>) -> R) -> R {
        f(&self.machines.read().unwrap())
    }

    /// Names of all machines currently tracked.
    pub fn machine_names(&self) -> Vec<String> {
        self.machines.read().unwrap().keys().cloned().collect()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Observable state of the ingest queue (depth, watermark, the
    /// once-dark accepted/evicted counters) — what the daemon exports
    /// as `ebc_daemon_ingest_*` metrics.
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.lock().unwrap().stats()
    }

    /// A clone of the current service config (live-reloadable — see
    /// [`Self::apply_config`]).
    pub fn config(&self) -> ServiceConfig {
        self.cfg.read().unwrap().clone()
    }

    /// Live config reload: swap every runtime-tunable section without
    /// dropping machine windows or queued records. Returns the list of
    /// sections that changed. The `[engine]` section is baked into the
    /// oracle factory at construction and cannot be swapped here —
    /// a changed engine section is a typed error (restart required).
    ///
    /// Applied live: `[summary]` (k / algorithm / refresh cadence;
    /// window resize trims or grows per-machine windows in place),
    /// `[coordinator]` (queue capacity resizes preserving queued
    /// records, ingest batch), `[shard]` (plan cache is dropped; the
    /// transport is rebuilt only when its knobs changed — replica
    /// registries otherwise survive), `machines` (new names are added;
    /// existing windows are never dropped), `[obs]` (span switch).
    pub fn apply_config(&self, new: ServiceConfig) -> Result<Vec<&'static str>, String> {
        let old = self.cfg.read().unwrap().clone();
        if new.engine != old.engine {
            return Err(
                "the [engine] section is baked into the oracle factory at startup and cannot \
                 be live-reloaded (restart the daemon to change precision/kernel/threads)"
                    .into(),
            );
        }
        let mut applied = Vec::new();
        if new.summary != old.summary {
            applied.push("summary");
            if new.summary.window != old.summary.window {
                let cap = new.summary.window.max(1);
                for m in self.machines.write().unwrap().values_mut() {
                    m.set_window_cap(cap);
                }
            }
        }
        if new.coordinator != old.coordinator {
            applied.push("coordinator");
            if new.coordinator.queue_capacity != old.coordinator.queue_capacity {
                self.queue.lock().unwrap().set_capacity(new.coordinator.queue_capacity);
            }
        }
        if new.shard != old.shard {
            applied.push("shard");
            self.plan_cache.lock().unwrap().clear();
            // only rebuild the transport when its own knobs moved —
            // a replica registry's accumulated state survives plain
            // shard-count / partitioner changes
            if new.shard.transport != old.shard.transport
                || new.shard.replicas != old.shard.replicas
                || new.shard.net_options() != old.shard.net_options()
            {
                let t = crate::shard::build_transport_with(
                    &new.shard.transport,
                    new.shard.replicas,
                    &new.shard.net_options(),
                )
                .ok_or_else(|| format!("unknown shard transport '{}'", new.shard.transport))?;
                *self.transport.lock().unwrap() = t;
            }
        }
        if new.machines != old.machines {
            applied.push("machines");
            let cap = new.summary.window.max(1);
            let mut machines = self.machines.write().unwrap();
            for name in &new.machines {
                if name.starts_with('@') {
                    log::warn!("ignoring machine '{name}': '@' names are reserved for routes");
                    continue;
                }
                machines
                    .entry(name.clone())
                    .or_insert_with(|| MachineState::new(name, cap));
            }
        }
        if new.obs != old.obs {
            applied.push("obs");
            obs::configure(&new.obs.obs_config());
        }
        if new.name != old.name {
            applied.push("name");
        }
        *self.cfg.write().unwrap() = new;
        Ok(applied)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::CpuOracle;

    fn cpu_factory() -> OracleFactory {
        Box::new(|m: SharedMatrix, _spec: &OracleSpec| {
            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
        })
    }

    fn cfg(k: usize, refresh_every: usize, window: usize) -> ServiceConfig {
        let mut c = ServiceConfig::default();
        c.summary.k = k;
        c.summary.refresh_every = refresh_every;
        c.summary.window = window;
        c.summary.algorithm = "greedy".into();
        c.engine.batch = 64;
        c
    }

    fn rec(m: &str, seq: u64, x: f32) -> CycleRecord {
        CycleRecord { machine: m.into(), seq, values: vec![x, x * 0.5, 1.0] }
    }

    #[test]
    fn ingests_and_refreshes() {
        let c = Coordinator::new(cfg(2, 5, 100), cpu_factory());
        for s in 0..20u64 {
            c.offer(rec("m1", s, s as f32));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested.get(), 20);
        assert!(c.metrics.refreshes.get() >= 1);
        match c.query("m1") {
            RouteResult::Summary(s) => {
                assert!(s.representative_seqs.len() <= 2);
                assert!(s.window_len <= 20);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn coordinator_is_shareable_across_threads() {
        // the daemon contract: Arc<Coordinator> + &self methods
        let c = Arc::new(Coordinator::new(cfg(2, 5, 100), cpu_factory()));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for s in 0..25u64 {
                    c.offer(rec(&format!("m{t}"), s, (s + t) as f32));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested.get(), 100);
        for t in 0..4 {
            assert!(matches!(c.query(&format!("m{t}")), RouteResult::Summary(_)));
        }
    }

    #[test]
    fn fold_defers_refreshes_to_caller() {
        let c = Coordinator::new(cfg(2, 5, 100), cpu_factory());
        for s in 0..20u64 {
            c.offer(rec("m1", s, s as f32));
        }
        let mut due_seen = false;
        while c.queue_len() > 0 {
            let (_, due) = c.fold();
            if !due.is_empty() {
                assert_eq!(due, vec!["m1".to_string()]);
                due_seen = true;
            }
        }
        // fold alone never refreshed anything
        assert!(due_seen);
        assert_eq!(c.metrics.refreshes.get(), 0);
        assert!(c.refresh("m1"));
        assert_eq!(c.metrics.refreshes.get(), 1);
        assert!(!c.refresh("no-such-machine"));
    }

    #[test]
    fn summary_seqs_track_window() {
        // window of 10: after 30 records the reps must be from seq >= 20
        let c = Coordinator::new(cfg(3, 5, 10), cpu_factory());
        for s in 0..30u64 {
            c.offer(rec("m1", s, (s % 7) as f32));
            c.tick();
        }
        c.refresh("m1");
        match c.query("m1") {
            RouteResult::Summary(s) => {
                assert!(s.representative_seqs.iter().all(|&q| q >= 20), "{:?}", s.representative_seqs);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frames_counted() {
        let c = Coordinator::new(cfg(2, 100, 50), cpu_factory());
        c.offer(rec("m1", 0, 1.0));
        c.offer(CycleRecord { machine: "m1".into(), seq: 1, values: vec![1.0] }); // wrong dim
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested.get(), 1);
        assert_eq!(c.metrics.malformed.get(), 1);
        assert!(c.metrics.refresh_latency.snapshot().count == c.metrics.refreshes.get());
    }

    #[test]
    fn unknown_machine_routes() {
        let c = Coordinator::new(cfg(2, 5, 10), cpu_factory());
        c.offer(rec("alpha", 0, 1.0));
        c.tick();
        match c.query("alhpa") {
            RouteResult::UnknownMachine { suggestions } => {
                assert_eq!(suggestions[0], "alpha");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn backpressure_evicts_under_burst() {
        let mut small = cfg(2, 1000, 10);
        small.coordinator.queue_capacity = 16;
        let c = Coordinator::new(small, cpu_factory());
        for s in 0..100u64 {
            c.offer(rec("m", s, s as f32));
        }
        assert!(c.metrics.evicted.get() > 0);
        let stats = c.queue_stats();
        assert_eq!(stats.accepted, 100);
        assert_eq!(stats.evicted, c.metrics.evicted.get());
        assert!(stats.above_watermark);
        while c.queue_len() > 0 {
            c.tick();
        }
        // freshest records survived
        c.with_machines(|ms| {
            let (_, seqs) = ms["m"].window_matrix().unwrap();
            assert_eq!(*seqs.last().unwrap(), 99);
        });
    }

    #[test]
    fn fleet_query_shards_merges_and_counts() {
        let mut cfg = cfg(3, 1000, 100);
        cfg.shard.shards = 2;
        let c = Coordinator::new(cfg, cpu_factory());
        for m in ["m1", "m2", "m3"] {
            for s in 0..12u64 {
                c.offer(rec(m, s, (s as f32) + m.len() as f32));
            }
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        match c.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => {
                assert_eq!(f.machines, 3);
                assert_eq!(f.machines_skipped, 0);
                assert_eq!(f.window_total, 36);
                assert_eq!(f.shards, 2);
                assert!(f.representatives.len() <= 3 && !f.representatives.is_empty());
                assert!(f.f_value > 0.0);
                for (m, seq) in &f.representatives {
                    assert!(["m1", "m2", "m3"].contains(&m.as_str()), "{m}");
                    assert!(*seq < 12, "{seq}");
                }
            }
            other => panic!("{other:?}"),
        }
        // the new counters moved
        assert_eq!(c.metrics.fleet_queries.get(), 1);
        assert_eq!(c.metrics.shard_runs.get(), 2);
        assert!(c.metrics.shard_merge_seconds_total.get() > 0.0);
        assert_eq!(c.metrics.queries.get(), 1); // fleet queries count as queries too
        assert!(c.metrics.wire_bytes_total.get() > 0, "fleet query moved no wire bytes");
        assert_eq!(c.metrics.shard_retries.get(), 0);
        assert_eq!(c.metrics.fleet_degraded.get(), 0, "healthy fleet reported degraded");
        assert_eq!(c.metrics.replica_count.get(), 0, "inproc transport has no replicas");
        assert_eq!(c.metrics.fleet_latency.snapshot().count, 1);
        let bytes_after_one = c.metrics.wire_bytes_total.get();
        c.query(FLEET_QUERY);
        assert_eq!(c.metrics.fleet_queries.get(), 2);
        assert_eq!(c.metrics.shard_runs.get(), 4);
        assert_eq!(c.metrics.wire_bytes_total.get(), 2 * bytes_after_one);
    }

    #[test]
    fn loopback_fleet_query_survives_replica_failure_with_identical_reps() {
        use crate::shard::LoopbackReplicaTransport;
        use std::sync::Arc as StdArc;
        let mk = |transport: Option<Box<dyn ShardTransport>>| {
            let mut cfg = cfg(3, 1000, 100);
            cfg.shard.shards = 4;
            let mut c = Coordinator::new(cfg, cpu_factory());
            if let Some(t) = transport {
                c = c.with_transport(t);
            }
            for m in ["m1", "m2", "m3"] {
                for s in 0..10u64 {
                    c.offer(rec(m, s, (s as f32) * 1.7 + m.len() as f32));
                }
            }
            while c.queue_len() > 0 {
                c.tick();
            }
            c
        };
        let reps_of = |c: &Coordinator| match c.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => f.representatives,
            other => panic!("{other:?}"),
        };

        let healthy = mk(None);
        let want = reps_of(&healthy);

        let chaos = StdArc::new(LoopbackReplicaTransport::with_replicas(3, 1));
        chaos.fail_after("replica-0", 1); // dies after its first shard
        let degraded = mk(Some(Box::new(StdArc::clone(&chaos))));
        let got = reps_of(&degraded);
        assert_eq!(got, want, "replica failure changed the selection");
        assert!(degraded.metrics.shard_retries.get() >= 1, "no retry counted");
        assert_eq!(degraded.metrics.replica_count.get(), 2, "dead replica still counted");
        assert!(degraded.metrics.wire_bytes_total.get() > 0);

        // a drained replica receives no new shards on the next query
        let done_before = chaos.with_registry(|r| r.get("replica-2").unwrap().jobs_done);
        chaos.drain("replica-2");
        let again = reps_of(&degraded);
        assert_eq!(again, want);
        assert_eq!(
            chaos.with_registry(|r| r.get("replica-2").unwrap().jobs_done),
            done_before,
            "drained replica still received shards"
        );
        assert_eq!(degraded.metrics.replica_count.get(), 1);
    }

    #[test]
    fn fleet_queries_reuse_one_plan_per_window_shape() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut cfg = cfg(3, 1000, 100);
        cfg.shard.shards = 2;
        let planned_oracles = Arc::new(AtomicUsize::new(0));
        let po = Arc::clone(&planned_oracles);
        let factory: OracleFactory = Box::new(move |m: SharedMatrix, spec: &OracleSpec| {
            if spec.plan.is_some() {
                po.fetch_add(1, Ordering::SeqCst);
            }
            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
        });
        let plans_built = Arc::new(AtomicUsize::new(0));
        let pb = Arc::clone(&plans_built);
        let c = Coordinator::new(cfg, factory).with_planner(Box::new(move |req| {
            pb.fetch_add(1, Ordering::SeqCst);
            Arc::new(ShardPlan::plan(None, req))
        }));
        for m in ["m1", "m2"] {
            for s in 0..10u64 {
                c.offer(rec(m, s, s as f32));
            }
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        assert!(matches!(c.query(FLEET_QUERY), RouteResult::Fleet(_)));
        assert!(matches!(c.query(FLEET_QUERY), RouteResult::Fleet(_)));
        // same (n, d, P) window shape twice: the plan is built once...
        assert_eq!(plans_built.load(Ordering::SeqCst), 1);
        // ...and every fleet oracle (2 shards + merge, per query) got it
        assert_eq!(planned_oracles.load(Ordering::SeqCst), 2 * 3);
    }

    #[test]
    fn fleet_plan_disabled_keeps_unplanned_specs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut cfg = cfg(2, 1000, 100);
        cfg.shard.shards = 2;
        cfg.shard.plan = false;
        let planned_oracles = Arc::new(AtomicUsize::new(0));
        let po = Arc::clone(&planned_oracles);
        let factory: OracleFactory = Box::new(move |m: SharedMatrix, spec: &OracleSpec| {
            if spec.plan.is_some() || spec.threads.is_some() {
                po.fetch_add(1, Ordering::SeqCst);
            }
            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
        });
        let c = Coordinator::new(cfg, factory);
        for s in 0..8u64 {
            c.offer(rec("m1", s, s as f32));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        assert!(matches!(c.query(FLEET_QUERY), RouteResult::Fleet(_)));
        assert_eq!(planned_oracles.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn summarize_rejects_engine_knobs_the_factory_cannot_honor() {
        use crate::api::{DatasetRef, SummarizeRequest};
        use crate::engine::Precision;
        use crate::linalg::CpuKernel;
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        let mut rng = crate::util::rng::Rng::new(4);
        let ds = DatasetRef::Inline(Arc::new(Matrix::random_normal(20, 3, &mut rng)));
        // matching knobs run fine (engine defaults: f32 / blocked / 0)
        let ok = SummarizeRequest::new(ds.clone(), 3);
        assert!(c.summarize(&ok).is_ok());
        // mismatched knobs are typed errors, not silent substitutions
        let bf16 = SummarizeRequest::new(ds.clone(), 3).precision(Precision::Bf16);
        assert!(matches!(
            c.summarize(&bf16),
            Err(crate::api::ApiError::Invalid { field: "precision", .. })
        ));
        let scalar = SummarizeRequest::new(ds.clone(), 3).cpu_kernel(CpuKernel::Scalar);
        assert!(matches!(
            c.summarize(&scalar),
            Err(crate::api::ApiError::Invalid { field: "cpu_kernel", .. })
        ));
        let threads = SummarizeRequest::new(ds, 3).threads(7);
        assert!(matches!(
            c.summarize(&threads),
            Err(crate::api::ApiError::Invalid { field: "threads", .. })
        ));
    }

    #[test]
    fn fleet_dimension_is_majority_not_first() {
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        // "aaa-probe" sorts first but carries the minority dimension
        c.offer(CycleRecord { machine: "aaa-probe".into(), seq: 0, values: vec![1.0, 2.0] });
        for s in 0..6u64 {
            c.offer(rec("m1", s, s as f32));
            c.offer(rec("m2", s, s as f32 + 1.0));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        match c.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => {
                assert_eq!(f.machines, 2);
                assert_eq!(f.machines_skipped, 1);
                assert_eq!(f.window_total, 12);
                assert!(f.representatives.iter().all(|(m, _)| m != "aaa-probe"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn reserved_route_names_rejected_at_ingest() {
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        c.offer(rec("@fleet", 0, 1.0));
        c.offer(rec("ok", 0, 1.0));
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested.get(), 1);
        assert_eq!(c.metrics.malformed.get(), 1);
        assert!(!c.with_machines(|ms| ms.contains_key("@fleet")));
        // the route still answers as a fleet query
        assert!(matches!(c.query(FLEET_QUERY), RouteResult::Fleet(_)));
    }

    #[test]
    fn fleet_query_without_data_is_not_ready() {
        let c = Coordinator::new(cfg(2, 5, 10), cpu_factory());
        match c.query(FLEET_QUERY) {
            RouteResult::NotReady { ingested: 0 } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(c.metrics.fleet_queries.get(), 1);
        assert_eq!(c.metrics.shard_runs.get(), 0);
    }

    #[test]
    fn fleet_query_skips_dimension_mismatched_machines() {
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        // m1 produces 3-dim cycles (the `rec` helper), modd 2-dim ones
        for s in 0..8u64 {
            c.offer(rec("m1", s, s as f32));
            c.offer(CycleRecord {
                machine: "modd".into(),
                seq: s,
                values: vec![s as f32, 1.0],
            });
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        match c.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => {
                assert_eq!(f.machines, 1);
                assert_eq!(f.machines_skipped, 1);
                assert_eq!(f.window_total, 8);
                assert!(f.representatives.iter().all(|(m, _)| m == "m1"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn query_cached_never_computes_fleet_inline() {
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        for s in 0..6u64 {
            c.offer(rec("m1", s, s as f32));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        c.refresh("m1");
        assert!(matches!(c.query_cached("m1"), RouteResult::Summary(_)));
        // the reserved fleet route resolves through the router (no
        // machine named '@fleet' exists), not through a merge
        let fleet_before = c.metrics.fleet_queries.get();
        assert!(matches!(c.query_cached(FLEET_QUERY), RouteResult::UnknownMachine { .. }));
        assert_eq!(c.metrics.fleet_queries.get(), fleet_before);
    }

    #[test]
    fn apply_config_preserves_windows_and_rejects_engine_changes() {
        let c = Coordinator::new(cfg(2, 1000, 50), cpu_factory());
        for s in 0..20u64 {
            c.offer(rec("m1", s, s as f32));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        let window_before = c.with_machines(|ms| ms["m1"].window_len());
        assert_eq!(window_before, 20);

        // live-tunable sections apply; windows survive
        let mut new = c.config();
        new.summary.k = 3;
        new.summary.refresh_every = 7;
        new.coordinator.queue_capacity = 512;
        new.machines = vec!["m1".into(), "m-new".into()];
        let applied = c.apply_config(new).unwrap();
        assert!(applied.contains(&"summary"));
        assert!(applied.contains(&"coordinator"));
        assert!(applied.contains(&"machines"));
        assert_eq!(c.with_machines(|ms| ms["m1"].window_len()), window_before);
        assert!(c.with_machines(|ms| ms.contains_key("m-new")));
        assert_eq!(c.config().summary.k, 3);
        assert_eq!(c.queue_stats().capacity, 512);

        // shrinking the window trims in place, preserving fresh cycles
        let mut shrink = c.config();
        shrink.summary.window = 8;
        c.apply_config(shrink).unwrap();
        c.with_machines(|ms| {
            let (_, seqs) = ms["m1"].window_matrix().unwrap();
            assert_eq!(seqs.len(), 8);
            assert_eq!(*seqs.last().unwrap(), 19);
        });

        // engine changes are rejected with the windows untouched
        let mut eng = c.config();
        eng.engine.cpu_threads = 9;
        assert!(c.apply_config(eng).is_err());
        assert_eq!(c.with_machines(|ms| ms["m1"].window_len()), 8);
    }

    #[test]
    fn run_stream_processes_everything() {
        use crate::coordinator::stream::SimulatedFleet;
        use crate::imm::{Part, ProcessState};
        let mut cfg = cfg(3, 50, 200);
        cfg.coordinator.queue_capacity = 4096;
        let c = Coordinator::new(cfg, cpu_factory());
        let mut fleet = SimulatedFleet::new(
            &[("a", Part::Cover, ProcessState::Stable)],
            16,
            3,
        );
        let n = c.run_stream(&mut fleet);
        assert_eq!(n, 1000);
        assert!(matches!(c.query("a"), RouteResult::Summary(_)));
    }
}
