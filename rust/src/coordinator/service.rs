//! The coordinator service: ties queue → batcher → machines → optimizer.

use crate::config::schema::ServiceConfig;
use crate::coordinator::backpressure::{Admission, BoundedQueue};
use crate::coordinator::batcher::{adaptive_drain, group_by_machine};
use crate::coordinator::machine::{MachineState, Summary};
use crate::coordinator::router::{RouteResult, Router};
use crate::coordinator::stream::{CycleRecord, StreamSource};
use crate::linalg::Matrix;
use crate::optim::{
    Greedy, LazyGreedy, Optimizer, RandomSelection, SieveStreaming, SieveStreamingPp,
    StochasticGreedy, ThreeSieves,
};
use crate::submodular::Oracle;
use crate::util::timer::Profile;
use std::collections::BTreeMap;
use std::time::Instant;

/// Produces an oracle for a window matrix — the seam between the
/// coordinator and the evaluation backend (CPU baseline or XLA engine).
pub type OracleFactory = Box<dyn Fn(Matrix) -> Box<dyn Oracle>>;

/// Service-level counters.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorMetrics {
    pub ingested: u64,
    pub malformed: u64,
    pub evicted: u64,
    pub throttle_signals: u64,
    pub refreshes: u64,
    pub refresh_seconds_total: f64,
    pub queries: u64,
}

/// The streaming summarization coordinator.
pub struct Coordinator {
    cfg: ServiceConfig,
    queue: BoundedQueue<CycleRecord>,
    machines: BTreeMap<String, MachineState>,
    oracle_factory: OracleFactory,
    pub metrics: CoordinatorMetrics,
    pub profile: Profile,
    version: u64,
}

impl Coordinator {
    pub fn new(cfg: ServiceConfig, oracle_factory: OracleFactory) -> Coordinator {
        let queue = BoundedQueue::new(cfg.coordinator.queue_capacity);
        let mut machines = BTreeMap::new();
        for name in &cfg.machines {
            machines.insert(name.clone(), MachineState::new(name, cfg.summary.window.max(1)));
        }
        Coordinator {
            cfg,
            queue,
            machines,
            oracle_factory,
            metrics: CoordinatorMetrics::default(),
            profile: Profile::new(),
            version: 0,
        }
    }

    fn build_optimizer(&self) -> Box<dyn Optimizer> {
        match self.cfg.summary.algorithm.as_str() {
            "greedy" => Box::new(Greedy { batch: self.cfg.engine.batch }),
            "lazy_greedy" => Box::new(LazyGreedy::default()),
            "stochastic_greedy" => Box::new(StochasticGreedy::default()),
            "sieve_streaming" => Box::new(SieveStreaming::default()),
            "sieve_streaming_pp" => Box::new(SieveStreamingPp::default()),
            "three_sieves" => Box::new(ThreeSieves { epsilon: 0.1, t: 50 }),
            "random" => Box::new(RandomSelection::default()),
            other => unreachable!("schema validated algorithm '{other}'"),
        }
    }

    /// Offer one record (sensor push path). Returns the admission advice.
    pub fn offer(&mut self, rec: CycleRecord) -> Admission {
        let adm = self.queue.push(rec);
        match adm {
            Admission::AcceptedEvicted => self.metrics.evicted += 1,
            Admission::AcceptedThrottle => self.metrics.throttle_signals += 1,
            Admission::Accepted => {}
        }
        adm
    }

    /// One event-loop tick: drain a batch, fold into machines, refresh
    /// summaries that are due. Returns the number of records processed.
    pub fn tick(&mut self) -> usize {
        let drain = adaptive_drain(
            self.queue.len(),
            self.cfg.coordinator.ingest_batch,
            self.queue.capacity(),
        );
        let records = self.queue.drain(drain);
        let count = records.len();
        let grouped = self.profile.scope("coord.batch", || group_by_machine(records));
        for (name, recs) in grouped {
            let window_cap = self.cfg.summary.window.max(1);
            let m = self
                .machines
                .entry(name.clone())
                .or_insert_with(|| MachineState::new(&name, window_cap));
            for r in &recs {
                if m.ingest(r) {
                    self.metrics.ingested += 1;
                } else {
                    self.metrics.malformed += 1;
                }
            }
        }
        // refresh pass
        let due: Vec<String> = self
            .machines
            .iter()
            .filter(|(_, m)| m.needs_refresh(self.cfg.summary.refresh_every))
            .map(|(n, _)| n.clone())
            .collect();
        for name in due {
            self.refresh(&name);
        }
        count
    }

    /// Recompute the summary of one machine now.
    pub fn refresh(&mut self, name: &str) {
        let Some(m) = self.machines.get(name) else { return };
        let Some((window, seqs)) = m.window_matrix() else { return };
        let k = self.cfg.summary.k.min(window.rows());
        let optimizer = self.build_optimizer();
        let t0 = Instant::now();
        let mut oracle = (self.oracle_factory)(window);
        let res = self
            .profile
            .scope("coord.refresh", || optimizer.run(oracle.as_mut(), k));
        let dt = t0.elapsed().as_secs_f64();
        self.version += 1;
        let summary = Summary {
            representative_seqs: res.indices.iter().map(|&i| seqs[i]).collect(),
            representative_idx: res.indices.clone(),
            f_value: res.f_final,
            window_len: seqs.len(),
            refresh_seconds: dt,
            version: self.version,
        };
        self.metrics.refreshes += 1;
        self.metrics.refresh_seconds_total += dt;
        if let Some(m) = self.machines.get_mut(name) {
            m.set_summary(summary);
        }
    }

    /// Operator query: cached summary for `machine`.
    pub fn query(&mut self, machine: &str) -> RouteResult {
        self.metrics.queries += 1;
        Router::query(&self.machines, machine)
    }

    /// Drive a whole stream to exhaustion (utility for examples/tests).
    pub fn run_stream(&mut self, source: &mut dyn StreamSource) -> usize {
        let mut total = 0;
        loop {
            let mut pushed = 0;
            // fill up to the ingest batch, then tick
            for _ in 0..self.cfg.coordinator.ingest_batch {
                match source.next_record() {
                    Some(rec) => {
                        self.offer(rec);
                        pushed += 1;
                    }
                    None => break,
                }
            }
            if pushed == 0 && self.queue.is_empty() {
                break;
            }
            total += self.tick();
        }
        // final flush
        while !self.queue.is_empty() {
            total += self.tick();
        }
        total
    }

    pub fn machines(&self) -> &BTreeMap<String, MachineState> {
        &self.machines
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::CpuOracle;

    fn cpu_factory() -> OracleFactory {
        Box::new(|m: Matrix| Box::new(CpuOracle::new(m)) as Box<dyn Oracle>)
    }

    fn cfg(k: usize, refresh_every: usize, window: usize) -> ServiceConfig {
        let mut c = ServiceConfig::default();
        c.summary.k = k;
        c.summary.refresh_every = refresh_every;
        c.summary.window = window;
        c.summary.algorithm = "greedy".into();
        c.engine.batch = 64;
        c
    }

    fn rec(m: &str, seq: u64, x: f32) -> CycleRecord {
        CycleRecord { machine: m.into(), seq, values: vec![x, x * 0.5, 1.0] }
    }

    #[test]
    fn ingests_and_refreshes() {
        let mut c = Coordinator::new(cfg(2, 5, 100), cpu_factory());
        for s in 0..20u64 {
            c.offer(rec("m1", s, s as f32));
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested, 20);
        assert!(c.metrics.refreshes >= 1);
        match c.query("m1") {
            RouteResult::Summary(s) => {
                assert!(s.representative_seqs.len() <= 2);
                assert!(s.window_len <= 20);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn summary_seqs_track_window() {
        // window of 10: after 30 records the reps must be from seq >= 20
        let mut c = Coordinator::new(cfg(3, 5, 10), cpu_factory());
        for s in 0..30u64 {
            c.offer(rec("m1", s, (s % 7) as f32));
            c.tick();
        }
        c.refresh("m1");
        match c.query("m1") {
            RouteResult::Summary(s) => {
                assert!(s.representative_seqs.iter().all(|&q| q >= 20), "{:?}", s.representative_seqs);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frames_counted() {
        let mut c = Coordinator::new(cfg(2, 100, 50), cpu_factory());
        c.offer(rec("m1", 0, 1.0));
        c.offer(CycleRecord { machine: "m1".into(), seq: 1, values: vec![1.0] }); // wrong dim
        while c.queue_len() > 0 {
            c.tick();
        }
        assert_eq!(c.metrics.ingested, 1);
        assert_eq!(c.metrics.malformed, 1);
    }

    #[test]
    fn unknown_machine_routes() {
        let mut c = Coordinator::new(cfg(2, 5, 10), cpu_factory());
        c.offer(rec("alpha", 0, 1.0));
        c.tick();
        match c.query("alhpa") {
            RouteResult::UnknownMachine { suggestions } => {
                assert_eq!(suggestions[0], "alpha");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn backpressure_evicts_under_burst() {
        let mut small = cfg(2, 1000, 10);
        small.coordinator.queue_capacity = 16;
        let mut c = Coordinator::new(small, cpu_factory());
        for s in 0..100u64 {
            c.offer(rec("m", s, s as f32));
        }
        assert!(c.metrics.evicted > 0);
        while c.queue_len() > 0 {
            c.tick();
        }
        // freshest records survived
        let m = &c.machines()["m"];
        let (_, seqs) = m.window_matrix().unwrap();
        assert_eq!(*seqs.last().unwrap(), 99);
    }

    #[test]
    fn run_stream_processes_everything() {
        use crate::coordinator::stream::SimulatedFleet;
        use crate::imm::{Part, ProcessState};
        let mut cfg = cfg(3, 50, 200);
        cfg.coordinator.queue_capacity = 4096;
        let mut c = Coordinator::new(cfg, cpu_factory());
        let mut fleet = SimulatedFleet::new(
            &[("a", Part::Cover, ProcessState::Stable)],
            16,
            3,
        );
        let n = c.run_stream(&mut fleet);
        assert_eq!(n, 1000);
        assert!(matches!(c.query("a"), RouteResult::Summary(_)));
    }
}
