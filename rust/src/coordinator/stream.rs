//! Cycle ingestion sources.

use crate::imm::{generate_dataset_with, Part, ProcessState};
use crate::util::rng::Rng;

/// One molding cycle arriving from a machine's sensor recorder.
#[derive(Debug, Clone)]
pub struct CycleRecord {
    pub machine: String,
    /// Machine-local monotone sequence number.
    pub seq: u64,
    /// Melt-pressure curve.
    pub values: Vec<f32>,
}

/// A pullable stream of cycle records (None = exhausted).
pub trait StreamSource {
    fn next_record(&mut self) -> Option<CycleRecord>;
}

/// Simulated fleet: each machine replays a generated IMM campaign;
/// records are interleaved round-robin with random skips, approximating
/// asynchronous arrival.
pub struct SimulatedFleet {
    machines: Vec<FleetMachine>,
    rng: Rng,
    cursor: usize,
}

struct FleetMachine {
    name: String,
    data: crate::linalg::Matrix,
    next: usize,
    seq: u64,
}

impl SimulatedFleet {
    /// Build a fleet of `specs` = (name, part, state) with `samples`-dim
    /// cycles (use a small value in tests, 3524 for realism).
    pub fn new(specs: &[(&str, Part, ProcessState)], samples: usize, seed: u64) -> SimulatedFleet {
        let machines = specs
            .iter()
            .enumerate()
            .map(|(i, (name, part, state))| FleetMachine {
                name: name.to_string(),
                data: generate_dataset_with(*part, *state, seed + i as u64, samples).cycles,
                next: 0,
                seq: 0,
            })
            .collect();
        SimulatedFleet { machines, rng: Rng::new(seed ^ 0xF1EE7), cursor: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.machines.iter().map(|m| m.data.rows() - m.next).sum()
    }
}

impl StreamSource for SimulatedFleet {
    fn next_record(&mut self) -> Option<CycleRecord> {
        let n = self.machines.len();
        for _ in 0..n {
            let i = self.cursor % n;
            self.cursor += 1;
            // random skip: not all machines produce at identical rates
            if self.rng.f32() < 0.2 {
                continue;
            }
            let m = &mut self.machines[i];
            if m.next < m.data.rows() {
                let rec = CycleRecord {
                    machine: m.name.clone(),
                    seq: m.seq,
                    values: m.data.row(m.next).to_vec(),
                };
                m.next += 1;
                m.seq += 1;
                return Some(rec);
            }
        }
        // fall back to strict order to drain the tail
        for m in self.machines.iter_mut() {
            if m.next < m.data.rows() {
                let rec = CycleRecord {
                    machine: m.name.clone(),
                    seq: m.seq,
                    values: m.data.row(m.next).to_vec(),
                };
                m.next += 1;
                m.seq += 1;
                return Some(rec);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_drains_completely() {
        let mut fleet = SimulatedFleet::new(
            &[
                ("a", Part::Cover, ProcessState::Stable),
                ("b", Part::Plate, ProcessState::StartUp),
            ],
            32,
            1,
        );
        let total = fleet.remaining();
        assert_eq!(total, 2000);
        let mut count = 0;
        let mut per_machine = std::collections::BTreeMap::new();
        while let Some(rec) = fleet.next_record() {
            count += 1;
            *per_machine.entry(rec.machine.clone()).or_insert(0u64) += 1;
            assert_eq!(rec.values.len(), 32);
        }
        assert_eq!(count, total);
        assert_eq!(per_machine["a"], 1000);
        assert_eq!(per_machine["b"], 1000);
    }

    #[test]
    fn seq_monotone_per_machine() {
        let mut fleet =
            SimulatedFleet::new(&[("a", Part::Cover, ProcessState::Stable)], 16, 2);
        let mut last = None;
        while let Some(rec) = fleet.next_record() {
            if let Some(l) = last {
                assert_eq!(rec.seq, l + 1);
            }
            last = Some(rec.seq);
        }
        assert_eq!(last, Some(999));
    }
}
