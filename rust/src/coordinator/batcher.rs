//! Ingest batcher: groups queued cycle records per machine so each
//! coordinator tick folds whole batches into machine windows (fewer
//! window locks, fewer summary-refresh triggers).

use crate::coordinator::stream::CycleRecord;
use std::collections::BTreeMap;

/// Group records by machine, preserving per-machine arrival order.
pub fn group_by_machine(records: Vec<CycleRecord>) -> BTreeMap<String, Vec<CycleRecord>> {
    let mut out: BTreeMap<String, Vec<CycleRecord>> = BTreeMap::new();
    for r in records {
        out.entry(r.machine.clone()).or_default().push(r);
    }
    out
}

/// Batch sizing policy: adapt the per-tick drain to queue depth — drain
/// more aggressively as the queue fills (keeps latency bounded under
/// burst load, the knob the backpressure ablation exercises).
pub fn adaptive_drain(queue_len: usize, base: usize, capacity: usize) -> usize {
    if queue_len == 0 {
        return 0;
    }
    let fill = queue_len as f64 / capacity as f64;
    if fill > 0.75 {
        (base * 4).min(queue_len)
    } else if fill > 0.5 {
        (base * 2).min(queue_len)
    } else {
        base.min(queue_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(m: &str, seq: u64) -> CycleRecord {
        CycleRecord { machine: m.into(), seq, values: vec![0.0] }
    }

    #[test]
    fn groups_preserve_order() {
        let recs = vec![rec("b", 0), rec("a", 0), rec("b", 1), rec("a", 1), rec("b", 2)];
        let g = group_by_machine(recs);
        assert_eq!(g["a"].iter().map(|r| r.seq).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(g["b"].iter().map(|r| r.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn adaptive_drain_scales_with_fill() {
        assert_eq!(adaptive_drain(0, 8, 100), 0);
        assert_eq!(adaptive_drain(10, 8, 100), 8);
        assert_eq!(adaptive_drain(60, 8, 100), 16);
        assert_eq!(adaptive_drain(90, 8, 100), 32);
        // never more than available
        assert_eq!(adaptive_drain(5, 8, 100), 5);
    }
}
