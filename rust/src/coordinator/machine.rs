//! Per-machine state: a sliding window of recent cycles + the cached
//! summary served to operators.

use crate::coordinator::stream::CycleRecord;
use crate::linalg::Matrix;
use std::collections::VecDeque;
use std::time::Instant;

/// A cached data summarization of one machine's recent cycles.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Sequence numbers of the representative cycles, in selection order.
    pub representative_seqs: Vec<u64>,
    /// Window-relative indices at refresh time.
    pub representative_idx: Vec<usize>,
    /// EBC value of the summary.
    pub f_value: f32,
    /// How many cycles the window held at refresh.
    pub window_len: usize,
    /// Wall-clock cost of the refresh (seconds).
    pub refresh_seconds: f64,
    /// Monotone refresh counter.
    pub version: u64,
}

/// Sliding-window state of one machine.
#[derive(Debug)]
pub struct MachineState {
    pub name: String,
    dim: Option<usize>,
    window: VecDeque<(u64, Vec<f32>)>,
    window_cap: usize,
    /// Cycles ingested since the last summary refresh.
    pub since_refresh: usize,
    pub total_ingested: u64,
    pub summary: Option<Summary>,
    pub last_seen: Option<Instant>,
}

impl MachineState {
    pub fn new(name: &str, window_cap: usize) -> MachineState {
        MachineState {
            name: name.to_string(),
            dim: None,
            window: VecDeque::new(),
            window_cap: window_cap.max(1),
            since_refresh: 0,
            total_ingested: 0,
            summary: None,
            last_seen: None,
        }
    }

    /// Fold one record into the window. Returns false (and ignores the
    /// record) on dimension mismatch — a malformed sensor frame.
    pub fn ingest(&mut self, rec: &CycleRecord) -> bool {
        match self.dim {
            None => self.dim = Some(rec.values.len()),
            Some(d) if d != rec.values.len() => {
                log::warn!(
                    "machine {}: dropping malformed frame seq={} dim {} != {}",
                    self.name,
                    rec.seq,
                    rec.values.len(),
                    d
                );
                return false;
            }
            _ => {}
        }
        if self.window.len() == self.window_cap {
            self.window.pop_front();
        }
        self.window.push_back((rec.seq, rec.values.clone()));
        self.since_refresh += 1;
        self.total_ingested += 1;
        self.last_seen = Some(Instant::now());
        true
    }

    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Live-resize the sliding window (config reload). Shrinking trims
    /// the **oldest** cycles in place; the freshest data always
    /// survives a reload.
    pub fn set_window_cap(&mut self, cap: usize) {
        let cap = cap.max(1);
        while self.window.len() > cap {
            self.window.pop_front();
        }
        self.window_cap = cap;
    }

    pub fn dim(&self) -> Option<usize> {
        self.dim
    }

    /// Materialize the window as a (n x d) matrix + the seq of each row.
    pub fn window_matrix(&self) -> Option<(Matrix, Vec<u64>)> {
        let d = self.dim?;
        if self.window.is_empty() {
            return None;
        }
        let mut data = Vec::with_capacity(self.window.len() * d);
        let mut seqs = Vec::with_capacity(self.window.len());
        for (seq, row) in &self.window {
            data.extend_from_slice(row);
            seqs.push(*seq);
        }
        Some((Matrix::from_vec(seqs.len(), d, data), seqs))
    }

    /// Store a fresh summary.
    pub fn set_summary(&mut self, s: Summary) {
        self.summary = Some(s);
        self.since_refresh = 0;
    }

    /// Does the refresh policy trigger?
    pub fn needs_refresh(&self, refresh_every: usize) -> bool {
        if self.window.is_empty() {
            return false;
        }
        match &self.summary {
            None => true,
            Some(_) => self.since_refresh >= refresh_every.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, vals: &[f32]) -> CycleRecord {
        CycleRecord { machine: "m".into(), seq, values: vals.to_vec() }
    }

    #[test]
    fn window_slides() {
        let mut m = MachineState::new("m", 3);
        for s in 0..5u64 {
            assert!(m.ingest(&rec(s, &[s as f32, 0.0])));
        }
        assert_eq!(m.window_len(), 3);
        let (mat, seqs) = m.window_matrix().unwrap();
        assert_eq!(seqs, vec![2, 3, 4]);
        assert_eq!(mat.row(0), &[2.0, 0.0]);
        assert_eq!(m.total_ingested, 5);
    }

    #[test]
    fn rejects_dim_mismatch() {
        let mut m = MachineState::new("m", 4);
        assert!(m.ingest(&rec(0, &[1.0, 2.0])));
        assert!(!m.ingest(&rec(1, &[1.0])));
        assert_eq!(m.window_len(), 1);
    }

    #[test]
    fn refresh_policy() {
        let mut m = MachineState::new("m", 10);
        assert!(!m.needs_refresh(5)); // empty window: nothing to summarize
        m.ingest(&rec(0, &[0.0]));
        assert!(m.needs_refresh(5)); // no summary yet
        m.set_summary(Summary {
            representative_seqs: vec![0],
            representative_idx: vec![0],
            f_value: 0.0,
            window_len: 1,
            refresh_seconds: 0.0,
            version: 1,
        });
        assert!(!m.needs_refresh(5));
        for s in 1..=4 {
            m.ingest(&rec(s, &[s as f32]));
        }
        assert!(!m.needs_refresh(5)); // 4 < 5
        m.ingest(&rec(5, &[5.0]));
        assert!(m.needs_refresh(5));
    }

    #[test]
    fn set_window_cap_trims_oldest() {
        let mut m = MachineState::new("m", 8);
        for s in 0..6u64 {
            m.ingest(&rec(s, &[s as f32]));
        }
        m.set_window_cap(3);
        let (_, seqs) = m.window_matrix().unwrap();
        assert_eq!(seqs, vec![3, 4, 5]);
        // growing keeps contents and raises the cap
        m.set_window_cap(5);
        m.ingest(&rec(6, &[6.0]));
        m.ingest(&rec(7, &[7.0]));
        assert_eq!(m.window_len(), 5);
        // zero clamps to one instead of emptying the window
        m.set_window_cap(0);
        assert_eq!(m.window_len(), 1);
    }

    #[test]
    fn empty_window_matrix_none() {
        let m = MachineState::new("m", 2);
        assert!(m.window_matrix().is_none());
    }
}
