//! Operator-facing request router: resolves machine names (exact or
//! unique-prefix) and serves summary queries from cached state.

use crate::coordinator::machine::{MachineState, Summary};
use std::collections::BTreeMap;

/// Reserved query name answered with a fleet-wide sharded summary
/// instead of a per-machine lookup ('@' cannot start a machine name).
pub const FLEET_QUERY: &str = "@fleet";

/// A cross-machine summary of the whole fleet's recent cycles,
/// computed on demand by sharding the concatenated per-machine windows
/// (see [`crate::shard`]).
#[derive(Debug, Clone)]
pub struct FleetSummary {
    /// Representative cycles as (machine, seq), in selection order.
    pub representatives: Vec<(String, u64)>,
    /// EBC value of the merged summary over the pooled windows.
    pub f_value: f32,
    /// Total window rows pooled across machines.
    pub window_total: usize,
    /// Machines contributing windows.
    pub machines: usize,
    /// Machines skipped (empty window or dimension mismatch).
    pub machines_skipped: usize,
    /// Non-empty shards the first stage ran.
    pub shards: usize,
    /// Wall-clock of the parallel per-shard stage (seconds).
    pub shard_seconds: f64,
    /// Wall-clock of the merge stage (seconds).
    pub merge_seconds: f64,
}

/// Routing outcome for a summary query.
#[derive(Debug, Clone)]
pub enum RouteResult {
    /// Cached summary for the machine.
    Summary(Summary),
    /// On-demand fleet-wide summary (the [`FLEET_QUERY`] route).
    Fleet(FleetSummary),
    /// Machine known but no summary computed yet.
    NotReady { ingested: u64 },
    /// Name didn't resolve.
    UnknownMachine { suggestions: Vec<String> },
    /// Prefix matched several machines.
    Ambiguous { matches: Vec<String> },
}

impl RouteResult {
    /// Human-readable one-liner for CLI output.
    pub fn describe(&self) -> String {
        match self {
            RouteResult::Summary(s) => format!(
                "summary v{} over {} cycles: representatives (seq) {:?}, f={:.4}, refreshed in {:.3}s",
                s.version, s.window_len, s.representative_seqs, s.f_value, s.refresh_seconds
            ),
            RouteResult::Fleet(s) => format!(
                "fleet summary over {} machine(s) / {} cycles ({} shard(s)): \
                 representatives {:?}, f={:.4}, shard {:.3}s + merge {:.3}s",
                s.machines,
                s.window_total,
                s.shards,
                s.representatives,
                s.f_value,
                s.shard_seconds,
                s.merge_seconds
            ),
            RouteResult::NotReady { ingested } => {
                format!("no summary yet ({ingested} cycles ingested)")
            }
            RouteResult::UnknownMachine { suggestions } => {
                format!("unknown machine; did you mean {suggestions:?}?")
            }
            RouteResult::Ambiguous { matches } => format!("ambiguous prefix: {matches:?}"),
        }
    }
}

/// Stateless resolver over the coordinator's machine map.
pub struct Router;

impl Router {
    /// Resolve `query` against the machine map.
    pub fn resolve<'a>(
        machines: &'a BTreeMap<String, MachineState>,
        query: &str,
    ) -> Result<&'a MachineState, RouteResult> {
        if let Some(m) = machines.get(query) {
            return Ok(m);
        }
        let matches: Vec<&String> = machines
            .keys()
            .filter(|k| k.starts_with(query))
            .collect();
        match matches.len() {
            1 => Ok(&machines[matches[0]]),
            0 => Err(RouteResult::UnknownMachine {
                suggestions: nearest_names(machines, query, 3),
            }),
            _ => Err(RouteResult::Ambiguous {
                matches: matches.into_iter().cloned().collect(),
            }),
        }
    }

    /// Full query path: resolve + fetch summary.
    pub fn query(machines: &BTreeMap<String, MachineState>, name: &str) -> RouteResult {
        match Self::resolve(machines, name) {
            Ok(m) => match &m.summary {
                Some(s) => RouteResult::Summary(s.clone()),
                None => RouteResult::NotReady { ingested: m.total_ingested },
            },
            Err(e) => e,
        }
    }
}

/// Closest names by edit distance (suggestions for typos).
fn nearest_names(
    machines: &BTreeMap<String, MachineState>,
    query: &str,
    top: usize,
) -> Vec<String> {
    let mut scored: Vec<(usize, &String)> = machines
        .keys()
        .map(|k| (edit_distance(k, query), k))
        .collect();
    scored.sort_by_key(|(d, k)| (*d, (*k).clone()));
    scored.into_iter().take(top).map(|(_, k)| k.clone()).collect()
}

/// Levenshtein distance (small strings; O(nm) is fine).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for i in 1..=a.len() {
        let mut cur = vec![i; b.len() + 1];
        for j in 1..=b.len() {
            let cost = if a[i - 1] == b[j - 1] { 0 } else { 1 };
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        prev = cur;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machines(names: &[&str]) -> BTreeMap<String, MachineState> {
        names
            .iter()
            .map(|n| (n.to_string(), MachineState::new(n, 10)))
            .collect()
    }

    #[test]
    fn exact_and_prefix_resolution() {
        let m = machines(&["imm-plate-1", "imm-plate-2", "imm-cover-1"]);
        assert!(Router::resolve(&m, "imm-cover-1").is_ok());
        assert!(Router::resolve(&m, "imm-cover").is_ok()); // unique prefix
        match Router::resolve(&m, "imm-plate") {
            Err(RouteResult::Ambiguous { matches }) => assert_eq!(matches.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_gets_suggestions() {
        let m = machines(&["alpha", "beta", "gamma"]);
        match Router::query(&m, "btea") {
            RouteResult::UnknownMachine { suggestions } => {
                assert_eq!(suggestions[0], "beta");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn not_ready_before_first_summary() {
        let m = machines(&["a"]);
        match Router::query(&m, "a") {
            RouteResult::NotReady { ingested } => assert_eq!(ingested, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn edit_distance_basic() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
